//! Continuous-batching serving benchmark: the scheduler's continuous
//! admission policy vs static batching at the same max-batch, on a bursty
//! trace of mixed short/long generations over the hermetic fixture model —
//! no artifacts required, so it runs on a clean checkout and in CI smoke
//! mode.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) so the perf trajectory gains a serving-throughput +
//! TTFT series next to `bench_decode_kv`.
//!
//!     cargo bench --bench bench_continuous            # full run
//!     cargo bench --bench bench_continuous -- --quick # CI smoke mode
//!
//! Expected shape: identical per-request outputs on both policies; mean
//! TTFT strictly lower under continuous admission (short requests no
//! longer wait for a whole static chunk of long decodes to drain); peak
//! live KV bytes within the configured admission budget (both asserted).

use angelslim::data::RequestGen;
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, assert_terminal_outcomes, retry_timing,
};

const MAX_BATCH: usize = 4;
const SHORT_NEW: usize = 4;
const LONG_NEW: usize = 40;

fn trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<angelslim::data::TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    // bursts land well inside the previous chunk's drain time, so static
    // batching queues them while continuous admission slots them in
    gen.take_bursty(bursts, per_burst, 0.05, SHORT_NEW, LONG_NEW)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bursts, per_burst) = if quick { (2, 4) } else { (4, 6) };
    let n = bursts * per_burst;

    let spec = FixtureSpec::default();
    let model = fixture_target(3);
    let corpus = fixture_corpus(&spec, 8_192, 9);

    // retry_timing: declare a TTFT regression only after several skewed runs
    let (stat, cont) = retry_timing(5, || {
        let stat =
            ServingEngine::serve_batched(trace(&corpus, bursts, per_burst), &model, MAX_BATCH)
                .expect("static serve");
        let cont = ServingEngine::serve_scheduled::<Transformer, _>(
            trace(&corpus, bursts, per_burst),
            &model,
            None,
            &ServeCfg::continuous(MAX_BATCH),
            0,
        )
        .expect("continuous serve");

        assert_serving_contracts(&stat, n, 0);
        assert_serving_contracts(&cont, n, 0);
        assert_outputs_match(&stat, &cont, "continuous vs static");
        let (sm, cm) = (stat.ttft_summary().mean, cont.ttft_summary().mean);
        if cm < sm {
            Ok((stat, cont))
        } else {
            Err(format!(
                "continuous mean TTFT {cm:.3}ms must beat static {sm:.3}ms at \
                 max-batch {MAX_BATCH}"
            ))
        }
    });

    let stat_ttft = stat.ttft_summary();
    let cont_ttft = cont.ttft_summary();

    // budgeted run: admission reserves projected peak KV bytes, so live
    // bytes stay within ~2 concurrent requests' worth
    let per_req_bytes =
        (8 + LONG_NEW).min(model.cfg.max_t) * model.cfg.kv_bytes_per_token();
    let budget = 2 * per_req_bytes + 1024;
    let budgeted = ServingEngine::serve_scheduled::<Transformer, _>(
        trace(&corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_BATCH).with_budget(budget),
        0,
    )
    .expect("budgeted serve");
    // completion under budget pressure + peak within budget, via the
    // shared contract assertions
    assert_serving_contracts(&budgeted, n, budget);

    // paged run at the SAME budget: free-block admission needs only each
    // prompt's pages up front, so it must sustain strictly more live
    // requests per round than projected-peak reservation — while staying
    // bit-identical per request (preemption restarts recompute greedily)
    let paged = ServingEngine::serve_paged(
        trace(&corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_BATCH)
            .with_budget(budget)
            .with_block_tokens(8),
        0,
    )
    .expect("paged serve");
    // preemption may consume extra attempts, so assert the exactly-once
    // terminal contract rather than the single-attempt fault-free one
    assert_terminal_outcomes(&paged, n, budget);
    assert_eq!(paged.goodput(), n, "paged serving completes every request");
    assert_outputs_match(&budgeted, &paged, "paged vs contiguous at equal budget");
    assert!(
        paged.mean_in_flight > budgeted.mean_in_flight,
        "paged free-block admission must sustain more in-flight than \
         projected-peak reservation at the same budget: paged {:.3} vs \
         contiguous {:.3}",
        paged.mean_in_flight,
        budgeted.mean_in_flight
    );

    let kv_util = |r: &angelslim::server::ServeReport| r.peak_kv_bytes as f64 / budget as f64;

    let mut table = Table::new(
        "continuous vs static batching (fixture model, bursty trace)",
        &[
            "policy",
            "tok/s",
            "TTFT mean ms",
            "TTFT p50 ms",
            "TTFT p99 ms",
            "peak KV KiB",
            "mean in-flight",
        ],
    );
    for (name, r, ttft) in [
        ("static", &stat, &stat_ttft),
        ("continuous", &cont, &cont_ttft),
        ("cont+budget", &budgeted, &budgeted.ttft_summary()),
        ("paged+budget", &paged, &paged.ttft_summary()),
    ] {
        table.row_strs(&[
            name,
            &f2(r.tps()),
            &f2(ttft.mean),
            &f2(ttft.p50),
            &f2(ttft.p99),
            &format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
            &f2(r.mean_in_flight),
        ]);
    }
    table.print();

    println!(
        "BENCH_JSON {{\"bench\":\"continuous_serve\",\"n_requests\":{n},\"max_batch\":{MAX_BATCH},\
         \"static_tps\":{:.2},\"cont_tps\":{:.2},\
         \"static_ttft_mean_ms\":{:.3},\"cont_ttft_mean_ms\":{:.3},\
         \"static_ttft_p50_ms\":{:.3},\"cont_ttft_p50_ms\":{:.3},\
         \"static_ttft_p99_ms\":{:.3},\"cont_ttft_p99_ms\":{:.3},\
         \"budget_bytes\":{budget},\"budget_peak_kv_bytes\":{},\
         \"budget_kv_util\":{:.4},\"budget_mean_in_flight\":{:.3},\
         \"budget_peak_in_flight\":{},\
         \"paged_peak_kv_bytes\":{},\"paged_kv_util\":{:.4},\
         \"paged_mean_in_flight\":{:.3},\"paged_peak_in_flight\":{},\
         \"quick\":{quick}}}",
        stat.tps(),
        cont.tps(),
        stat_ttft.mean,
        cont_ttft.mean,
        stat_ttft.p50,
        cont_ttft.p50,
        stat_ttft.p99,
        cont_ttft.p99,
        budgeted.peak_kv_bytes,
        kv_util(&budgeted),
        budgeted.mean_in_flight,
        budgeted.peak_in_flight,
        paged.peak_kv_bytes,
        kv_util(&paged),
        paged.mean_in_flight,
        paged.peak_in_flight,
    );
    println!(
        "shape: outputs bit-identical across policies (paged included); continuous \
         mean TTFT strictly below static at equal max-batch; budgeted peak KV \
         within budget; paged mean in-flight strictly above projected-peak \
         admission at the same budget."
    );
}
