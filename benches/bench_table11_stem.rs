//! Table 11 reproduction: LongBench-proxy accuracy of Stem vs dense and
//! the dynamic sparse baselines, per task family.
//!
//! Expected shape: Stem's average closest to Dense among the sparse
//! methods at equal budget (TPD protects the early anchors; OAM avoids
//! high-score/low-value traps).

use angelslim::eval::eval_sparse_accuracy;
use angelslim::models::{Transformer, WeightStore};
use angelslim::sparse_attn::SparseAlgo;
use angelslim::util::table::{f2, Table};

fn main() {
    let ws = WeightStore::load("artifacts").expect("run `make artifacts`");
    let model = Transformer::from_store(&ws, "target").unwrap();
    let budget = 0.35;
    let seq = 120;
    let samples = 8;

    let mut t = Table::new(
        &format!("Table 11 analogue: long-context accuracy at density {budget}"),
        &["method", "CC", "FSL", "MD1", "MD2", "SUM", "SYN", "AVG", "density"],
    );
    for algo in [
        SparseAlgo::Dense,
        SparseAlgo::MInference,
        SparseAlgo::FlexPrefill,
        SparseAlgo::XAttention,
        SparseAlgo::Stem,
    ] {
        let row = eval_sparse_accuracy(&model, algo, seq, samples, 8, budget);
        let mut cells = vec![algo.name().to_string()];
        cells.extend(row.per_task.iter().map(|(_, a)| f2(*a)));
        cells.push(f2(row.avg));
        cells.push(f2(row.mean_density));
        t.row(&cells);
    }
    t.print();
    println!(
        "paper shape: Stem's AVG sits closest to Dense among sparse methods \
         at matched budget; SYN (needle) separates anchor-preserving methods."
    );
}
