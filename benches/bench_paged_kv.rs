//! Paged-KV serving benchmark: the block-pool executors vs the contiguous
//! per-request caches on the hermetic fixture model — no artifacts
//! required, so it runs on a clean checkout and in CI smoke mode.
//!
//! Two sections, both asserted:
//!
//! 1. **Shared-prefix residency.** Every request carries the same
//!    two-page prompt; copy-on-write prefix sharing must keep resident
//!    KV strictly below `n_requests x prompt_bytes` (the contiguous
//!    cost of materializing the prompt once per request) while decoding
//!    bit-identically to the contiguous path.
//! 2. **Budget pressure.** At the same KV byte budget on a bursty
//!    short/long trace, free-block admission (charge only the prompt's
//!    pages up front, grow one page per decode round) must sustain a
//!    strictly higher mean in-flight than projected-peak reservation —
//!    again with bit-identical per-request outputs.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) so the perf trajectory gains a paged-KV series next to
//! `bench_continuous` / `bench_sharded`.
//!
//!     cargo bench --bench bench_paged_kv            # full run
//!     cargo bench --bench bench_paged_kv -- --quick # CI smoke mode

use angelslim::data::{RequestGen, TokenRequest};
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, assert_terminal_outcomes,
};

const BLOCK_TOKENS: usize = 8;
/// Two full pages at `BLOCK_TOKENS = 8`, so the entire prompt is shareable.
const PROMPT_LEN: usize = 16;
const SHARED_NEW: usize = 8;
const SHORT_NEW: usize = 4;
const LONG_NEW: usize = 40;
const MAX_BATCH: usize = 4;

/// Shared-prefix trace: every request carries the identical prompt (a
/// planted-rule walk, so greedy decoding is meaningful). All requests
/// arrive together so concurrency is pinned by `max_in_flight`, not by
/// how fast a decode round happens to run — the residency comparison
/// needs the prompts live at the same time.
fn shared_prefix_trace(n: usize) -> Vec<TokenRequest> {
    let prompt: Vec<u8> = (0..PROMPT_LEN).map(|i| ((i * 5) % 32) as u8).collect();
    (0..n)
        .map(|i| TokenRequest {
            id: i as u64,
            prompt: prompt.clone(),
            max_new_tokens: SHARED_NEW,
            arrival_ms: 0.0,
            deadline_ms: None,
            class: Default::default(),
        })
        .collect()
}

fn bursty_trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    // bursts land well inside the previous burst's drain time, so the
    // admission policy — not the arrival process — sets concurrency
    gen.take_bursty(bursts, per_burst, 0.05, SHORT_NEW, LONG_NEW)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let model = fixture_target(3);
    let kv_per_token = model.cfg.kv_bytes_per_token();

    // ── 1. shared-prefix residency (unbounded pool, all requests live) ──
    let n_shared = if quick { 6 } else { 12 };
    let flat = ServingEngine::serve_scheduled::<Transformer, _>(
        shared_prefix_trace(n_shared),
        &model,
        None,
        &ServeCfg::continuous(n_shared),
        0,
    )
    .expect("contiguous shared-prefix serve");
    let paged_shared = ServingEngine::serve_paged(
        shared_prefix_trace(n_shared),
        &model,
        None,
        &ServeCfg::continuous(n_shared).with_block_tokens(BLOCK_TOKENS),
        0,
    )
    .expect("paged shared-prefix serve");
    assert_serving_contracts(&flat, n_shared, 0);
    assert_terminal_outcomes(&paged_shared, n_shared, 0);
    assert_outputs_match(&flat, &paged_shared, "paged vs contiguous, shared prefix");

    // the contiguous cost of holding every request's prompt at once; the
    // paged path must stay strictly below it because the two sealed
    // prompt pages are resident once and refcounted, not copied per slot
    let naive_prompt_bytes = n_shared * PROMPT_LEN * kv_per_token;
    assert!(
        paged_shared.peak_kv_bytes < naive_prompt_bytes,
        "shared-prefix resident KV must stay strictly below n x prompt bytes: \
         paged peak {} vs naive {}",
        paged_shared.peak_kv_bytes,
        naive_prompt_bytes
    );
    assert!(
        paged_shared.peak_kv_bytes < flat.peak_kv_bytes,
        "paged peak KV {} must undercut the contiguous peak {} on a \
         shared-prefix trace",
        paged_shared.peak_kv_bytes,
        flat.peak_kv_bytes
    );
    let residency_ratio = naive_prompt_bytes as f64 / paged_shared.peak_kv_bytes as f64;

    // ── 2. budget pressure (bursty trace, equal byte budget) ──
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);
    let (bursts, per_burst) = if quick { (2, 4) } else { (3, 6) };
    let n_burst = bursts * per_burst;
    let per_req_bytes = (8 + LONG_NEW).min(model.cfg.max_t) * kv_per_token;
    // ~2 long requests' worth; the largest single request still fits, so
    // the paged overcommit valve never has to fire and peak stays in budget
    let budget = 2 * per_req_bytes + 1024;

    let cont_b = ServingEngine::serve_scheduled::<Transformer, _>(
        bursty_trace(&corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_BATCH).with_budget(budget),
        0,
    )
    .expect("contiguous budgeted serve");
    let paged_b = ServingEngine::serve_paged(
        bursty_trace(&corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_BATCH)
            .with_budget(budget)
            .with_block_tokens(BLOCK_TOKENS),
        0,
    )
    .expect("paged budgeted serve");
    assert_serving_contracts(&cont_b, n_burst, budget);
    // preemption may consume extra attempts, so assert the exactly-once
    // terminal contract rather than the single-attempt fault-free one
    assert_terminal_outcomes(&paged_b, n_burst, budget);
    assert_eq!(paged_b.goodput(), n_burst, "paged serving completes every request");
    assert_outputs_match(&cont_b, &paged_b, "paged vs contiguous at equal budget");
    assert!(
        paged_b.mean_in_flight > cont_b.mean_in_flight,
        "free-block admission must sustain more in-flight than projected-peak \
         reservation at the same budget: paged {:.3} vs contiguous {:.3}",
        paged_b.mean_in_flight,
        cont_b.mean_in_flight
    );

    let kv_util = |r: &ServeReport| r.peak_kv_bytes as f64 / budget as f64;

    let mut table = Table::new(
        "paged vs contiguous KV (fixture model)",
        &[
            "section",
            "path",
            "peak KV KiB",
            "KV util",
            "mean in-flight",
            "peak in-flight",
        ],
    );
    let kib = |b: usize| format!("{:.1}", b as f64 / 1024.0);
    for (section, path, r, util) in [
        ("shared-prefix", "contiguous", &flat, f64::NAN),
        ("shared-prefix", "paged", &paged_shared, f64::NAN),
        ("budget", "contiguous", &cont_b, kv_util(&cont_b)),
        ("budget", "paged", &paged_b, kv_util(&paged_b)),
    ] {
        table.row_strs(&[
            section,
            path,
            &kib(r.peak_kv_bytes),
            &(if util.is_nan() { "-".to_string() } else { f2(util) }),
            &f2(r.mean_in_flight),
            &r.peak_in_flight.to_string(),
        ]);
    }
    table.print();

    println!(
        "BENCH_JSON {{\"bench\":\"paged_kv\",\"block_tokens\":{BLOCK_TOKENS},\
         \"shared\":{{\"n_requests\":{n_shared},\"prompt_len\":{PROMPT_LEN},\
         \"naive_prompt_bytes\":{naive_prompt_bytes},\
         \"flat_peak_kv_bytes\":{},\"paged_peak_kv_bytes\":{},\
         \"prompt_residency_ratio\":{residency_ratio:.3}}},\
         \"budget\":{{\"n_requests\":{n_burst},\"budget_bytes\":{budget},\
         \"cont_kv_util\":{:.4},\"cont_mean_in_flight\":{:.3},\
         \"cont_peak_in_flight\":{},\
         \"paged_kv_util\":{:.4},\"paged_mean_in_flight\":{:.3},\
         \"paged_peak_in_flight\":{}}},\"quick\":{quick}}}",
        flat.peak_kv_bytes,
        paged_shared.peak_kv_bytes,
        kv_util(&cont_b),
        cont_b.mean_in_flight,
        cont_b.peak_in_flight,
        kv_util(&paged_b),
        paged_b.mean_in_flight,
        paged_b.peak_in_flight,
    );
    println!(
        "shape: outputs bit-identical paged vs contiguous on both traces; \
         shared-prefix resident KV strictly below n x prompt bytes (prompt \
         pages refcounted, not copied); paged mean in-flight strictly above \
         projected-peak admission at the same byte budget."
    );
}
