//! Tables 4-6 reproduction: FP8 degradation and LeptoQuant recovery, plus
//! the W4A8 near-lossless row, on the trained Rust transformer.
//!
//! Expected shape: BF16 < FP8-lepto <= FP8 (NLL; lower better), with
//! lepto recovering part of the fp8 drop; W4A8 near-lossless.

use angelslim::config::SlimConfig;
use angelslim::coordinator::CompressEngine;
use angelslim::util::table::{f2, Table};

fn run(algo: &str) -> angelslim::coordinator::StageReport {
    let src = format!(
        "global:\n  save_path: ./output/t456\nmodel:\n  name: tiny-target\n  artifacts_dir: artifacts\n\
         compression:\n  method: quantization\n  quantization:\n    algo: {algo}\n\
         dataset:\n  kind: artifact\n  num_samples: 10\n  seq_len: 48\n"
    );
    CompressEngine::new(SlimConfig::from_str(&src).unwrap())
        .unwrap()
        .run()
        .unwrap()
        .stages
        .into_iter()
        .next()
        .unwrap()
}

fn main() {
    let mut t = Table::new(
        "Tables 4-6 analogue: FP8 / LeptoQuant / W4A8 (NLL, lower better)",
        &["type", "NLL", "delta vs BF16", "notes"],
    );
    let fp8 = run("fp8_dynamic");
    let base = fp8.metric_before;
    t.row_strs(&["BF16 (fp32 here)", &f2(base), "+0.00", ""]);
    t.row_strs(&[
        "FP8",
        &f2(fp8.metric_after),
        &format!("{:+.3}", fp8.metric_after - base),
        "",
    ]);
    let lepto = run("leptoquant");
    let alpha_notes: Vec<&str> = lepto
        .notes
        .iter()
        .filter(|n| n.contains("alpha"))
        .map(String::as_str)
        .take(2)
        .collect();
    t.row_strs(&[
        "FP8-lepto",
        &f2(lepto.metric_after),
        &format!("{:+.3}", lepto.metric_after - base),
        &alpha_notes.join("; "),
    ]);
    let w4a8 = run("w4a8");
    t.row_strs(&[
        "W4A8",
        &f2(w4a8.metric_after),
        &format!("{:+.3}", w4a8.metric_after - base),
        "group-wise int4 weights",
    ]);
    t.print();
    println!(
        "paper shape: FP8 costs accuracy on hard streams; LeptoQuant's \
         outlier-isolated scales recover part of it; W4A8 near-lossless."
    );
}
