//! Table 3 reproduction: inference efficiency of ternary packing
//! strategies — BF16 dense vs BitNet-I2_S (2.0b) vs Tequila-TL2 (1.67b)
//! vs Sherry (1.25b): tokens/s (packed GEMV decode) and model size.
//!
//! Expected shape: Sherry fastest AND smallest (power-of-two-aligned 4-way
//! decode); 1.67-bit base-3 decode slower than 2-bit despite fewer bytes;
//! all packed formats >> dense.

use angelslim::quant::packing::{
    gemv_f32, PackFormat, Packed2Bit, PackedSherry, PackedTernary167,
};
use angelslim::quant::{Sherry, TernaryQuantizer};
use angelslim::util::table::{f1, Table};
use angelslim::util::{bench, Rng};

fn run_scale(label: &str, n: usize, k: usize, t: &mut Table) {
    let mut rng = Rng::new(0);
    let w = rng.normal_vec(n * k, 0.05);
    let x = rng.normal_vec(k, 1.0);
    let mut y = vec![0.0f32; n];

    let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
    let p2 = Packed2Bit::from_codes(&codes, &alphas, n, k);
    let p167 = PackedTernary167::from_codes(&codes, &alphas, n, k);
    let (scodes, salphas) = Sherry::quantize_codes(&w, n, k);
    let psherry = PackedSherry::from_codes(&scodes, &salphas, n, k);

    let iters = 30;
    let rows = [
        ("BF16", PackFormat::F16, bench("f32", 2, iters, || gemv_f32(&w, n, k, &x, &mut y))),
        ("BitNet(I2_S)", PackFormat::TwoBit, {
            let mut lut = Vec::new();
            bench("2b", 2, iters, || p2.gemv_lut(&x, &mut y, &mut lut))
        }),
        ("Tequila(TL2)", PackFormat::Ternary167, bench("167", 2, iters, || p167.gemv(&x, &mut y))),
        ("Sherry", PackFormat::Sherry125, bench("sherry", 2, iters, || psherry.gemv(&x, &mut y))),
    ];
    for (name, fmt, r) in rows {
        t.row_strs(&[
            label,
            name,
            &format!("{:.2}", fmt.bits_per_weight()),
            &f1(r.per_sec()),
            &format!("{:.2}", fmt.matrix_bytes(n, k) as f64 / 1e6),
        ]);
    }
}

fn main() {
    let mut t = Table::new(
        "Table 3 analogue: ternary packing efficiency (packed GEMV decode)",
        &["scale", "method", "bits", "speed (gemv/s)", "size (MB)"],
    );
    run_scale("small (2048x512)", 2048, 512, &mut t);
    run_scale("large (4096x1024)", 4096, 1024, &mut t);
    t.print();
    println!(
        "paper shape: Sherry beats BitNet-2.0b and Tequila-1.67b on both \
         speed and size; 1.67b trades size for slow 3-way decode."
    );
}
