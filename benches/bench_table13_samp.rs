//! Table 13 reproduction: audio token reduction WER — Samp vs merging /
//! pruning / hybrid baselines on three ASR model profiles.
//!
//! Expected shape: Samp lowest WER at both compression ratios; pure
//! pruning (VisionZip/VisPruner on audio) worst — dropping frames deletes
//! phonemes; merge-aware methods (A-ToMe, FastAdaSP) in between.

use angelslim::data::AudioSceneGen;
use angelslim::eval::{asr::baseline_wer, eval_wer};
use angelslim::token_prune::audio::all_audio_reducers;
use angelslim::util::table::{f2, Table};

fn main() {
    // three model rows of Table 13 = three noise/segment profiles
    let profiles = [
        ("qwen2audio-s", AudioSceneGen::new(16, 40, 0.3, 1)),
        ("kimiaudio-s", AudioSceneGen::new(16, 48, 0.25, 2)),
        ("glmasr-s", AudioSceneGen::new(12, 40, 0.35, 3)),
    ];
    let scenes = 20;
    let frames = 150;

    for (name, gen) in &profiles {
        let base = baseline_wer(gen, scenes, frames);
        let mut t = Table::new(
            &format!("Table 13 analogue [{name}]: WER% (full-token baseline {:.2})", base),
            &["method", "retain 30%", "retain 45%"],
        );
        let mut best = ("", f64::INFINITY);
        let mut rows = Vec::new();
        for r in all_audio_reducers() {
            let w60 = eval_wer(gen, r.as_ref(), 0.3, scenes, frames);
            let w70 = eval_wer(gen, r.as_ref(), 0.45, scenes, frames);
            let avg = (w60 + w70) / 2.0;
            rows.push((r.name(), w60, w70));
            if avg < best.1 {
                best = (r.name(), avg);
            }
        }
        for (name, w60, w70) in rows {
            t.row_strs(&[name, &f2(w60), &f2(w70)]);
        }
        t.print();
        println!("  best avg on {name}: {} ({:.2})", best.0, best.1);
    }
    println!(
        "paper shape: Samp lowest WER across profiles; pure pruning worst \
         (deletes phonemes), pure merging in between."
    );
}
