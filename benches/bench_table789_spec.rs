//! Tables 7-9 reproduction: Eagle3-style speculative decoding TPS / AL
//! across task mixes and speculative depths, on the PJRT artifacts.
//!
//! Expected shape: TPS_spec / TPS_vanilla ≈ 1.4-2.0x, AL in 1.7-3.5, with
//! task-dependent variation (predictable spans accept more).

use angelslim::runtime::ArtifactRegistry;
use angelslim::spec_decode::{SpecDecoder, VanillaDecoder};
use angelslim::util::table::{f2, Table};
use angelslim::util::Rng;

fn main() {
    let mut reg = ArtifactRegistry::open("artifacts").expect("run `make artifacts`");
    let target = reg.model("model_target_fp32_b1").unwrap();
    let draft = reg.model("model_draft_fp32_b1").unwrap();
    let eval = std::fs::read("artifacts/eval_corpus.bin").unwrap();

    // four "task mixes" = prompt pools from different corpus regions
    let mixes = [
        ("mix-A (gsm8k-like)", 0usize),
        ("mix-B (alpaca-like)", 8000),
        ("mix-C (humaneval-like)", 16000),
        ("mix-D (mtbench-like)", 24000),
    ];
    let n_prompts = 6;
    let max_new = 40;

    let mut t = Table::new(
        "Tables 7-9 analogue: Eagle3 speculative decoding on vLLM-style loop",
        &["task mix", "gamma", "vanilla TPS", "eagle3 TPS", "speedup", "AL", "accept%"],
    );

    for (label, off) in mixes {
        for gamma in [2usize, 4] {
            let mut rng = Rng::new(1);
            let mut v_tok = 0usize;
            let mut v_time = 0.0;
            let mut s_tok = 0usize;
            let mut s_time = 0.0;
            let mut steps = 0usize;
            let mut accepted = 0usize;
            let mut proposed = 0usize;
            for p in 0..n_prompts {
                let start = off + p * 97;
                let prompt = &eval[start..start + 12];
                let (vout, vs) = VanillaDecoder::new(&target)
                    .generate(prompt, max_new, &mut rng)
                    .unwrap();
                v_tok += vs.generated;
                v_time += vs.wall_s;
                let (sout, ss) = SpecDecoder::new(&draft, &target, gamma)
                    .generate(prompt, max_new, &mut rng)
                    .unwrap();
                assert_eq!(vout, sout, "spec decode must preserve outputs");
                s_tok += ss.generated;
                s_time += ss.wall_s;
                steps += ss.steps;
                accepted += ss.accepted_draft;
                proposed += ss.proposed;
            }
            let v_tps = v_tok as f64 / v_time;
            let s_tps = s_tok as f64 / s_time;
            t.row_strs(&[
                label,
                &gamma.to_string(),
                &f2(v_tps),
                &f2(s_tps),
                &format!("{:.2}x", s_tps / v_tps),
                &f2(s_tok as f64 / steps as f64),
                &format!("{:.0}%", 100.0 * accepted as f64 / proposed as f64),
            ]);
        }
    }
    t.print();
    println!(
        "paper shape: consistent TPS gain with AL ~2 (gamma=2) to ~3 \
         (gamma=4) on predictable mixes; outputs bit-identical to vanilla."
    );
}
