//! Figure 11 reproduction: prefill latency of sparse attention methods —
//! measured masked-attention wall-clock (pure-Rust consumer) and the
//! analytic kernel-FLOPs model, by sequence length.
//!
//! Expected shape: all sparse methods cut latency vs dense, with Stem
//! among the cheapest since its metric computation is lightweight and its
//! position-decay schedule keeps block selection simple.

use angelslim::sparse_attn::{attn_flops, flops::masked_attn_flops, SparseAlgo};
use angelslim::tensor::{ops::dot, Tensor};
use angelslim::util::table::{f2, Table};
use angelslim::util::{bench, Rng};

/// Masked single-head attention (the sparse-kernel consumer).
fn masked_attention(q: &Tensor, k: &Tensor, v: &Tensor, mask: &angelslim::sparse_attn::BlockMask) -> f32 {
    let t = q.rows();
    let dh = q.cols();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut acc_out = 0.0f32;
    let mut scores = vec![0.0f32; t];
    for qi in 0..t {
        let mut maxs = f32::NEG_INFINITY;
        for ki in 0..=qi {
            if mask.get(qi / mask.block, ki / mask.block) {
                scores[ki] = dot(q.row(qi), k.row(ki)) * scale;
                maxs = maxs.max(scores[ki]);
            } else {
                scores[ki] = f32::NEG_INFINITY;
            }
        }
        let mut denom = 0.0f32;
        let mut out0 = 0.0f32;
        for ki in 0..=qi {
            if scores[ki] > f32::NEG_INFINITY {
                let p = (scores[ki] - maxs).exp();
                denom += p;
                out0 += p * v.row(ki)[0];
            }
        }
        acc_out += out0 / denom.max(1e-12);
    }
    acc_out
}

fn main() {
    let dh = 32;
    let budget = 0.3;
    let mut t = Table::new(
        "Figure 11 analogue: prefill attention latency (ms) / analytic FLOP ratio",
        &["seq", "Dense", "MINF", "XATTN", "FLEX", "Stem"],
    );
    for seq in [128usize, 256, 512] {
        let mut rng = Rng::new(seq as u64);
        let q = Tensor::randn(&[seq, dh], 0.3, &mut rng);
        let k = Tensor::randn(&[seq, dh], 0.3, &mut rng);
        let v = Tensor::randn(&[seq, dh], 0.3, &mut rng);
        let mut cells = vec![seq.to_string()];
        for algo in [
            SparseAlgo::Dense,
            SparseAlgo::MInference,
            SparseAlgo::XAttention,
            SparseAlgo::FlexPrefill,
            SparseAlgo::Stem,
        ] {
            // latency = pattern estimation + masked attention execution
            let r = bench(algo.name(), 1, 5, || {
                let mask = algo.mask(&q, &k, &v, 16, budget);
                std::hint::black_box(masked_attention(&q, &k, &v, &mask));
            });
            let mask = algo.mask(&q, &k, &v, 16, budget);
            let ratio = masked_attn_flops(&mask, dh, 0) / attn_flops(seq, dh);
            cells.push(format!("{} / {}", f2(r.median_ms()), f2(ratio)));
        }
        t.row(&cells);
    }
    t.print();
    println!(
        "cells are `measured ms / kernel-FLOP fraction vs dense`; paper \
         shape: sparse methods cut prefill cost, growing with seq len."
    );
}
