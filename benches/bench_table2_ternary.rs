//! Table 2 reproduction: Tequila (1.67-bit) and Sherry (1.25-bit) vs
//! ternary QAT baselines across the 5-task suite.
//!
//! Expected shape: FP32 > {Tequila, Sherry} > {BitNet*, TWN-style,
//! LLM-QAT*}; Sherry matches Tequila despite 25% fewer bits.

use angelslim::qat::trainer::{train_suite, QatMethod, TrainCfg};
use angelslim::qat::ClassTask;
use angelslim::util::table::{f2, Table};

fn main() {
    let cfg = TrainCfg { steps: 1500, lr: 0.03, hidden: 48, eval_n: 300, seed: 3 };
    let dim = 24;
    let tasks = ClassTask::suite(dim, 7);
    let headers: Vec<String> = std::iter::once("method (bits)".to_string())
        .chain(tasks.iter().map(|t| t.name.to_string()))
        .chain(["average".to_string()])
        .collect();
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Table 2 analogue: ternary QAT suite accuracy", &hrefs);

    for method in [
        QatMethod::Fp32,
        QatMethod::LlmQatProxy,
        QatMethod::Twn,
        QatMethod::BitNetProxy,
        QatMethod::Tequila,
        QatMethod::Sherry,
    ] {
        let (reports, mean) = train_suite(method, dim, &cfg);
        let mut row = vec![format!("{} ({:.2})", method.name(), method.bits())];
        row.extend(reports.iter().map(|r| f2(r.accuracy)));
        row.push(f2(mean));
        t.row(&row);
    }
    t.print();
    println!(
        "paper shape: Tequila/Sherry close most of the gap to FP16 that \
         plain ternary baselines leave open; Sherry holds at 1.25 bits."
    );
}
