//! Table 12 reproduction: visual token pruning — IDPruner vs 8 baselines
//! at 25% and 10% retention on the VQA-proxy scenes.
//!
//! Expected shape: IDPruner best (or tied-best) average at both ratios;
//! importance-only (FastV) and diversity-only (DivPrune) both trail the
//! importance+diversity hybrid — the paper's MMR argument.

use angelslim::data::VisionSceneGen;
use angelslim::eval::{eval_pruner_accuracy, vqa::baseline_accuracy};
use angelslim::token_prune::visual::all_visual_pruners;
use angelslim::util::table::{pct, Table};

fn main() {
    // three "benchmarks" = scene generators with different stats
    let gens = [
        ("docvqa-s", VisionSceneGen::new(96, 24, 6, 1)),
        ("mme-s", VisionSceneGen::new(144, 32, 8, 2)),
        ("textvqa-s", VisionSceneGen::new(96, 16, 4, 3)),
    ];
    let n = 50;

    let mut base_row = vec!["Baseline (100%)".to_string()];
    for (_, gen) in &gens {
        let b = baseline_accuracy(gen, n);
        base_row.push(pct(b));
        base_row.push(pct(b));
    }
    base_row.push("100.0%".into());

    let headers: Vec<String> = std::iter::once("method".to_string())
        .chain(gens.iter().flat_map(|(name, _)| {
            [format!("{name}@25%"), format!("{name}@10%")]
        }))
        .chain(["avg".to_string()])
        .collect();
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Table 12 analogue: visual token pruning", &hrefs);
    t.row(&base_row);

    let mut results: Vec<(String, f64)> = Vec::new();
    for p in all_visual_pruners() {
        let mut row = vec![p.name().to_string()];
        let mut sum = 0.0;
        for (_, gen) in &gens {
            let a25 = eval_pruner_accuracy(gen, p.as_ref(), 0.25, n);
            let a10 = eval_pruner_accuracy(gen, p.as_ref(), 0.10, n);
            row.push(pct(a25));
            row.push(pct(a10));
            sum += a25 + a10;
        }
        let avg = sum / (gens.len() * 2) as f64;
        row.push(pct(avg));
        results.push((p.name().to_string(), avg));
        t.row(&row);
    }
    t.print();

    results.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("ranking by avg: {:?}", results.iter().map(|(n, a)| format!("{n}={a:.3}")).collect::<Vec<_>>());
    println!("paper shape: IDPruner top-ranked, importance-only and diversity-only both behind.");
}
