//! Figure 2 reproduction: TTFT (prefill) and generation throughput on
//! "edge" hardware across precisions {FP16/32, 4-bit, 2-bit}.
//!
//! The paper measures Apple M4 / Dimensity 9500 GGUF inference; we measure
//! the packed-GEMM hot path on the host CPU in the same memory-bandwidth-
//! bound regime (see DESIGN.md §Hardware-Adaptation). Expected shape:
//! lower bits => higher decode throughput and lower TTFT, super-linear in
//! the bandwidth-bound regime.

use angelslim::quant::packing::{gemv_f32, PackFormat, Packed2Bit, PackedInt4};
use angelslim::quant::{AffineQuantizer, Seq2Quantizer};
use angelslim::util::table::{f1, f2, Table};
use angelslim::util::{bench, Rng};

fn main() {
    // a decode step = GEMV through a d x 4d FFN-ish matrix; prefill(T) =
    // T GEMVs (no KV-cache reuse in this microcosm)
    let (n, k) = (2048, 512);
    let mut rng = Rng::new(0);
    let w: Vec<f32> = rng.normal_vec(n * k, 0.05);
    let x: Vec<f32> = rng.normal_vec(k, 1.0);
    let mut y = vec![0.0f32; n];

    let q4 = AffineQuantizer::int4_group32();
    let (codes4, scales4) = q4.quantize_codes(&w, n, k);
    let packed4 = PackedInt4::from_codes(&codes4, &scales4, n, k, 32);

    let q2 = Seq2Quantizer::new(32);
    let (codes2, _scales2) = q2.quantize_codes(&w, n, k);
    // 2-bit decode path: ternary-style expansion with per-row alpha
    let alphas = vec![0.05f32; n];
    let packed2 = Packed2Bit::from_codes(&codes2, &alphas, n, k);

    let iters = 40;
    let r_f32 = bench("f32", 3, iters, || gemv_f32(&w, n, k, &x, &mut y));
    let r_i4_base = bench("int4-base", 3, iters, || packed4.gemv(&x, &mut y));
    let mut lut4 = Vec::new();
    let r_i4 = bench("int4-lut", 3, iters, || packed4.gemv_lut(&x, &mut y, &mut lut4));
    let r_2b_base = bench("2bit-base", 3, iters, || packed2.gemv(&x, &mut y));
    let mut lut = Vec::new();
    let r_2b = bench("2bit-lut", 3, iters, || packed2.gemv_lut(&x, &mut y, &mut lut));
    println!(
        "perf: 2-bit inline-unpack {:.1}/s -> T-MAC LUT {:.1}/s ({:.2}x); \
         int4 {:.1}/s -> {:.1}/s ({:.2}x)",
        r_2b_base.per_sec(), r_2b.per_sec(), r_2b.per_sec() / r_2b_base.per_sec(),
        r_i4_base.per_sec(), r_i4.per_sec(), r_i4.per_sec() / r_i4_base.per_sec()
    );

    let mut t = Table::new(
        "Figure 2 analogue: decode throughput + prefill TTFT by precision",
        &["precision", "bytes/layer", "decode t/s", "speedup", "TTFT@256 ms", "TTFT@512 ms", "TTFT@1024 ms"],
    );
    let base_tps = r_f32.per_sec();
    for (name, fmt, r) in [
        ("FP16/32", PackFormat::F32, &r_f32),
        ("4-bit (Q4)", PackFormat::Int4, &r_i4),
        ("2-bit (SEQ)", PackFormat::TwoBit, &r_2b),
    ] {
        let step = r.median_s;
        t.row_strs(&[
            name,
            &fmt.matrix_bytes(n, k).to_string(),
            &f1(r.per_sec()),
            &format!("{:.2}x", r.per_sec() / base_tps),
            &f2(step * 256.0 * 1e3),
            &f2(step * 512.0 * 1e3),
            &f2(step * 1024.0 * 1e3),
        ]);
    }
    t.print();
    println!(
        "paper shape: 2-bit gives 3-8x TTFT gain and >2x decode over FP16; \
         4-bit sits between."
    );
}
