//! SLO-aware serving benchmark: class-priority admission + admission-time
//! compression routing vs the class-blind FIFO pool on the same seeded
//! mixed-class bursty trace (interactive / long-context / multimodal /
//! batch) over the hermetic fixture model — no artifacts required, so it
//! runs on a clean checkout and in CI smoke mode.
//!
//! The class-aware pool seats the highest-priority queued request next
//! (strict FIFO within a class, aging bound so batch never starves),
//! routes long-context prefills through the STeM sparse-attention path,
//! and token-prunes multimodal prompts before KV admission. The
//! class-blind pool is the same `WorkerPool` with `classes` unset —
//! byte-identical to the historical FIFO scheduler.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) so the perf trajectory gains an SLO series next to
//! `bench_sharded` / `bench_faults`.
//!
//!     cargo bench --bench bench_slo            # full run
//!     cargo bench --bench bench_slo -- --quick # CI smoke mode
//!
//! Expected shape: equal goodput (every request completes in both
//! modes), strictly lower interactive p99 TTFT under the class-aware
//! pool (asserted under retry_timing), sparse prefills > 0 and pruned
//! prompt tokens > 0 only in the class-aware run, and interactive /
//! batch outputs bit-identical across modes (their prompts and decode
//! path are untouched by the routing).

use angelslim::data::{RequestGen, TokenRequest};
use angelslim::models::Transformer;
use angelslim::server::{ClassPolicy, RequestClass, ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::retry_timing;
use angelslim::util::Summary;

const WORKERS: usize = 2;
const MAX_IN_FLIGHT: usize = 2; // per worker: keeps the shared queue deep
// long prompts stay below the fixture's max_t (48) so decode room is
// never zero — a request with no decode budget finishes empty without
// ever prefilling, which would undercount sparse routing
const LONG_PROMPT: usize = 32;
const MM_VISUAL: usize = 12;
const MM_AUDIO: usize = 8;

fn trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    gen.max_new_tokens = 8;
    // bursts land nearly simultaneously so admission order — not arrival
    // order — decides who waits behind the long-context prefills
    gen.take_mixed_classes(bursts, per_burst, 0.05, LONG_PROMPT, MM_VISUAL, MM_AUDIO)
}

fn run(corpus: &[u8], bursts: usize, per_burst: usize, aware: bool) -> ServeReport {
    let model = fixture_target(3);
    let mut cfg = ServeCfg::continuous(MAX_IN_FLIGHT).with_workers(WORKERS);
    if aware {
        cfg = cfg.with_classes(ClassPolicy::default());
    }
    ServingEngine::serve_scheduled::<Transformer, _>(
        trace(corpus, bursts, per_burst),
        &model,
        None,
        &cfg,
        0,
    )
    .expect("slo serve")
}

/// TTFT summary of one class's completed requests.
fn class_ttft(r: &ServeReport, name: &str) -> Summary {
    Summary::of(
        &r.completed
            .iter()
            .filter(|c| c.class.name() == name && c.is_completed())
            .map(|c| c.ttft_ms)
            .collect::<Vec<_>>(),
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bursts, per_burst) = if quick { (3, 10) } else { (6, 10) };
    let n = bursts * per_burst;

    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);

    // retry_timing: the virtual clock charges measured wall time per
    // round, so declare a regression only after several skewed runs
    let (blind, aware) = retry_timing(5, || {
        let blind = run(&corpus, bursts, per_burst, false);
        let aware = run(&corpus, bursts, per_burst, true);

        // equal goodput: no faults, no deadlines — both modes must
        // complete the entire trace
        assert_eq!(blind.goodput(), n, "class-blind pool must complete the trace");
        assert_eq!(aware.goodput(), n, "class-aware pool must complete the trace");

        // compression routing fires only under the class policy
        assert_eq!(blind.sparse_prefills, 0, "no sparse routing without classes");
        assert_eq!(blind.pruned_prompt_tokens, 0, "no pruning without classes");
        assert!(aware.sparse_prefills > 0, "LongContext must prefill sparse");
        assert!(aware.pruned_prompt_tokens > 0, "Multimodal must be pruned");

        // interactive/batch prompts and decode are untouched by the
        // routing, so their outputs are bit-identical across modes
        for (b, a) in blind.completed.iter().zip(&aware.completed) {
            assert_eq!(b.id, a.id, "reports are ordered by id");
            if matches!(b.class, RequestClass::Interactive | RequestClass::Batch) {
                assert_eq!(
                    b.output, a.output,
                    "request {} ({}) output must not depend on scheduling",
                    b.id,
                    b.class.name()
                );
            }
        }

        let bp99 = class_ttft(&blind, "interactive").p99;
        let ap99 = class_ttft(&aware, "interactive").p99;
        if ap99 < bp99 {
            Ok((blind, aware))
        } else {
            Err(format!(
                "class-aware admission must strictly beat class-blind FIFO on \
                 interactive p99 TTFT at equal goodput (aware {ap99:.3} ms vs \
                 blind {bp99:.3} ms)"
            ))
        }
    });

    let mut table = Table::new(
        "SLO-aware serving: class-aware vs class-blind FIFO (fixture model, mixed-class bursty trace)",
        &["class", "blind TTFT p50", "blind TTFT p99", "aware TTFT p50", "aware TTFT p99"],
    );
    for name in RequestClass::NAMES {
        let b = class_ttft(&blind, name);
        let a = class_ttft(&aware, name);
        table.row_strs(&[name, &f2(b.p50), &f2(b.p99), &f2(a.p50), &f2(a.p99)]);
    }
    table.print();
    println!(
        "routing: {} sparse prefills, {} multimodal prompt tokens pruned \
         (class-aware run only)",
        aware.sparse_prefills, aware.pruned_prompt_tokens
    );

    let j = |r: &ServeReport| {
        let i = class_ttft(r, "interactive");
        let b = class_ttft(r, "batch");
        format!(
            "\"goodput\":{},\"tps\":{:.2},\"makespan_ms\":{:.3},\
             \"interactive_ttft_p50_ms\":{:.3},\"interactive_ttft_p99_ms\":{:.3},\
             \"batch_ttft_p99_ms\":{:.3},\
             \"sparse_prefills\":{},\"pruned_prompt_tokens\":{}",
            r.goodput(),
            r.virtual_tps(),
            r.makespan_ms,
            i.p50,
            i.p99,
            b.p99,
            r.sparse_prefills,
            r.pruned_prompt_tokens,
        )
    };
    let improvement = class_ttft(&blind, "interactive").p99
        / class_ttft(&aware, "interactive").p99.max(1e-12);
    println!(
        "BENCH_JSON {{\"bench\":\"slo_serve\",\"n_requests\":{n},\
         \"workers\":{WORKERS},\"max_in_flight\":{MAX_IN_FLIGHT},\
         \"blind\":{{{}}},\"aware\":{{{}}},\
         \"interactive_p99_speedup\":{improvement:.3},\"quick\":{quick}}}",
        j(&blind),
        j(&aware),
    );
    println!(
        "shape: equal goodput in both modes; interactive p99 TTFT strictly \
         lower under class-aware admission; sparse prefills and multimodal \
         pruning fire only under the class policy; interactive/batch outputs \
         bit-identical across modes."
    );
}
