//! Table 1 reproduction: HY-1.8B-2Bit (SEQ QAT) vs FP16 / INT4-PTQ /
//! half-size dense, on the trained TinyTransformer artifacts.
//!
//! Columns: NLL + next-token accuracy on the held-out stream, plus the
//! "Distance" column (accuracy gap vs the FP16 target). Expected shape:
//! QAT-2bit ≈ INT4 (small gap to FP16); 2-bit PTQ collapses; the small
//! dense model trails the 2-bit QAT model by a wide margin.

use angelslim::eval::{corpus_nll, task_accuracy};
use angelslim::runtime::ArtifactRegistry;
use angelslim::util::table::{f2, pct, Table};

fn main() {
    let mut reg = ArtifactRegistry::open("artifacts").expect("run `make artifacts`");
    let eval = std::fs::read("artifacts/eval_corpus.bin").unwrap();

    let rows = [
        ("HY-target-FP32 (1x)", "model_target_fp32_b1"),
        ("HY-small-FP32 (0.25x dense)", "model_small_fp32_b1"),
        ("HY-target-INT4 (PTQ)", "model_target_int4_b1"),
        ("HY-target-2Bit (SEQ QAT)", "model_target_seq2qat_b1"),
        ("HY-target-2Bit (PTQ, no QAT)", "model_target_seq2_b1"),
    ];

    let mut results = Vec::new();
    for (label, name) in rows {
        let exe = reg.model(name).unwrap();
        let nll = corpus_nll(&exe, &eval, 48, 24).unwrap();
        let acc = task_accuracy(&exe, &eval, 48, 24).unwrap();
        results.push((label, nll, acc));
    }
    let fp32_ppl = results[0].1.exp();

    let mut t = Table::new(
        "Table 1 analogue: accuracy across precisions (held-out stream)",
        &["model", "NLL", "PPL", "next-token acc", "PPL distance vs FP32"],
    );
    for (label, nll, acc) in &results {
        t.row_strs(&[
            label,
            &f2(*nll),
            &f2(nll.exp()),
            &pct(*acc),
            &format!("{:+.2}%", (fp32_ppl / nll.exp() - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!(
        "paper shape: QAT-2bit within a few points of FP16 and ~on par with \
         INT4; small dense model far behind; PTQ-2bit collapses (the paper's \
         motivation for QAT)."
    );
}
