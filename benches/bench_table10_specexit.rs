//! Table 10 reproduction: SpecExit vs Vanilla vs EAGLE3 — accuracy proxy,
//! generated tokens, and end-to-end latency.
//!
//! Expected shape: SpecExit cuts generated tokens ~40-66% and latency up
//! to ~2x vs EAGLE3 while the quality proxy (mean target log-prob of the
//! emitted continuation) stays close.

use angelslim::runtime::ArtifactRegistry;
use angelslim::spec_decode::spec_exit::SpecExitDecoder;
use angelslim::spec_decode::{
    LogitsModel, SpecDecoder, SpecExitController, VanillaDecoder,
};
use angelslim::tensor::ops::log_softmax;
use angelslim::util::table::{f1, f2, Table};
use angelslim::util::Rng;

/// Quality proxy: mean log-prob the TARGET assigns to the emitted tokens
/// (higher = more on-distribution continuation).
fn quality<M: LogitsModel>(target: &M, prompt_len: usize, seq: &[u8]) -> f64 {
    let rows = target.seq_logits(seq).unwrap();
    let mut total = 0.0f64;
    let mut n = 0usize;
    for p in prompt_len.saturating_sub(1)..seq.len() - 1 {
        let lp = log_softmax(&rows[p]);
        total += lp[seq[p + 1] as usize] as f64;
        n += 1;
    }
    total / n.max(1) as f64
}

fn main() {
    let mut reg = ArtifactRegistry::open("artifacts").expect("run `make artifacts`");
    let target = reg.model("model_target_fp32_b1").unwrap();
    let draft = reg.model("model_draft_fp32_b1").unwrap();
    let eval = std::fs::read("artifacts/eval_corpus.bin").unwrap();

    let n_prompts = 8;
    let max_new = 48;
    let mut rows: Vec<(&str, f64, f64, f64)> = Vec::new(); // (name, qual, tok, lat_ms)

    for method in ["Vanilla", "EAGLE3", "SpecExit"] {
        let mut rng = Rng::new(2);
        let mut tok = 0usize;
        let mut lat = 0.0f64;
        let mut qual = 0.0f64;
        for p in 0..n_prompts {
            let start = 500 + p * 131;
            let prompt = &eval[start..start + 12];
            let (seq, stats) = match method {
                "Vanilla" => VanillaDecoder::new(&target)
                    .generate(prompt, max_new, &mut rng)
                    .unwrap(),
                "EAGLE3" => SpecDecoder::new(&draft, &target, 3)
                    .generate(prompt, max_new, &mut rng)
                    .unwrap(),
                _ => {
                    let ctl = SpecExitController::new(0.55, 10, 2);
                    let mut d = SpecExitDecoder::new(&draft, &target, 3, ctl);
                    let (seq, stats, _exited) =
                        d.generate(prompt, max_new, &mut rng).unwrap();
                    (seq, stats)
                }
            };
            tok += stats.generated;
            lat += stats.wall_s * 1e3;
            qual += quality(&target, prompt.len(), &seq);
        }
        rows.push((method, qual / n_prompts as f64, tok as f64 / n_prompts as f64, lat / n_prompts as f64));
    }

    let mut t = Table::new(
        "Table 10 analogue: SpecExit early-exit (per-prompt means)",
        &["method", "quality (mean logp)", "tokens", "latency ms", "tok vs EAGLE3", "lat vs EAGLE3"],
    );
    let eagle = rows[1];
    for (name, q, tok, lat) in &rows {
        t.row_strs(&[
            name,
            &f2(*q),
            &f1(*tok),
            &f2(*lat),
            &format!("{:+.0}%", 100.0 * (tok / eagle.2 - 1.0)),
            &format!("{:+.0}%", 100.0 * (lat / eagle.3 - 1.0)),
        ]);
    }
    t.print();
    println!(
        "paper shape: SpecExit prunes redundant continuation (fewer tokens, \
         lower latency) at near-equal quality; EAGLE3 keeps full length."
    );
}
