//! Fault-tolerance benchmark: goodput and virtual throughput of the
//! work-stealing `WorkerPool` under a fixed, seeded chaos profile (step
//! errors, poisoned logits, stalls, one scheduled worker crash) versus a
//! fault-free baseline on the same bursty trace — hermetic fixture model,
//! so it runs on a clean checkout and in CI smoke mode.
//!
//! Step-error and NaN draws are keyed per (request, attempt, round), so
//! with a fixed seed the set of injected request faults — and therefore
//! goodput — is reproducible run to run; stalls and the crash perturb the
//! virtual timeline and which worker hosts what, which is exactly the
//! re-admission machinery this bench is gating.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) carrying per-outcome counts, and enforces the goodput
//! floor: with bounded retry absorbing the injected faults, at least
//! three quarters of the trace must still complete (asserted).
//!
//!     cargo bench --bench bench_faults            # full run
//!     cargo bench --bench bench_faults -- --quick # CI smoke mode

use angelslim::data::RequestGen;
use angelslim::models::Transformer;
use angelslim::server::{FaultPlan, ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::assert_terminal_outcomes;

const WORKERS: usize = 2;
const MAX_IN_FLIGHT: usize = 4; // per worker
const SHORT_NEW: usize = 4;
const LONG_NEW: usize = 24;
const MAX_RETRIES: usize = 4;
/// Goodput floor under the chaos profile: completed / submitted.
const MIN_GOODPUT_FRAC: f64 = 0.75;

fn trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<angelslim::data::TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    gen.take_bursty(bursts, per_burst, 0.05, SHORT_NEW, LONG_NEW)
}

fn run(corpus: &[u8], bursts: usize, per_burst: usize, cfg: &ServeCfg) -> ServeReport {
    let model = fixture_target(3);
    ServingEngine::serve_scheduled::<Transformer, _>(
        trace(corpus, bursts, per_burst),
        &model,
        None,
        cfg,
        0,
    )
    .expect("fault-tolerant serve must contain faults, not abort the pool")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bursts, per_burst) = if quick { (3, 8) } else { (6, 8) };
    let n = bursts * per_burst;

    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);

    let base_cfg = ServeCfg::continuous(MAX_IN_FLIGHT).with_workers(WORKERS);
    let baseline = run(&corpus, bursts, per_burst, &base_cfg);
    assert_terminal_outcomes(&baseline, n, 0);
    assert_eq!(baseline.goodput(), n, "fault-free baseline completes everything");

    // per-round rates are deliberately gentle: the gate is that bounded
    // retry *recovers* from chaos, not that chaos is survivable at any rate
    let plan = FaultPlan::default()
        .seeded(1234)
        .with_step_errors(0.02)
        .with_nan(0.01)
        .with_stalls(0.1, 0.2)
        .with_crash(1, 0.0); // worker 1 dies on its first round
    let chaos_cfg = base_cfg
        .clone()
        .with_deadline(60_000.0) // generous: exercised, never binding here
        .with_retries(MAX_RETRIES)
        .with_backoff(0.25)
        .with_faults(plan);
    let chaos = run(&corpus, bursts, per_burst, &chaos_cfg);
    assert_terminal_outcomes(&chaos, n, 0);

    let counts = chaos.outcome_counts();
    let floor = (n as f64 * MIN_GOODPUT_FRAC).ceil() as usize;
    assert!(
        chaos.goodput() >= floor,
        "goodput under chaos must stay >= {floor}/{n} (got {}): retry/re-admission \
         is not absorbing the injected faults",
        chaos.goodput()
    );
    assert_eq!(
        chaos.crashed_workers.len(),
        1,
        "the scheduled crash of worker 1 must fire and be logged"
    );

    let mut table = Table::new(
        "fault-tolerant serving: goodput under chaos (fixture model, bursty trace)",
        &[
            "scenario",
            "goodput",
            "failed",
            "deadline",
            "shed",
            "retried",
            "crashed",
            "tok/s (virtual)",
            "makespan ms",
        ],
    );
    for (name, r) in [("fault-free", &baseline), ("chaos", &chaos)] {
        let c = r.outcome_counts();
        table.row_strs(&[
            name,
            &format!("{}/{n}", r.goodput()),
            &c.failed.to_string(),
            &c.deadline_exceeded.to_string(),
            &c.shed.to_string(),
            &r.retried().to_string(),
            &r.crashed_workers.len().to_string(),
            &f2(r.virtual_tps()),
            &f2(r.makespan_ms),
        ]);
    }
    table.print();

    println!(
        "BENCH_JSON {{\"bench\":\"fault_serve\",\"n_requests\":{n},\"workers\":{WORKERS},\
         \"max_retries\":{MAX_RETRIES},\
         \"baseline_tps\":{:.2},\"chaos_tps\":{:.2},\
         \"goodput\":{},\"failed\":{},\"deadline_exceeded\":{},\"shed\":{},\
         \"retried\":{},\"crashed_workers\":{},\"goodput_floor\":{floor},\
         \"quick\":{quick}}}",
        baseline.virtual_tps(),
        chaos.virtual_tps(),
        chaos.goodput(),
        counts.failed,
        counts.deadline_exceeded,
        counts.shed,
        chaos.retried(),
        chaos.crashed_workers.len(),
    );
    println!(
        "shape: every request reaches exactly one terminal outcome; goodput stays \
         >= {MIN_GOODPUT_FRAC} of the trace under seeded chaos; the crashed worker's \
         load re-enters the queue and finishes on the survivor."
    );
}
