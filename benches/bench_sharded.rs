//! Sharded-serving benchmark: the work-stealing `WorkerPool` at 1 / 2 / 4
//! workers on a bursty trace of mixed short/long generations over the
//! hermetic fixture model — no artifacts required, so it runs on a clean
//! checkout and in CI smoke mode.
//!
//! Throughput is reported on the **virtual clock** (`virtual_tps`: total
//! tokens over the schedule makespan). The pool executes workers' decode
//! rounds one at a time and models them as parallel replicas on the
//! shared virtual timeline — the same time model TTFT uses — so the
//! virtual number is the one that scales with `workers`, while real wall
//! time (`tps`) measures the simulation itself and stays flat.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) so the perf trajectory gains a sharded-throughput
//! series next to `bench_continuous` / `bench_decode_kv`.
//!
//!     cargo bench --bench bench_sharded            # full run
//!     cargo bench --bench bench_sharded -- --quick # CI smoke mode
//!
//! Expected shape: per-request outputs bit-identical across worker
//! counts; ≥ 1.5x virtual tokens/sec at 4 workers vs 1 (asserted);
//! p50/p99 TTFT no worse as workers grow.

use angelslim::data::RequestGen;
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::{assert_outputs_match, assert_serving_contracts, retry_timing};

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
const MAX_IN_FLIGHT: usize = 4; // per worker
const SHORT_NEW: usize = 4;
const LONG_NEW: usize = 24;
const MIN_SPEEDUP_W4: f64 = 1.5;

fn trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<angelslim::data::TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    // bursts land nearly simultaneously, so the shared queue is deep and
    // extra workers have real stealing to do
    gen.take_bursty(bursts, per_burst, 0.05, SHORT_NEW, LONG_NEW)
}

fn run(corpus: &[u8], bursts: usize, per_burst: usize, workers: usize) -> ServeReport {
    let model = fixture_target(3);
    ServingEngine::serve_scheduled::<Transformer, _>(
        trace(corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_IN_FLIGHT).with_workers(workers),
        0,
    )
    .expect("sharded serve")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bursts, per_burst) = if quick { (3, 8) } else { (6, 8) };
    let n = bursts * per_burst;

    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);

    // retry_timing: declare a scaling regression only after several skewed runs
    let reports: Vec<ServeReport> = retry_timing(5, || {
        let reports: Vec<ServeReport> = WORKER_COUNTS
            .iter()
            .map(|&w| run(&corpus, bursts, per_burst, w))
            .collect();
        for (r, &w) in reports.iter().zip(&WORKER_COUNTS) {
            assert_serving_contracts(r, n, 0);
            assert_eq!(r.workers(), w);
            assert_outputs_match(&reports[0], r, &format!("workers={w} vs workers=1"));
        }
        let speedup = reports[2].virtual_tps() / reports[0].virtual_tps().max(1e-12);
        if speedup >= MIN_SPEEDUP_W4 {
            Ok(reports)
        } else {
            Err(format!(
                "4 workers must deliver >= {MIN_SPEEDUP_W4}x virtual tokens/sec \
                 over 1 (got {speedup:.2}x)"
            ))
        }
    });
    let speedup = reports[2].virtual_tps() / reports[0].virtual_tps().max(1e-12);

    let mut table = Table::new(
        "sharded serving: work-stealing pool (fixture model, bursty trace)",
        &[
            "workers",
            "tok/s (virtual)",
            "TTFT mean ms",
            "TTFT p50 ms",
            "TTFT p99 ms",
            "makespan ms",
        ],
    );
    for (r, &w) in reports.iter().zip(&WORKER_COUNTS) {
        let ttft = r.ttft_summary();
        table.row_strs(&[
            &w.to_string(),
            &f2(r.virtual_tps()),
            &f2(ttft.mean),
            &f2(ttft.p50),
            &f2(ttft.p99),
            &f2(r.makespan_ms),
        ]);
    }
    table.print();

    let j = |r: &ServeReport| {
        let ttft = r.ttft_summary();
        format!(
            "\"tps\":{:.2},\"ttft_mean_ms\":{:.3},\"ttft_p50_ms\":{:.3},\"ttft_p99_ms\":{:.3},\
             \"makespan_ms\":{:.3},\"peak_kv_bytes\":{},\
             \"mean_in_flight\":{:.3},\"peak_in_flight\":{}",
            r.virtual_tps(),
            ttft.mean,
            ttft.p50,
            ttft.p99,
            r.makespan_ms,
            r.peak_kv_bytes,
            r.mean_in_flight,
            r.peak_in_flight,
        )
    };
    println!(
        "BENCH_JSON {{\"bench\":\"sharded_serve\",\"n_requests\":{n},\
         \"max_in_flight\":{MAX_IN_FLIGHT},\
         \"w1\":{{{}}},\"w2\":{{{}}},\"w4\":{{{}}},\
         \"speedup_w4_vs_w1\":{speedup:.3},\"quick\":{quick}}}",
        j(&reports[0]),
        j(&reports[1]),
        j(&reports[2]),
    );
    println!(
        "shape: outputs bit-identical across 1/2/4 workers; virtual tokens/sec \
         scales with workers (>= {MIN_SPEEDUP_W4}x at 4); TTFT percentiles shrink \
         as the shared queue drains in parallel."
    );
}
