//! Sharded-serving benchmark: the work-stealing `WorkerPool` at 1 / 2 / 4
//! workers on a bursty trace of mixed short/long generations over the
//! hermetic fixture model — no artifacts required, so it runs on a clean
//! checkout and in CI smoke mode.
//!
//! Throughput is reported twice:
//!
//! - **virtual clock** (`virtual_tps`: total tokens over the schedule
//!   makespan) from the single-thread twin — the pool executes workers'
//!   decode rounds one at a time and models them as parallel replicas on
//!   the shared virtual timeline, so this number scales with `workers`
//!   on any machine;
//! - **wall clock** (`tps`: total tokens over real elapsed seconds) from
//!   the OS-thread pool (`serve.threads`) at 1/2/4 threads — the number
//!   that only real cores can move. The ≥1.5x scaling gate at 4 threads
//!   is asserted only when the machine has ≥4 cores (median-of-N with
//!   bounded retries); on smaller machines the numbers are still
//!   reported and the outputs still checked against the twin.
//!
//! Prints a human table plus one machine-readable JSON line (prefix
//! `BENCH_JSON `) so the perf trajectory gains a sharded-throughput
//! series next to `bench_continuous` / `bench_decode_kv`.
//!
//!     cargo bench --bench bench_sharded            # full run
//!     cargo bench --bench bench_sharded -- --quick # CI smoke mode
//!
//! Expected shape: per-request outputs bit-identical across worker
//! counts AND across virtual/threaded modes; ≥ 1.5x virtual tokens/sec
//! at 4 workers vs 1 (asserted); ≥ 1.5x wall tokens/sec at 4 threads
//! (asserted on ≥4-core machines); p50/p99 TTFT no worse as workers
//! grow.

use angelslim::data::RequestGen;
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::median_of;
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::{assert_outputs_match, assert_serving_contracts, retry_timing};

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
const MAX_IN_FLIGHT: usize = 4; // per worker
const SHORT_NEW: usize = 4;
const LONG_NEW: usize = 24;
const MIN_SPEEDUP_W4: f64 = 1.5;

fn trace(corpus: &[u8], bursts: usize, per_burst: usize) -> Vec<angelslim::data::TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), 42);
    gen.prompt_len = 8;
    // bursts land nearly simultaneously, so the shared queue is deep and
    // extra workers have real stealing to do
    gen.take_bursty(bursts, per_burst, 0.05, SHORT_NEW, LONG_NEW)
}

fn run(
    corpus: &[u8],
    bursts: usize,
    per_burst: usize,
    workers: usize,
    threads: bool,
) -> ServeReport {
    let model = fixture_target(3);
    ServingEngine::serve_scheduled::<Transformer, _>(
        trace(corpus, bursts, per_burst),
        &model,
        None,
        &ServeCfg::continuous(MAX_IN_FLIGHT)
            .with_workers(workers)
            .with_threads(threads),
        0,
    )
    .expect("sharded serve")
}

/// Wall-clock tokens/sec of the OS-thread pool at each worker count:
/// median-of-3 runs per count (one noisy draw on a loaded machine must
/// not decide the scaling gate), keeping the last report for the
/// output-identity checks.
fn measure_wall(corpus: &[u8], bursts: usize, per_burst: usize) -> (Vec<f64>, Vec<ServeReport>) {
    let mut tps = Vec::new();
    let mut reports = Vec::new();
    for &w in &WORKER_COUNTS {
        let mut last = None;
        let t = median_of(3, || {
            let r = run(corpus, bursts, per_burst, w, true);
            let t = r.tps();
            last = Some(r);
            t
        });
        tps.push(t);
        reports.push(last.expect("median_of runs the closure at least once"));
    }
    (tps, reports)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bursts, per_burst) = if quick { (3, 8) } else { (6, 8) };
    let n = bursts * per_burst;

    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);

    // retry_timing: declare a scaling regression only after several skewed runs
    let reports: Vec<ServeReport> = retry_timing(5, || {
        let reports: Vec<ServeReport> = WORKER_COUNTS
            .iter()
            .map(|&w| run(&corpus, bursts, per_burst, w, false))
            .collect();
        for (r, &w) in reports.iter().zip(&WORKER_COUNTS) {
            assert_serving_contracts(r, n, 0);
            assert_eq!(r.workers(), w);
            assert_outputs_match(&reports[0], r, &format!("workers={w} vs workers=1"));
        }
        let speedup = reports[2].virtual_tps() / reports[0].virtual_tps().max(1e-12);
        if speedup >= MIN_SPEEDUP_W4 {
            Ok(reports)
        } else {
            Err(format!(
                "4 workers must deliver >= {MIN_SPEEDUP_W4}x virtual tokens/sec \
                 over 1 (got {speedup:.2}x)"
            ))
        }
    });
    let speedup = reports[2].virtual_tps() / reports[0].virtual_tps().max(1e-12);

    // ── wall-clock section: the same pool on real OS threads ─────────
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let gate_wall = cores >= 4;
    let (wall_tps, wall_reports) = if gate_wall {
        retry_timing(5, || {
            let (tps, reps) = measure_wall(&corpus, bursts, per_burst);
            let s = tps[2] / tps[0].max(1e-12);
            if s >= MIN_SPEEDUP_W4 {
                Ok((tps, reps))
            } else {
                Err(format!(
                    "4 OS threads must deliver >= {MIN_SPEEDUP_W4}x wall-clock \
                     tokens/sec over 1 on a >=4-core machine (got {s:.2}x on \
                     {cores} cores)"
                ))
            }
        })
    } else {
        eprintln!(
            "SKIP: wall-clock scaling gate needs >= 4 cores, machine has {cores}; \
             reporting threaded numbers without asserting the speedup"
        );
        measure_wall(&corpus, bursts, per_burst)
    };
    // correctness is never hardware-gated: threaded outputs and terminal
    // outcomes must match the virtual-clock twin on any machine
    for (r, &w) in wall_reports.iter().zip(&WORKER_COUNTS) {
        assert_serving_contracts(r, n, 0);
        assert_eq!(r.workers(), w);
        assert_outputs_match(
            &reports[0],
            r,
            &format!("threads={w} vs single-thread twin"),
        );
    }
    let wall_speedup = wall_tps[2] / wall_tps[0].max(1e-12);

    let mut table = Table::new(
        "sharded serving: work-stealing pool (fixture model, bursty trace)",
        &[
            "workers",
            "tok/s (virtual)",
            "tok/s (wall, threaded)",
            "TTFT mean ms",
            "TTFT p50 ms",
            "TTFT p99 ms",
            "makespan ms",
        ],
    );
    for (i, (r, &w)) in reports.iter().zip(&WORKER_COUNTS).enumerate() {
        let ttft = r.ttft_summary();
        table.row_strs(&[
            &w.to_string(),
            &f2(r.virtual_tps()),
            &f2(wall_tps[i]),
            &f2(ttft.mean),
            &f2(ttft.p50),
            &f2(ttft.p99),
            &f2(r.makespan_ms),
        ]);
    }
    table.print();

    let j = |r: &ServeReport| {
        let ttft = r.ttft_summary();
        format!(
            "\"tps\":{:.2},\"ttft_mean_ms\":{:.3},\"ttft_p50_ms\":{:.3},\"ttft_p99_ms\":{:.3},\
             \"makespan_ms\":{:.3},\"peak_kv_bytes\":{},\
             \"mean_in_flight\":{:.3},\"peak_in_flight\":{}",
            r.virtual_tps(),
            ttft.mean,
            ttft.p50,
            ttft.p99,
            r.makespan_ms,
            r.peak_kv_bytes,
            r.mean_in_flight,
            r.peak_in_flight,
        )
    };
    println!(
        "BENCH_JSON {{\"bench\":\"sharded_serve\",\"n_requests\":{n},\
         \"max_in_flight\":{MAX_IN_FLIGHT},\
         \"w1\":{{{}}},\"w2\":{{{}}},\"w4\":{{{}}},\
         \"speedup_w4_vs_w1\":{speedup:.3},\
         \"wall\":{{\"t1_tps\":{:.2},\"t2_tps\":{:.2},\"t4_tps\":{:.2},\
         \"speedup_t4_vs_t1\":{wall_speedup:.3},\"cores\":{cores},\
         \"gated\":{gate_wall}}},\"quick\":{quick}}}",
        j(&reports[0]),
        j(&reports[1]),
        j(&reports[2]),
        wall_tps[0],
        wall_tps[1],
        wall_tps[2],
    );
    println!(
        "shape: outputs bit-identical across 1/2/4 workers and across \
         virtual/threaded modes; virtual tokens/sec scales with workers \
         (>= {MIN_SPEEDUP_W4}x at 4); wall tokens/sec scales with OS threads \
         (>= {MIN_SPEEDUP_W4}x at 4 on >= 4-core machines); TTFT percentiles \
         shrink as the shared queue drains in parallel."
    );
}
