//! Decode-throughput benchmark: KV-cached incremental decoding
//! (`prefill` + `decode_step`) vs re-forwarding the full prefix per token,
//! on the hermetic fixture transformer — no artifacts required, so it runs
//! on a clean checkout and in CI smoke mode.
//!
//! Prints a human table plus one machine-readable JSON line per
//! configuration (prefix `BENCH_JSON `) so `BENCH_*.json` perf-trajectory
//! tracking can diff tokens/sec across PRs.
//!
//!     cargo bench --bench bench_decode_kv            # full run
//!     cargo bench --bench bench_decode_kv -- --quick # CI smoke mode
//!
//! Expected shape: cached decode ≥ 5x uncached tokens/sec at seq ≥ 64
//! (the gap widens with sequence length: O(T²) total vs O(T³)).

use angelslim::models::transformer::Layer;
use angelslim::models::{AttnOverride, Transformer, TransformerCfg};
use angelslim::quant::packing::PackFormat;
use angelslim::tensor::ops::argmax;
use angelslim::tensor::Tensor;
use angelslim::util::fixtures::{fixture_corpus, fixture_transformer, FixtureSpec};
use angelslim::util::table::{f2, Table};
use angelslim::util::testing::retry_timing;
use angelslim::util::{median_of, Rng, Selector};
use std::time::Instant;

/// Fixture spec with room for long sequences (default max_t is 48).
fn bench_spec(max_t: usize) -> FixtureSpec {
    FixtureSpec { max_t, ..FixtureSpec::default() }
}

struct Run {
    seq: Vec<u8>,
    prefill_s: f64,
    decode_s: f64,
}

/// The pre-KV-cache loop: one full forward over the whole prefix per
/// generated token (next_logits already projects only the last row, so
/// this measures the layer stack, not the head).
fn uncached_generate(model: &Transformer, prompt: &[u8], max_new: usize) -> Run {
    let mut seq = prompt.to_vec();
    let t0 = Instant::now();
    let mut last = model.next_logits(&seq, &AttnOverride::None);
    let prefill_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for step in 0..max_new {
        let next = argmax(&last) as u8;
        seq.push(next);
        if step + 1 < max_new {
            last = model.next_logits(&seq, &AttnOverride::None);
        }
    }
    Run { seq, prefill_s, decode_s: t1.elapsed().as_secs_f64() }
}

/// The KV-cached loop: one prefill over the prompt, one decode step per
/// generated token.
fn cached_generate(model: &Transformer, prompt: &[u8], max_new: usize) -> Run {
    let mut seq = prompt.to_vec();
    let mut cache = model.new_cache();
    let t0 = Instant::now();
    let rows = model.prefill(&mut cache, prompt);
    let mut last = rows.row(rows.rows() - 1).to_vec();
    let prefill_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for step in 0..max_new {
        let next = argmax(&last) as u8;
        seq.push(next);
        if step + 1 < max_new {
            last = model.decode_step(&mut cache, next);
        }
    }
    Run { seq, prefill_s, decode_s: t1.elapsed().as_secs_f64() }
}

/// A serving-width model whose f32 weights (~537 MiB) stream from DRAM
/// every decode step, while the packed formats (int4 ~75 MiB, ternary
/// 2-bit ~34 MiB) stay cache-resident — the bandwidth regime where packed
/// GEMV kernels pay off. Random weights: this measures kernels, not the
/// fixture rule.
fn bench_packed_model(max_t: usize) -> Transformer {
    let (v, d, d_ff, n_layers) = (256, 1024, 4096, 8);
    let mut rng = Rng::new(0xBE9C_0DE5);
    let w = 0.02;
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        layers.push(Layer {
            ln1: vec![1.0; d],
            wq: Tensor::randn(&[d, d], w, &mut rng).into(),
            wk: Tensor::randn(&[d, d], w, &mut rng).into(),
            wv: Tensor::randn(&[d, d], w, &mut rng).into(),
            wo: Tensor::randn(&[d, d], w, &mut rng).into(),
            ln2: vec![1.0; d],
            w_gate: Tensor::randn(&[d_ff, d], w, &mut rng).into(),
            w_up: Tensor::randn(&[d_ff, d], w, &mut rng).into(),
            w_down: Tensor::randn(&[d, d_ff], w, &mut rng).into(),
        });
    }
    Transformer {
        cfg: TransformerCfg { vocab: v, d_model: d, n_layers, n_heads: 8, d_ff, max_t },
        embed: Tensor::randn(&[v, d], w, &mut rng),
        pos: Tensor::randn(&[max_t, d], w * 0.5, &mut rng),
        layers,
        ln_f: vec![1.0; d],
        head: Tensor::randn(&[v, d], w, &mut rng).into(),
    }
}

/// Greedy KV-cached decode throughput (tokens/sec), prefill excluded.
fn decode_tps(model: &Transformer, prompt: &[u8], new_toks: usize) -> f64 {
    let mut cache = model.new_cache();
    let rows = model.prefill(&mut cache, prompt);
    let mut last = rows.row(rows.rows() - 1).to_vec();
    let t0 = Instant::now();
    for _ in 0..new_toks {
        let next = argmax(&last) as u8;
        last = model.decode_step(&mut cache, next);
    }
    new_toks as f64 / t0.elapsed().as_secs_f64()
}

/// Packed-vs-f32 decode on the serving-width model: the quantized
/// execution path must deliver at least f32 tokens/sec on the int4 and
/// ternary (2-bit container) fixtures — the tentpole's perf contract.
fn run_packed_section(quick: bool) {
    let new_toks = if quick { 6 } else { 24 };
    let prompt: Vec<u8> = (0..8u8).collect();
    let dense = bench_packed_model(prompt.len() + new_toks + 8);
    let mib = |bytes: usize| bytes as f64 / (1024.0 * 1024.0);
    let dense_mib = mib(dense.stored_weight_bytes());

    let mut table = Table::new(
        "packed quantized decode vs f32 (d_model=1024, 8 layers, KV-cached)",
        &["format", "stored MiB", "f32 tok/s", "packed tok/s", "speedup"],
    );
    for fmt in [PackFormat::Int4, PackFormat::TwoBit] {
        let label = fmt.name();
        let mut packed = dense.clone();
        let n = packed
            .pack_weights(&Selector::all(), fmt, 32)
            .expect("bench dims admit every pack format");
        assert_eq!(n, dense.named_weights().len(), "bench packs every linear");
        let stored_mib = mib(packed.stored_weight_bytes());

        // median-of-3 inside bounded retries: the assertion compares two
        // wall-clock measurements on a shared machine, so a single
        // preemption can invert one draw; the median absorbs it and the
        // retry loop covers sustained load
        let (f32_tps, packed_tps) = retry_timing(5, || {
            let f = median_of(3, || decode_tps(&dense, &prompt, new_toks));
            let p = median_of(3, || decode_tps(&packed, &prompt, new_toks));
            if p >= f {
                Ok((f, p))
            } else {
                Err(format!("{label}: packed {p:.2} tok/s below f32 {f:.2}"))
            }
        });
        let speedup = packed_tps / f32_tps;
        table.row_strs(&[
            label,
            &format!("{stored_mib:.1}"),
            &f2(f32_tps),
            &f2(packed_tps),
            &format!("{speedup:.2}x"),
        ]);
        println!(
            "BENCH_JSON {{\"bench\":\"decode_kv_packed\",\"format\":\"{label}\",\
             \"decode_t\":{new_toks},\"f32_mib\":{dense_mib:.1},\"stored_mib\":{stored_mib:.1},\
             \"f32_tps\":{f32_tps:.2},\"packed_tps\":{packed_tps:.2},\"speedup\":{speedup:.3},\
             \"quick\":{quick}}}"
        );
    }
    table.print();
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 1 } else { 5 };
    let configs: &[(usize, usize)] = if quick {
        &[(64, 64)] // (prompt_t, decode_t): seq 128 ≥ the 64-token floor
    } else {
        &[(32, 32), (64, 64), (64, 128)]
    };

    let mut table = Table::new(
        "KV-cached incremental decoding vs full re-forward (fixture model)",
        &["prompt", "new", "uncached tok/s", "cached tok/s", "speedup", "cache KiB"],
    );

    for &(prompt_t, decode_t) in configs {
        let max_t = prompt_t + decode_t + 8;
        let spec = bench_spec(max_t);
        let model = fixture_transformer(&spec);
        let corpus = fixture_corpus(&spec, prompt_t + 16, 3);
        let prompt = &corpus[..prompt_t];

        let mut unc_decode = 0.0;
        let mut unc_prefill = 0.0;
        let mut cac_decode = 0.0;
        let mut cac_prefill = 0.0;
        let mut cache_bytes = 0usize;
        for _ in 0..reps {
            let u = uncached_generate(&model, prompt, decode_t);
            let c = cached_generate(&model, prompt, decode_t);
            assert_eq!(
                u.seq, c.seq,
                "cached decode must be output-identical to the full re-forward"
            );
            unc_decode += u.decode_s;
            unc_prefill += u.prefill_s;
            cac_decode += c.decode_s;
            cac_prefill += c.prefill_s;
            let mut cache = model.new_cache();
            model.prefill(&mut cache, &c.seq[..c.seq.len().min(max_t)]);
            cache_bytes = cache.bytes();
        }
        let n_tok = (decode_t * reps) as f64;
        let uncached_tps = n_tok / unc_decode;
        let cached_tps = n_tok / cac_decode;
        let speedup = cached_tps / uncached_tps;

        table.row_strs(&[
            &prompt_t.to_string(),
            &decode_t.to_string(),
            &f2(uncached_tps),
            &f2(cached_tps),
            &format!("{speedup:.2}x"),
            &format!("{:.1}", cache_bytes as f64 / 1024.0),
        ]);
        // machine-readable perf line (one JSON object per configuration)
        println!(
            "BENCH_JSON {{\"bench\":\"decode_kv\",\"prompt_t\":{prompt_t},\"decode_t\":{decode_t},\
             \"reps\":{reps},\"uncached_tps\":{uncached_tps:.2},\"cached_tps\":{cached_tps:.2},\
             \"speedup\":{speedup:.3},\"uncached_prefill_ms\":{:.3},\"cached_prefill_ms\":{:.3},\
             \"cache_bytes\":{cache_bytes},\"quick\":{quick}}}",
            unc_prefill * 1e3 / reps as f64,
            cac_prefill * 1e3 / reps as f64,
        );
    }
    table.print();
    println!(
        "shape: cached decode ≥ 5x at seq ≥ 64 and growing with T; \
         outputs bit-identical to the uncached path."
    );

    run_packed_section(quick);
    println!(
        "shape: packed decode ≥ 1x f32 tokens/sec on int4 and the ternary \
         2-bit container (f32 streams ~537 MiB/token; packed stays cache-resident)."
    );
}
