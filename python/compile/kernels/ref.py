"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: python/tests compares each Pallas
kernel (interpret=True) against these functions with assert_allclose across
a hypothesis-driven sweep of shapes and dtypes.

Quantized-weight layout conventions (shared with the Rust side, see
rust/src/quant/packing.rs):

* int4 group-wise: codes in [0, 15], zero-point 8, scale per (out_channel,
  group) with group size G along the reduction axis.  Packed two codes per
  uint8: low nibble = even index, high nibble = odd index.
* SEQ 2-bit   : codes in [0, 3] mapping to symmetric levels
  {-1.5, -0.5, +0.5, +1.5} = (2*code - 3) / 2, scale per (out_channel, group).
  Packed four codes per uint8, little-endian 2-bit fields.
* ternary     : codes in {0, 1, 2} mapping to {-1, 0, +1} = code - 1,
  per-out-channel scale alpha.  Packed four 2-bit fields per uint8 (the
  1.58-bit entropy packing lives on the Rust side; HLO interchange uses the
  SIMD-friendly 2-bit fields).
* fp8 QDQ     : weights and activations round-tripped through float8_e4m3fn
  with a per-tensor scale (absmax / 448).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# packing helpers (numpy, build-time only)
# --------------------------------------------------------------------------


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """Pack int4 codes [N, K] (values 0..15) into uint8 [N, K//2]."""
    assert codes.shape[-1] % 2 == 0
    lo = codes[..., 0::2].astype(np.uint8)
    hi = codes[..., 1::2].astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_nibbles, jnp: uint8 [N, K//2] -> int32 [N, K]."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def pack_crumbs(codes: np.ndarray) -> np.ndarray:
    """Pack 2-bit codes [N, K] (values 0..3) into uint8 [N, K//4]."""
    assert codes.shape[-1] % 4 == 0
    c = codes.reshape(*codes.shape[:-1], -1, 4).astype(np.uint8)
    return (c[..., 0] | (c[..., 1] << 2) | (c[..., 2] << 4) | (c[..., 3] << 6)).astype(
        np.uint8
    )


def unpack_crumbs(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_crumbs, jnp: uint8 [N, K//4] -> int32 [N, K]."""
    parts = [((packed >> (2 * i)) & 0x3).astype(jnp.int32) for i in range(4)]
    return jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1], -1)


# --------------------------------------------------------------------------
# quantizers (numpy, build-time: produce codes + scales from fp weights)
# --------------------------------------------------------------------------


def quantize_int4(w: np.ndarray, group: int = 32):
    """Group-wise symmetric-around-8 int4.  w: [N, K] -> (codes, scales)."""
    n, k = w.shape
    assert k % group == 0
    wg = w.reshape(n, k // group, group)
    absmax = np.abs(wg).max(axis=-1, keepdims=True)
    scale = np.where(absmax == 0, 1.0, absmax / 7.0)
    codes = np.clip(np.round(wg / scale) + 8, 0, 15).astype(np.uint8)
    return codes.reshape(n, k), scale[..., 0].astype(np.float32)


def dequantize_int4(codes: np.ndarray, scales: np.ndarray, group: int = 32):
    n, k = codes.shape
    wg = (codes.reshape(n, k // group, group).astype(np.float32) - 8.0) * scales[
        ..., None
    ]
    return wg.reshape(n, k)


def quantize_seq2(w: np.ndarray, group: int = 32):
    """Stretched Elastic Quantization (SEQ): symmetric 2-bit levels
    {-1.5,-0.5,+0.5,+1.5} * scale, scale per (out, group).

    The paper (sec 2.1.2) eliminates the zero level and shifts the centroid;
    the absmax-compatible scale maps absmax -> 1.5*scale.
    """
    n, k = w.shape
    assert k % group == 0
    wg = w.reshape(n, k // group, group)
    absmax = np.abs(wg).max(axis=-1, keepdims=True)
    scale = np.where(absmax == 0, 1.0, absmax / 1.5)
    # levels l(code) = (2*code - 3)/2 = code - 1.5 ; nearest code = round(w/scale + 1.5)
    codes = np.clip(np.round(wg / scale + 1.5), 0, 3).astype(np.uint8)
    return codes.reshape(n, k), scale[..., 0].astype(np.float32)


def dequantize_seq2(codes: np.ndarray, scales: np.ndarray, group: int = 32):
    n, k = codes.shape
    lv = (2.0 * codes.reshape(n, k // group, group).astype(np.float32) - 3.0) / 2.0
    return (lv * scales[..., None]).reshape(n, k)


def quantize_ternary(w: np.ndarray):
    """TWN-style ternary: threshold Delta = 0.75 * mean|w| per out channel,
    alpha = mean of |w| over the kept set.  codes in {0,1,2} -> {-1,0,+1}."""
    delta = 0.75 * np.abs(w).mean(axis=1, keepdims=True)
    mask = np.abs(w) >= delta
    cnt = np.maximum(mask.sum(axis=1, keepdims=True), 1)
    alpha = (np.abs(w) * mask).sum(axis=1, keepdims=True) / cnt
    alpha = np.where(alpha == 0, 1.0, alpha)
    codes = (np.sign(w) * mask + 1).astype(np.uint8)
    return codes, alpha[:, 0].astype(np.float32)


def dequantize_ternary(codes: np.ndarray, alpha: np.ndarray):
    return (codes.astype(np.float32) - 1.0) * alpha[:, None]


FP8_E4M3_MAX = 448.0


def fp8_qdq(x: jnp.ndarray, scale=None) -> jnp.ndarray:
    """Round-trip through float8_e4m3fn with per-tensor scale."""
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / FP8_E4M3_MAX
    y = (x / scale).astype(jnp.float8_e4m3fn).astype(jnp.float32)
    return y * scale


# --------------------------------------------------------------------------
# reference computations (jnp) — what the Pallas kernels must match
# --------------------------------------------------------------------------


def ref_int4_matmul(x, packed, scales, group: int = 32):
    """x [M, K] @ dequant(packed, scales).T -> [M, N]."""
    codes = unpack_nibbles(packed)
    n, k = codes.shape
    wg = (codes.reshape(n, k // group, group).astype(jnp.float32) - 8.0) * scales[
        ..., None
    ]
    w = wg.reshape(n, k)
    return x @ w.T


def ref_seq2_matmul(x, packed, scales, group: int = 32):
    codes = unpack_crumbs(packed)
    n, k = codes.shape
    lv = (2.0 * codes.reshape(n, k // group, group).astype(jnp.float32) - 3.0) / 2.0
    w = (lv * scales[..., None]).reshape(n, k)
    return x @ w.T


def ref_ternary_matmul(x, packed, alpha):
    codes = unpack_crumbs(packed)
    w = (codes.astype(jnp.float32) - 1.0) * alpha[:, None]
    return x @ w.T


def ref_fp8_matmul(x, w):
    """QDQ both operands (per-tensor dynamic scale) then matmul."""
    return fp8_qdq(x) @ fp8_qdq(w).T


def ref_block_sparse_attn(q, k, v, block_mask, block: int):
    """Causal attention with an additional [Tq/b, Tk/b] block mask.

    q,k,v: [T, H, D].  block_mask[i, j] == True keeps the (i, j) block.
    Masked-out entries get -inf before softmax.  Fully-masked rows produce
    zeros (guarded; matches kernel behaviour).
    """
    t, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d)
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    bm = jnp.repeat(jnp.repeat(block_mask, block, axis=0), block, axis=1)[:t, :t]
    keep = causal & bm
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(keep[None], scores, neg)
    row_any = keep.any(axis=1)
    m = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - m)
    probs = jnp.where(keep[None], probs, 0.0)
    denom = jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hqk,khd->qhd", probs / denom, v)
    return jnp.where(row_any[:, None, None], out, 0.0)
