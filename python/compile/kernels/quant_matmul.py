"""Layer-1 Pallas kernels: quantized-weight matmuls.

Each kernel consumes *packed* integer codes plus scales and expands them to
float tiles inside the kernel (VMEM-resident on real hardware), so HBM
traffic is proportional to the compressed weight size — the paper's
bandwidth argument for 2-bit / ternary edge inference (§2.1.3, Table 3),
re-thought for the TPU memory hierarchy (see DESIGN.md §Hardware-Adaptation).

All kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is validated against kernels/ref.py.

Tiling: grid over (M/bm, N/bn); the reduction axis K is kept whole per tile
(K <= 512 for every model in this repo, so a [bm, K] activation tile plus a
[bn, K/pack] code tile plus the [bm, bn] output tile fit comfortably in the
~16 MiB VMEM budget of a TPU core — the footprint estimate lives in
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BM = 32
DEFAULT_BN = 32


def _expand_scales(scales, group):
    """[bn, K/group] -> [bn, K] by repeating each group scale."""
    return jnp.repeat(scales, group, axis=1)


# --------------------------------------------------------------------------
# int4 group-wise dequant matmul
# --------------------------------------------------------------------------


def _int4_kernel(x_ref, packed_ref, scales_ref, o_ref, *, group):
    x = x_ref[...]  # [bm, K] f32
    packed = packed_ref[...]  # [bn, K//2] u8
    scales = scales_ref[...]  # [bn, K//group] f32
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    w = (codes - 8.0) * _expand_scales(scales, group)  # [bn, K]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def int4_matmul(x, packed, scales, *, group=32, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """x [M, K] f32 @ dequant(packed [N, K//2] u8, scales [N, K//group]).T."""
    m, k = x.shape
    n = packed.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        functools.partial(_int4_kernel, group=group),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k // 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k // group), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scales)


# --------------------------------------------------------------------------
# SEQ 2-bit dequant matmul — levels {-1.5, -0.5, +0.5, +1.5} * scale
# --------------------------------------------------------------------------


def _seq2_kernel(x_ref, packed_ref, scales_ref, o_ref, *, group):
    x = x_ref[...]
    packed = packed_ref[...]  # [bn, K//4] u8
    scales = scales_ref[...]
    parts = [((packed >> (2 * i)) & 0x3).astype(jnp.float32) for i in range(4)]
    codes = jnp.stack(parts, axis=-1).reshape(packed.shape[0], -1)  # [bn, K]
    levels = (2.0 * codes - 3.0) * 0.5
    w = levels * _expand_scales(scales, group)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def seq2_matmul(x, packed, scales, *, group=32, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """SEQ 2-bit matmul: x [M, K] @ dequant(packed [N, K//4]).T."""
    m, k = x.shape
    n = packed.shape[0]
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        functools.partial(_seq2_kernel, group=group),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k // 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k // group), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scales)


# --------------------------------------------------------------------------
# ternary dequant matmul — codes {0,1,2} -> {-1,0,+1} * alpha[out]
# --------------------------------------------------------------------------


def _ternary_kernel(x_ref, packed_ref, alpha_ref, o_ref):
    x = x_ref[...]
    packed = packed_ref[...]  # [bn, K//4] u8
    alpha = alpha_ref[...]  # [bn] f32
    parts = [((packed >> (2 * i)) & 0x3).astype(jnp.float32) for i in range(4)]
    codes = jnp.stack(parts, axis=-1).reshape(packed.shape[0], -1)
    w = (codes - 1.0) * alpha[:, None]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def ternary_matmul(x, packed, alpha, *, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Ternary matmul: x [M, K] @ ((codes-1) * alpha[:, None]).T."""
    m, k = x.shape
    n = packed.shape[0]
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _ternary_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k // 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, alpha)


# --------------------------------------------------------------------------
# fp8 QDQ matmul — per-tensor dynamic scales (W8A8-FP8 Dynamic, §2.3.1)
# --------------------------------------------------------------------------


def _fp8_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref):
    # Scales are computed over the *whole tensor* outside the kernel (the
    # dynamic-quantization step); the kernel does the QDQ + matmul.
    x = x_ref[...]
    w = w_ref[...]
    xs = xs_ref[0]
    ws = ws_ref[0]
    xq = (x / xs).astype(jnp.float8_e4m3fn).astype(jnp.float32) * xs
    wq = (w / ws).astype(jnp.float8_e4m3fn).astype(jnp.float32) * ws
    o_ref[...] = jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def fp8_matmul(x, w, *, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """W8A8-FP8 dynamic QDQ matmul: x [M, K] @ w [N, K].T."""
    m, k = x.shape
    n = w.shape[0]
    assert m % bm == 0 and n % bn == 0
    xs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / ref.FP8_E4M3_MAX
    ws = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / ref.FP8_E4M3_MAX
    xs = xs.reshape(1)
    ws = ws.reshape(1)
    return pl.pallas_call(
        _fp8_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, xs, ws)
