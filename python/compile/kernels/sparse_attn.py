"""Layer-1 Pallas kernel: block-sparse causal prefill attention.

This is the sparse-kernel half of the paper's sparse-attention framework
(§4.1): L3 pattern algorithms (A-shape / Tri-shape / MInference / XAttention
/ FlexPrefill / Stem) produce a *block mask* as metadata; this kernel
consumes that mask and computes attention only where the mask keeps a block.

GPU-kernel -> Pallas adaptation: the paper's CUDA kernels schedule thread
blocks over (q_block, kv_block) pairs surviving the mask; here the HBM->VMEM
schedule is expressed with BlockSpec over q blocks, and masked kv blocks are
zeroed in-kernel (interpret=True executes densely on CPU; on a real TPU the
same structure lets Mosaic skip masked KV DMA — the compute-savings model is
accounted analytically in rust/src/sparse_attn/flops.rs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block, t):
    i = pl.program_id(0)
    q = q_ref[...]  # [bq, H, D]
    k = k_ref[...]  # [T, H, D]
    v = v_ref[...]  # [T, H, D]
    bmask = mask_ref[...]  # [1, T//block] f32 (1.0 keep / 0.0 drop)
    bq, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale  # [H, bq, T]

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, t), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (bq, t), 1)
    causal = q_pos >= k_pos
    keep_blocks = jnp.repeat(bmask[0] > 0.5, block)[:t]  # [T]
    keep = causal & keep_blocks[None, :]  # [bq, T]

    neg = jnp.float32(-1e30)
    scores = jnp.where(keep[None], scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(keep[None], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hqk,khd->qhd", p / denom, v)
    row_any = keep.any(axis=1)
    o_ref[...] = jnp.where(row_any[:, None, None], out, 0.0)


def block_sparse_attn(q, k, v, block_mask, *, block=16):
    """Causal block-sparse attention.

    q, k, v     : [T, H, D] f32
    block_mask  : [T//block, T//block] f32 (1.0 = keep block)
    Returns [T, H, D] f32.
    """
    t, h, d = q.shape
    nb = t // block
    assert t % block == 0 and block_mask.shape == (nb, nb)
    return pl.pallas_call(
        functools.partial(_attn_kernel, block=block, t=t),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, h, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((t, h, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, d), jnp.float32),
        interpret=True,
    )(q, k, v, block_mask)
