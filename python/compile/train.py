"""Build-time training: target LM + Eagle3-style draft distillation.

The paper's speculative-decoding framework (§3.1) trains draft models that
are *target-model-dependent*: the objective is alignment with the target's
token distribution, not standalone quality.  We reproduce that pipeline at
build time:

1. train the TARGET TinyTransformer on a synthetic structured byte corpus
   (next-token cross entropy, manual Adam — optax is not available);
2. distill the DRAFT against the frozen target with a KL(target ‖ draft)
   objective plus a hidden-state alignment term (the paper's "hidden state
   extraction from the target model" supervision signal, §3.1.3) and a small
   CE anchor.

Everything is deterministic (seeded); Python never runs at request time —
aot.py bakes the resulting weights into HLO artifacts and weights.bin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

# --------------------------------------------------------------------------
# synthetic corpus — a structured byte language (Markov backbone + templates)
# --------------------------------------------------------------------------

N_STATES = 64  # "common" symbols; bytes >= N_STATES appear only in templates
TEMPLATES = [
    bytes([65, 110, 103, 101, 108]),  # "Angel"
    bytes([83, 108, 105, 109, 33]),  # "Slim!"
    bytes([113, 117, 97, 110, 116]),  # "quant"
    bytes([115, 112, 97, 114, 115, 101]),  # "sparse"
]


def make_transition(seed: int) -> np.ndarray:
    """Sparse order-1 Markov transition: each state has 4 likely successors."""
    rng = np.random.default_rng(seed)
    trans = np.full((N_STATES, N_STATES), 0.02 / N_STATES)
    for s in range(N_STATES):
        succ = rng.choice(N_STATES, size=4, replace=False)
        probs = rng.dirichlet(np.ones(4) * 2.0) * 0.98
        trans[s, succ] += probs
    return trans / trans.sum(axis=1, keepdims=True)


def make_corpus(n_tokens: int, seed: int) -> np.ndarray:
    """Generate a deterministic byte stream: Markov walk with occasional
    verbatim template insertions (gives the LM sharp, predictable spans that
    speculative decoding can exploit — mirrors real-text redundancy)."""
    rng = np.random.default_rng(seed)
    trans = make_transition(seed=1234)  # transition structure is fixed
    out = np.empty(n_tokens, dtype=np.uint8)
    s = int(rng.integers(N_STATES))
    i = 0
    while i < n_tokens:
        if rng.random() < 0.02:
            tpl = TEMPLATES[int(rng.integers(len(TEMPLATES)))]
            n = min(len(tpl), n_tokens - i)
            out[i : i + n] = np.frombuffer(tpl[:n], dtype=np.uint8)
            i += n
            continue
        s = int(rng.choice(N_STATES, p=trans[s]))
        out[i] = s
        i += 1
    return out


def batches(corpus: np.ndarray, batch: int, t: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    hi = len(corpus) - t - 1
    for _ in range(steps):
        starts = rng.integers(0, hi, size=batch)
        x = np.stack([corpus[s : s + t] for s in starts]).astype(np.int32)
        y = np.stack([corpus[s + 1 : s + t + 1] for s in starts]).astype(np.int32)
        yield jnp.asarray(x), jnp.asarray(y)


# --------------------------------------------------------------------------
# manual Adam
# --------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def ce_loss(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# --------------------------------------------------------------------------
# target training
# --------------------------------------------------------------------------


def train_target(corpus, cfg=M.TARGET_CFG, steps=400, batch=16, t=64, seed=0,
                 log_every=100):
    params = M.init_params(cfg, seed=seed)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            return ce_loss(M.forward(p, x, cfg), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=2e-3)
        return params, opt, loss

    losses = []
    for i, (x, y) in enumerate(batches(corpus, batch, t, steps, seed=seed + 7)):
        params, opt, loss = step(params, opt, x, y)
        if i % log_every == 0 or i == steps - 1:
            losses.append(float(loss))
            print(f"  target step {i:4d} loss {float(loss):.4f}")
    return params, losses


# --------------------------------------------------------------------------
# SEQ 2-bit QAT (paper §2.1.2): fake-quant with STE on every linear weight
# --------------------------------------------------------------------------


def _seq2_fake_quant(w, group=32):
    """Differentiable SEQ fake-quant: forward = QDQ, backward = identity."""
    n, k = w.shape
    wg = w.reshape(n, k // group, group)
    absmax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 1.5)
    codes = jnp.clip(jnp.round(wg / scale + 1.5), 0, 3)
    wq = ((2.0 * codes - 3.0) * 0.5 * scale).reshape(n, k)
    return w + jax.lax.stop_gradient(wq - w)


def _qat_forward(params, x, cfg):
    qp = {}
    for name, w in params.items():
        base = name.split(".")[-1]
        if base in M._LAYER_LINEARS or base == "head":
            qp[name] = _seq2_fake_quant(w)
        else:
            qp[name] = w
    return M.forward(qp, x, cfg)


def qat_seq2(init, corpus, cfg=M.TARGET_CFG, steps=200, batch=16, t=64,
             seed=2, log_every=100):
    """QAT fine-tune from instruction-tuned-style init (the paper inits from
    tuned weights rather than raw pre-training, §2.1.2)."""
    params = dict(init)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            return ce_loss(_qat_forward(p, x, cfg), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=5e-4)
        return params, opt, loss

    losses = []
    for i, (x, y) in enumerate(batches(corpus, batch, t, steps, seed=seed + 3)):
        params, opt, loss = step(params, opt, x, y)
        if i % log_every == 0 or i == steps - 1:
            losses.append(float(loss))
            print(f"  qat    step {i:4d} loss {float(loss):.4f}")
    return params, losses


# --------------------------------------------------------------------------
# draft distillation (Eagle3-style target alignment)
# --------------------------------------------------------------------------


def distill_draft(target_params, corpus, tgt_cfg=M.TARGET_CFG,
                  draft_cfg=M.DRAFT_CFG, steps=400, batch=16, t=64, seed=1,
                  log_every=100):
    params = M.init_params(draft_cfg, seed=seed)
    opt = adam_init(params)
    proj_seed = np.random.default_rng(seed + 99)
    # fixed random projection target_d -> draft_d for hidden alignment
    proj = jnp.asarray(
        proj_seed.normal(0, tgt_cfg.d_model**-0.5,
                         (tgt_cfg.d_model, draft_cfg.d_model)),
        jnp.float32,
    )

    @jax.jit
    def step(params, opt, x, y):
        t_logits = M.forward(target_params, x, tgt_cfg)
        t_hidden = M.hidden_states(target_params, x, tgt_cfg) @ proj
        t_probs = jax.nn.softmax(t_logits, axis=-1)

        def loss_fn(p):
            d_logits = M.forward(p, x, draft_cfg)
            d_hidden = M.hidden_states(p, x, draft_cfg)
            logp = jax.nn.log_softmax(d_logits, axis=-1)
            kl = -(t_probs * logp).sum(-1).mean()  # CE(target_probs, draft)
            ce = ce_loss(d_logits, y)
            align = jnp.mean((d_hidden - t_hidden) ** 2)
            return kl + 0.3 * ce + 0.1 * align

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=2e-3)
        return params, opt, loss

    losses = []
    for i, (x, y) in enumerate(batches(corpus, batch, t, steps, seed=seed + 13)):
        params, opt, loss = step(params, opt, x, y)
        if i % log_every == 0 or i == steps - 1:
            losses.append(float(loss))
            print(f"  draft  step {i:4d} loss {float(loss):.4f}")
    return params, losses
