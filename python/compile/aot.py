"""AOT entry point: train models, lower everything to HLO text artifacts.

Run once via `make artifacts` (no-op afterwards thanks to the Makefile
stamp).  Python is build-time only; the Rust coordinator loads these
artifacts through PJRT and never calls back into Python.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under artifacts/):
  model_target_<mode>_b<B>.hlo.txt  tokens i32[B,64] -> (logits f32[B,64,256],)
  model_draft_fp32_b<B>.hlo.txt     same signature, draft-sized
  kernel_<int4|seq2|ternary|fp8>.hlo.txt  x f32[64,128] -> (y f32[64,128],)
  sparse_attn.hlo.txt               q,k,v f32[128,4,32] + mask f32[8,8] -> out
  weights.bin / meta.json           flat f32 LE params + layout contract
  eval_corpus.bin / train_corpus.bin  synthetic byte streams for Rust eval
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import quant_matmul as QK
from .kernels import ref
from .kernels import sparse_attn as SA

SEQ_T = 64
ATTN_T = 128
ATTN_BLOCK = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer elides big literals
    # as `constant({...})`, which the text parser on the Rust side cannot
    # reconstruct — baked weights would be silently lost.
    return comp.as_hlo_text(True)


def dump(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def export_model(params, cfg, mode, batch, out_dir, name):
    qp = M.quantize_params(params, mode)

    def fn(tokens):
        return (M.forward(qp, tokens, cfg),)

    spec = jax.ShapeDtypeStruct((batch, SEQ_T), jnp.int32)
    dump(fn, (spec,), os.path.join(out_dir, f"{name}_b{batch}.hlo.txt"))


def export_kernels(params, out_dir):
    """Standalone Pallas-kernel artifacts with baked packed weights.

    Uses target layer-0 wq (128x128) so the codes come from a *real* trained
    weight distribution, not random data.
    """
    w = np.asarray(params["layer0.wq"])
    x_spec = jax.ShapeDtypeStruct((64, w.shape[1]), jnp.float32)

    codes, scales = ref.quantize_int4(w)
    packed = jnp.asarray(ref.pack_nibbles(codes))
    sc = jnp.asarray(scales)
    dump(lambda x: (QK.int4_matmul(x, packed, sc),), (x_spec,),
         os.path.join(out_dir, "kernel_int4.hlo.txt"))

    codes, scales = ref.quantize_seq2(w)
    packed2 = jnp.asarray(ref.pack_crumbs(codes))
    sc2 = jnp.asarray(scales)
    dump(lambda x: (QK.seq2_matmul(x, packed2, sc2),), (x_spec,),
         os.path.join(out_dir, "kernel_seq2.hlo.txt"))

    codes, alpha = ref.quantize_ternary(w)
    packed3 = jnp.asarray(ref.pack_crumbs(codes))
    al = jnp.asarray(alpha)
    dump(lambda x: (QK.ternary_matmul(x, packed3, al),), (x_spec,),
         os.path.join(out_dir, "kernel_ternary.hlo.txt"))

    wj = jnp.asarray(w)
    dump(lambda x: (QK.fp8_matmul(x, wj),), (x_spec,),
         os.path.join(out_dir, "kernel_fp8.hlo.txt"))


def export_sparse_attn(out_dir):
    h, d = 4, 32
    nb = ATTN_T // ATTN_BLOCK
    qs = jax.ShapeDtypeStruct((ATTN_T, h, d), jnp.float32)
    ms = jax.ShapeDtypeStruct((nb, nb), jnp.float32)

    def fn(q, k, v, mask):
        return (SA.block_sparse_attn(q, k, v, mask, block=ATTN_BLOCK),)

    dump(fn, (qs, qs, qs, ms), os.path.join(out_dir, "sparse_attn.hlo.txt"))


def export_weights(target_params, draft_params, out_dir):
    blobs = []
    layout = []
    offset = 0
    for model_name, params, cfg in [
        ("target", target_params, M.TARGET_CFG),
        ("draft", draft_params, M.DRAFT_CFG),
    ]:
        for name, shape in M.param_spec(cfg):
            arr = np.asarray(params[name], dtype=np.float32)
            assert arr.shape == tuple(shape), (name, arr.shape, shape)
            blobs.append(arr.tobytes())
            layout.append(
                {"model": model_name, "name": name, "shape": list(shape),
                 "offset": offset, "len": int(arr.size)}
            )
            offset += arr.size
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for b in blobs:
            f.write(b)
    meta = {
        "seq_t": SEQ_T,
        "attn_t": ATTN_T,
        "attn_block": ATTN_BLOCK,
        "target": M.TARGET_CFG.__dict__,
        "draft": M.DRAFT_CFG.__dict__,
        "layout": layout,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote weights.bin ({offset * 4} bytes) + meta.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print("[1/6] corpus")
    train_corpus = T.make_corpus(200_000, seed=42)
    eval_corpus = T.make_corpus(32_768, seed=777)  # held-out stream
    train_corpus[: 65536].tofile(os.path.join(args.out, "train_corpus.bin"))
    eval_corpus.tofile(os.path.join(args.out, "eval_corpus.bin"))

    print("[2/6] train target")
    target_params, _ = T.train_target(train_corpus, steps=args.steps)

    print("[3/6] distill draft (Eagle3-style alignment) + SEQ QAT + small dense")
    draft_params, _ = T.distill_draft(target_params, train_corpus,
                                      steps=args.steps)
    # 2-bit QAT from the tuned target init (paper §2.1.2) — exported as the
    # HY-1.8B-2Bit analogue; plain PTQ-seq2 is exported too to show collapse.
    qat_params, _ = T.qat_seq2(target_params, train_corpus,
                               steps=args.steps // 2)
    # small dense model trained from scratch = the HY-0.5B baseline analogue
    small_params, _ = T.train_target(train_corpus, cfg=M.DRAFT_CFG,
                                     steps=args.steps, seed=3)

    print("[4/6] export model artifacts")
    for mode in M.QUANT_MODES:
        export_model(target_params, M.TARGET_CFG, mode, 1, args.out,
                     f"model_target_{mode}")
    export_model(qat_params, M.TARGET_CFG, "seq2", 1, args.out,
                 "model_target_seq2qat")
    export_model(small_params, M.DRAFT_CFG, "fp32", 1, args.out,
                 "model_small_fp32")
    export_model(target_params, M.TARGET_CFG, "fp32", 8, args.out,
                 "model_target_fp32")
    export_model(draft_params, M.DRAFT_CFG, "fp32", 1, args.out,
                 "model_draft_fp32")
    export_model(draft_params, M.DRAFT_CFG, "fp32", 8, args.out,
                 "model_draft_fp32")

    print("[5/6] export kernel + sparse-attention artifacts")
    export_kernels(target_params, args.out)
    export_sparse_attn(args.out)

    print("[6/6] export weights.bin / meta.json")
    export_weights(target_params, draft_params, args.out)
    print("AOT done.")


if __name__ == "__main__":
    main()
