"""Layer-2: TinyTransformer in JAX — the paper's model substrate.

The paper compresses Hunyuan-1.8B / Qwen3 / LLaMA-3.2 checkpoints; those are
not available here, so every algorithm is exercised on this byte-level
TinyTransformer (see DESIGN.md §3 substitution table).  Two sizes:

* target : d=128, 4 layers, 4 heads — the model being compressed/served.
* draft  : d=64,  2 layers, 2 heads — the Eagle3-style speculator, distilled
  against the target at build time (train.py).

Architecture: learned positional embeddings, pre-RMSNorm, causal MHA, SwiGLU
MLP, untied output head.  Everything is a plain dict of jnp arrays so
train.py can run manual Adam and aot.py can bake weights into HLO constants.

Quantized model variants apply the *same* quantizers as kernels/ref.py to
every linear weight (QDQ at trace time, so the HLO carries the quantized
weights); the packed-code hot path is exported separately as standalone
Pallas-kernel artifacts consumed by the Rust runtime.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


@dataclass(frozen=True)
class ModelCfg:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    max_t: int = 128


TARGET_CFG = ModelCfg()
DRAFT_CFG = ModelCfg(d_model=64, n_layers=2, n_heads=2, d_ff=128)

# Linear parameter names (out_features x in_features), per layer.
_LAYER_LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]


def param_spec(cfg: ModelCfg):
    """Ordered (name, shape) list — the weights.bin layout contract with
    rust/src/models/weights.rs.  Keep in sync!"""
    spec = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.max_t, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_ff, cfg.d_model)),
            (p + "w_up", (cfg.d_ff, cfg.d_model)),
            (p + "w_down", (cfg.d_model, cfg.d_ff)),
        ]
    spec += [
        ("ln_f", (cfg.d_model,)),
        ("head", (cfg.vocab, cfg.d_model)),
    ]
    return spec


def init_params(cfg: ModelCfg, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_spec(cfg):
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = jnp.asarray(
                rng.normal(0.0, fan_in**-0.5, shape), jnp.float32
            )
    return params


def rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _attn(x, p, prefix, cfg: ModelCfg):
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    q = (x @ p[prefix + "wq"].T).reshape(b, t, h, dh)
    k = (x @ p[prefix + "wk"].T).reshape(b, t, h, dh)
    v = (x @ p[prefix + "wv"].T).reshape(b, t, h, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, None], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
    return out @ p[prefix + "wo"].T


def _mlp(x, p, prefix):
    gate = jax.nn.silu(x @ p[prefix + "w_gate"].T)
    up = x @ p[prefix + "w_up"].T
    return (gate * up) @ p[prefix + "w_down"].T


def forward(params: dict, tokens: jnp.ndarray, cfg: ModelCfg):
    """tokens int32 [B, T] -> logits f32 [B, T, vocab]."""
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        x = x + _attn(rmsnorm(x, params[pre + "ln1"]), params, pre, cfg)
        x = x + _mlp(rmsnorm(x, params[pre + "ln2"]), params, pre)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["head"].T


def hidden_states(params: dict, tokens: jnp.ndarray, cfg: ModelCfg):
    """Final pre-head hidden states [B, T, d] — the target-model supervision
    signal for Eagle3-style draft alignment (paper §3.1.3)."""
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        x = x + _attn(rmsnorm(x, params[pre + "ln1"]), params, pre, cfg)
        x = x + _mlp(rmsnorm(x, params[pre + "ln2"]), params, pre)
    return rmsnorm(x, params["ln_f"])


# --------------------------------------------------------------------------
# quantized variants — QDQ every linear weight with the shared quantizers
# --------------------------------------------------------------------------

QUANT_MODES = ("fp32", "int4", "seq2", "ternary", "fp8")


def quantize_params(params: dict, mode: str, group: int = 32) -> dict:
    """Return params with every linear weight replaced by its QDQ image."""
    if mode == "fp32":
        return dict(params)
    out = {}
    for name, w in params.items():
        base = name.split(".")[-1]
        if base in _LAYER_LINEARS or base == "head":
            wn = np.asarray(w)
            if mode == "int4":
                codes, scales = ref.quantize_int4(wn, group)
                wq = ref.dequantize_int4(codes, scales, group)
            elif mode == "seq2":
                codes, scales = ref.quantize_seq2(wn, group)
                wq = ref.dequantize_seq2(codes, scales, group)
            elif mode == "ternary":
                codes, alpha = ref.quantize_ternary(wn)
                wq = ref.dequantize_ternary(codes, alpha)
            elif mode == "fp8":
                wq = np.asarray(ref.fp8_qdq(jnp.asarray(wn)))
            else:
                raise ValueError(mode)
            out[name] = jnp.asarray(wq, jnp.float32)
        else:
            out[name] = w
    return out
