"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (multiples of the tile sizes) and value scales;
every kernel must match its ref.py oracle to tight tolerance under
interpret=True.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant_matmul as QK
from compile.kernels import ref
from compile.kernels import sparse_attn as SA

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)


dims = st.sampled_from([32, 64, 96])
kdims = st.sampled_from([64, 128])
scales = st.sampled_from([0.05, 1.0, 30.0])


class TestInt4:
    @given(m=dims, n=dims, k=kdims, scale=scales, seed=st.integers(0, 99))
    @settings(**SETTINGS)
    def test_matches_ref(self, m, n, k, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = np.asarray(rand(rng, n, k, scale=scale))
        codes, sc = ref.quantize_int4(w)
        packed = jnp.asarray(ref.pack_nibbles(codes))
        sc = jnp.asarray(sc)
        got = QK.int4_matmul(x, packed, sc)
        want = ref.ref_int4_matmul(x, packed, sc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * scale)

    def test_dequant_error_bounded(self):
        rng = np.random.default_rng(0)
        w = rng.normal(0, 1, (64, 128)).astype(np.float32)
        codes, sc = ref.quantize_int4(w)
        wq = ref.dequantize_int4(codes, sc)
        # int4 with group 32: max error is half a step = absmax/14 per group
        err = np.abs(wq - w)
        step = np.repeat(sc, 32, axis=1)
        assert (err <= 0.5 * step + 1e-6).all()

    def test_pack_roundtrip(self):
        rng = np.random.default_rng(1)
        codes = rng.integers(0, 16, (8, 32)).astype(np.uint8)
        packed = ref.pack_nibbles(codes)
        assert packed.shape == (8, 16)
        back = np.asarray(ref.unpack_nibbles(jnp.asarray(packed)))
        np.testing.assert_array_equal(back, codes)


class TestSeq2:
    @given(m=dims, n=dims, k=kdims, scale=scales, seed=st.integers(0, 99))
    @settings(**SETTINGS)
    def test_matches_ref(self, m, n, k, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = np.asarray(rand(rng, n, k, scale=scale))
        codes, sc = ref.quantize_seq2(w)
        packed = jnp.asarray(ref.pack_crumbs(codes))
        sc = jnp.asarray(sc)
        got = QK.seq2_matmul(x, packed, sc)
        want = ref.ref_seq2_matmul(x, packed, sc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * scale)

    def test_levels_are_symmetric_no_zero(self):
        """SEQ levels must be {-1.5,-0.5,0.5,1.5}*scale — no zero level."""
        w = np.linspace(-2, 2, 128, dtype=np.float32)[None, :]
        codes, sc = ref.quantize_seq2(w)
        wq = ref.dequantize_seq2(codes, sc)
        assert (np.abs(wq) > 1e-9).all()
        levels = np.unique(np.round(wq / np.repeat(sc, 32, axis=1), 4))
        assert len(levels) <= 4

    def test_pack_roundtrip(self):
        rng = np.random.default_rng(2)
        codes = rng.integers(0, 4, (8, 32)).astype(np.uint8)
        packed = ref.pack_crumbs(codes)
        assert packed.shape == (8, 8)
        back = np.asarray(ref.unpack_crumbs(jnp.asarray(packed)))
        np.testing.assert_array_equal(back, codes)


class TestTernary:
    @given(m=dims, n=dims, k=kdims, scale=scales, seed=st.integers(0, 99))
    @settings(**SETTINGS)
    def test_matches_ref(self, m, n, k, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = np.asarray(rand(rng, n, k, scale=scale))
        codes, alpha = ref.quantize_ternary(w)
        packed = jnp.asarray(ref.pack_crumbs(codes))
        al = jnp.asarray(alpha)
        got = QK.ternary_matmul(x, packed, al)
        want = ref.ref_ternary_matmul(x, packed, al)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * scale)

    def test_codes_in_range(self):
        rng = np.random.default_rng(3)
        w = rng.normal(0, 1, (16, 64)).astype(np.float32)
        codes, alpha = ref.quantize_ternary(w)
        assert set(np.unique(codes)) <= {0, 1, 2}
        assert (alpha > 0).all()


class TestFp8:
    @given(m=dims, n=dims, k=kdims, scale=scales, seed=st.integers(0, 99))
    @settings(**SETTINGS)
    def test_matches_ref(self, m, n, k, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = rand(rng, n, k, scale=scale)
        got = QK.fp8_matmul(x, w)
        want = ref.ref_fp8_matmul(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5,
                                   atol=1e-3 * max(scale, 1.0))

    def test_qdq_relative_error(self):
        """fp8 e4m3 has ~2^-3 relative precision for normal values."""
        x = jnp.asarray(np.random.default_rng(4).normal(0, 1, 1024),
                        jnp.float32)
        y = ref.fp8_qdq(x)
        big = np.abs(np.asarray(x)) > 1e-2
        rel = np.abs(np.asarray(y - x))[big] / np.abs(np.asarray(x))[big]
        assert rel.max() < 0.13


class TestSparseAttn:
    @given(
        t=st.sampled_from([32, 64, 128]),
        h=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([8, 32]),
        density=st.floats(0.2, 1.0),
        seed=st.integers(0, 99),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, t, h, d, density, seed):
        block = 16
        nb = t // block
        rng = np.random.default_rng(seed)
        q = rand(rng, t, h, d)
        k = rand(rng, t, h, d)
        v = rand(rng, t, h, d)
        mask = (rng.random((nb, nb)) < density)
        np.fill_diagonal(mask, True)  # keep the causal diagonal blocks
        maskf = jnp.asarray(mask.astype(np.float32))
        got = SA.block_sparse_attn(q, k, v, maskf, block=block)
        want = ref.ref_block_sparse_attn(q, k, v, jnp.asarray(mask), block)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_dense_mask_equals_causal_attention(self):
        t, h, d, block = 64, 2, 16, 16
        rng = np.random.default_rng(5)
        q, k, v = (rand(rng, t, h, d) for _ in range(3))
        ones = jnp.ones((t // block, t // block), jnp.float32)
        got = SA.block_sparse_attn(q, k, v, ones, block=block)
        # plain causal softmax attention
        scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(d)
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(causal[None], scores, -1e30)
        import jax

        probs = jax.nn.softmax(scores, axis=-1)
        want = jnp.einsum("hqk,khd->qhd", probs, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
