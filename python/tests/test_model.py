"""L2 model invariants: shapes, causality, quantized-variant sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T

SMALL = M.ModelCfg(vocab=256, d_model=32, n_layers=2, n_heads=2, d_ff=64,
                   max_t=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(SMALL, seed=0)


def test_forward_shape(params):
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = M.forward(params, toks, SMALL)
    assert logits.shape == (2, 16, 256)
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    """Changing token t must not affect logits at positions < t."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 256, (1, 16)).astype(np.int32)
    base = M.forward(params, jnp.asarray(toks), SMALL)
    toks2 = toks.copy()
    toks2[0, 10] = (toks2[0, 10] + 1) % 256
    pert = M.forward(params, jnp.asarray(toks2), SMALL)
    np.testing.assert_allclose(base[0, :10], pert[0, :10], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(base[0, 10:], pert[0, 10:])


def test_hidden_states_shape(params):
    toks = jnp.zeros((1, 8), jnp.int32)
    h = M.hidden_states(params, toks, SMALL)
    assert h.shape == (1, 8, SMALL.d_model)


def test_param_spec_covers_init(params):
    names = {n for n, _ in M.param_spec(SMALL)}
    assert names == set(params.keys())
    for n, s in M.param_spec(SMALL):
        assert params[n].shape == tuple(s)


@pytest.mark.parametrize("mode", ["int4", "seq2", "ternary", "fp8"])
def test_quantized_variant_close_but_not_identical(params, mode):
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, 256, (1, 16)), jnp.int32
    )
    base = M.forward(params, toks, SMALL)
    qp = M.quantize_params(params, mode)
    qlog = M.forward(qp, toks, SMALL)
    # quantization perturbs but must not destroy the logits
    assert not np.allclose(base, qlog)
    assert bool(jnp.isfinite(qlog).all())
    if mode in ("fp8", "int4"):
        # >= 4-bit PTQ is near-lossless; <= 2-bit PTQ collapses without QAT
        # (that collapse is the paper's §2.1.2 motivation — asserted in
        # test_qat_recovers_seq2 below).
        corr = np.corrcoef(np.asarray(base).ravel(),
                           np.asarray(qlog).ravel())[0, 1]
        assert corr > 0.8, f"{mode} corr {corr}"


def test_qat_recovers_seq2():
    """SEQ 2-bit QAT must recover most of the PTQ collapse (paper §2.1.2)."""
    corpus = T.make_corpus(20_000, seed=11)
    params, _ = T.train_target(corpus, cfg=SMALL, steps=60, batch=8, t=32,
                               log_every=1000)
    x, y = next(T.batches(corpus, 16, 32, 1, seed=5))
    base = float(T.ce_loss(M.forward(params, x, SMALL), y))
    ptq = float(
        T.ce_loss(M.forward(M.quantize_params(params, "seq2"), x, SMALL), y)
    )
    qat_params, _ = T.qat_seq2(params, corpus, cfg=SMALL, steps=60, batch=8,
                               t=32, log_every=1000)
    qat = float(
        T.ce_loss(M.forward(M.quantize_params(qat_params, "seq2"), x, SMALL),
                  y)
    )
    assert ptq > base + 0.5, "2-bit PTQ should hurt noticeably"
    assert qat < ptq - 0.3, f"QAT should recover: base={base} ptq={ptq} qat={qat}"


def test_quantize_params_preserves_norms_and_embeddings(params):
    qp = M.quantize_params(params, "ternary")
    np.testing.assert_array_equal(qp["embed"], params["embed"])
    np.testing.assert_array_equal(qp["layer0.ln1"], params["layer0.ln1"])
    assert not np.allclose(qp["layer0.wq"], params["layer0.wq"])


def test_degradation_ordering(params):
    """Coarser quantization ⇒ larger logit MSE (int4 < seq2 ≈ ternary)."""
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, 256, (2, 16)), jnp.int32
    )
    base = np.asarray(M.forward(params, toks, SMALL))
    mse = {}
    for mode in ["fp8", "int4", "seq2", "ternary"]:
        q = np.asarray(M.forward(M.quantize_params(params, mode), toks, SMALL))
        mse[mode] = float(((q - base) ** 2).mean())
    assert mse["fp8"] < mse["int4"] < mse["seq2"]
    assert mse["int4"] < mse["ternary"]


class TestCorpus:
    def test_deterministic(self):
        a = T.make_corpus(1000, seed=7)
        b = T.make_corpus(1000, seed=7)
        np.testing.assert_array_equal(a, b)

    def test_seed_changes_stream(self):
        a = T.make_corpus(1000, seed=7)
        b = T.make_corpus(1000, seed=8)
        assert not np.array_equal(a, b)

    def test_templates_present(self):
        c = bytes(T.make_corpus(50_000, seed=1))
        assert b"Angel" in c
        assert b"quant" in c

    def test_learnable(self):
        """A couple of Adam steps must reduce CE on this corpus."""
        corpus = T.make_corpus(20_000, seed=3)
        params = M.init_params(SMALL, seed=0)
        import jax

        opt = T.adam_init(params)
        losses = []
        for x, y in T.batches(corpus, 8, 32, 30, seed=0):
            def loss_fn(p):
                return T.ce_loss(M.forward(p, x, SMALL), y)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt = T.adam_update(params, grads, opt, lr=3e-3)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5
