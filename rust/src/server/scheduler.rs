//! Sharded continuous-batching scheduler with KV-memory admission control.
//!
//! One loop serves every path: a per-request state machine
//!
//!     Queued ──admit──▶ Prefill ──first step──▶ Decoding ──▶ Finished
//!
//! driven by a [`WorkerPool`] of `workers` independent scheduler loops —
//! each with its own [`StepExecutor`], KV-budget share, live set, and
//! compute clock — pulling from **one shared FIFO queue**. Between every
//! decode round a worker retires finished requests, and admission is
//! **work-stealing**: the worker that can start the queue head earliest
//! (an idle worker jumps its clock to the arrival in O(1)) steals it,
//! under that worker's KV-memory budget share (projected from [`KvCache`]
//! bytes accounting). A request that fits *no* worker's budget share is
//! routed to an idle least-loaded worker to run alone (safety valve)
//! instead of starving. Static batching, sequential serving, and the
//! single-worker [`Scheduler`] are degenerate configurations of the same
//! loop (see [`AdmissionPolicy`] / [`ServeCfg::workers`]), which is what
//! unifies the time model across `ServingEngine::serve` / `serve_batched`
//! / `serve_batched_pjrt` / sharded serving.
//!
//! Compute is pluggable through [`StepExecutor`]: greedy KV-session
//! decoding ([`GreedyExecutor`]), speculative draft+target sessions with
//! rollback ([`SpecExecutor`]), or a joint batched forward over a PJRT
//! executable ([`PjrtBatchExecutor`]).
//!
//! Time model (unified across all paths): request *arrivals* are virtual
//! (from the workload trace) on one global timeline; compute occupies
//! real wall-clock measured around each decode round **on the worker
//! that ran it**, so worker clocks advance independently (parallel
//! replicas on the virtual timeline). An empty round jumps straight to
//! the earliest next event across workers — never further than the
//! arrival the jumping worker is about to admit — in O(1) (no
//! busy-advance). Per-request TTFT = first-token round end − arrival,
//! total = finish round end − arrival, on the same timeline everywhere,
//! so sharded reports compare directly against single-worker ones.
//!
//! Because every executor decodes each request in its own session(s),
//! per-request outputs are **bit-identical** for every worker count and
//! admission interleaving (property-tested in
//! `tests/test_sharded_props.rs`).
//!
//! Fault tolerance: a per-request problem during a round comes back as a
//! [`StepFault`] on that request's [`StepEvent`] — the pool retires the
//! request (freeing its KV reservation immediately), re-admits it to the
//! shared queue with exponential virtual-time backoff while attempts
//! remain, and otherwise records a `Failed` outcome; no other request is
//! disturbed. An `Err` from [`StepExecutor::step_round`] means the whole
//! worker is lost: it is marked dead, its live set is requeued (or
//! failed, out of attempts), and the surviving workers absorb the load
//! through the existing work-stealing admission. Requests may carry
//! deadlines ([`TokenRequest::deadline_ms`] / [`ServeCfg::deadline_ms`]);
//! the pool cancels past-deadline requests between rounds on the virtual
//! clock and evicts their KV. Every submitted request ends in exactly one
//! terminal [`RequestOutcome`], and requests that never fault keep
//! bit-identical outputs versus a fault-free run. Deterministic chaos is
//! injected by wrapping every executor in a [`FaultInjector`] when
//! [`ServeCfg::fault`] is set (see `server/faults.rs`).
//!
//! SLO-aware serving: when [`ServeCfg::classes`] is set, the queue is no
//! longer strictly FIFO — the entry with the highest effective class
//! priority is seated next (FIFO within a class, and an entry that has
//! waited past the policy's `aging_ms` competes at the maximum priority,
//! so Batch can never starve). Admission also routes compression by
//! class: LongContext prompts prefill through the STeM sparse-attention
//! path, and Multimodal prompts are token-pruned before they ever reach
//! the queue, so KV admission bytes are charged for the pruned prompt.
//! Without `classes` every queue decision is byte-identical to the
//! class-blind scheduler.
//!
//! [`KvCache`]: crate::models::KvCache
//! [`RequestOutcome`]: super::engine::RequestOutcome
//! [`FaultInjector`]: super::faults::FaultInjector

use crate::data::TokenRequest;
use crate::models::{Sampler, POOL_EXHAUSTED_PREFIX};
use crate::runtime::ModelExecutable;
use crate::spec_decode::{spec_verify_step, DecodeSession, SessionModel};
use crate::tensor::ops::argmax;
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::classes::{prune_multimodal_prompt, ClassPolicy, RequestClass};
use super::engine::{CompletedRequest, RequestOutcome, ServeReport};
use super::faults::{FaultInjector, FaultPlan, WorkerCrash};

/// When the scheduler may move a request from Queued to Prefill.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit whenever a slot and KV budget are free — between every decode
    /// round. This is continuous batching.
    Continuous,
    /// Admit only when no request is in flight, up to `max_in_flight` at
    /// once: classic static batching (the whole chunk drains before the
    /// next one forms).
    Static,
    /// One request at a time, in arrival order (`max_in_flight` is forced
    /// to 1): the old per-request serve loop.
    Sequential,
}

impl AdmissionPolicy {
    /// Parse a config/CLI name ("continuous" | "static" | "sequential").
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "continuous" => AdmissionPolicy::Continuous,
            "static" => AdmissionPolicy::Static,
            "sequential" => AdmissionPolicy::Sequential,
            other => bail!(
                "unknown admission policy `{other}` (continuous | static | sequential)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Continuous => "continuous",
            AdmissionPolicy::Static => "static",
            AdmissionPolicy::Sequential => "sequential",
        }
    }
}

/// Scheduler configuration — the `serve:` section of a YAML config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeCfg {
    pub policy: AdmissionPolicy,
    /// concurrent-request cap **per worker** (executors may clamp it
    /// further, e.g. to the PJRT batch dimension)
    pub max_in_flight: usize,
    /// Total KV-memory admission budget in bytes, split evenly across
    /// `workers`; 0 = unlimited. Admission reserves each request's
    /// *projected peak* KV bytes up front against its worker's share —
    /// and sessions are allocated at exactly that bound
    /// (`new_session_bounded`) — so both observable and resident KV
    /// memory stay within every worker's share. A request projected over
    /// every worker's share is admitted alone on an idle worker (safety
    /// valve) rather than starving.
    pub kv_budget_bytes: usize,
    /// Number of scheduler workers sharing the FIFO queue (work-stealing
    /// admission). 1 = the classic single-worker scheduler; 0 is invalid
    /// and rejected at config validation.
    pub workers: usize,
    /// Pool-wide default completion deadline in milliseconds from arrival
    /// on the virtual clock. Precedence (most specific wins): a request's
    /// own [`TokenRequest::deadline_ms`] > the per-class
    /// [`ClassSlo::deadline_ms`](super::ClassSlo) default (when `classes`
    /// is configured) > this pool-wide value. Past-deadline requests are
    /// cancelled between rounds (outcome `DeadlineExceeded`, KV evicted,
    /// partial output kept). `None` = no deadline; a non-positive value is
    /// rejected loudly at validation and at [`WorkerPool::run`].
    pub deadline_ms: Option<f64>,
    /// How many times a faulted request may re-enter the shared queue
    /// before its outcome becomes `Failed`. 0 = fail on the first fault;
    /// a request consumes at most `max_retries + 1` execution attempts.
    pub max_retries: usize,
    /// Base virtual-time backoff before a retry becomes admissible again:
    /// the k-th failed attempt re-queues the request no earlier than
    /// `failure time + retry_backoff_ms * 2^(k-1)`. Must be >= 0.
    pub retry_backoff_ms: f64,
    /// Ceiling on the computed exponential backoff (ms). Without a cap,
    /// high attempt counts push `ready_ms` astronomically far into the
    /// virtual future and a retried request silently never re-admits.
    /// Must be >= 0 and finite.
    pub max_backoff_ms: f64,
    /// Deterministic fault-injection plan (chaos tests, resilience
    /// benches). `None` = no injection; the serve loop is byte-identical
    /// to the pre-fault-tolerance scheduler for fault-free runs.
    pub fault: Option<FaultPlan>,
    /// Page size (tokens per KV block) for the paged serving path. `Some`
    /// routes `serve:` configs through the paged executors with this
    /// block size; `None` keeps the contiguous per-request caches.
    pub kv_block_tokens: Option<usize>,
    /// Run pool workers on real OS threads (`true`) instead of the
    /// single-thread virtual-clock loop (`false`, the default). The two
    /// modes produce identical per-request outputs and terminal outcome
    /// kinds — only wall-clock timing fields differ (see the README's
    /// determinism contract). Threaded mode is what `bench_sharded`'s
    /// wall-clock scaling numbers measure.
    pub threads: bool,
    /// SLO-aware serving policy (`serve.classes:`): per-class SLOs +
    /// priorities drive class-priority admission over the shared queue
    /// (with an aging/starvation bound), per-class default deadlines,
    /// priority-aware preemption, and admission-time compression routing
    /// (LongContext → STeM sparse prefill, Multimodal → token-pruned
    /// prompts). `None` = class-blind FIFO, byte-identical to the
    /// pre-class scheduler.
    pub classes: Option<ClassPolicy>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Continuous,
            max_in_flight: 8,
            kv_budget_bytes: 0,
            workers: 1,
            deadline_ms: None,
            max_retries: 0,
            retry_backoff_ms: 1.0,
            max_backoff_ms: 60_000.0,
            fault: None,
            kv_block_tokens: None,
            threads: false,
            classes: None,
        }
    }
}

impl ServeCfg {
    pub fn continuous(max_in_flight: usize) -> Self {
        ServeCfg { max_in_flight, ..ServeCfg::default() }
    }

    pub fn sequential() -> Self {
        ServeCfg { policy: AdmissionPolicy::Sequential, max_in_flight: 1, ..ServeCfg::default() }
    }

    pub fn static_batch(max_batch: usize) -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Static,
            max_in_flight: max_batch,
            ..ServeCfg::default()
        }
    }

    pub fn with_budget(mut self, kv_budget_bytes: usize) -> Self {
        self.kv_budget_bytes = kv_budget_bytes;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Pool-wide default deadline (ms from arrival, virtual clock).
    pub fn with_deadline(mut self, deadline_ms: f64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Allow `max_retries` re-admissions per faulted request.
    pub fn with_retries(mut self, max_retries: usize) -> Self {
        self.max_retries = max_retries;
        self
    }

    /// Base virtual-time retry backoff in milliseconds.
    pub fn with_backoff(mut self, retry_backoff_ms: f64) -> Self {
        self.retry_backoff_ms = retry_backoff_ms;
        self
    }

    /// Ceiling on the computed exponential retry backoff (ms).
    pub fn with_max_backoff(mut self, max_backoff_ms: f64) -> Self {
        self.max_backoff_ms = max_backoff_ms;
        self
    }

    /// Run pool workers on real OS threads (`false` = the bit-exactness
    /// single-thread virtual-clock twin).
    pub fn with_threads(mut self, threads: bool) -> Self {
        self.threads = threads;
        self
    }

    /// Inject deterministic faults via a [`FaultInjector`] on every worker.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Serve from paged KV with `block_tokens`-token pages.
    pub fn with_block_tokens(mut self, block_tokens: usize) -> Self {
        self.kv_block_tokens = Some(block_tokens);
        self
    }

    /// Enable SLO-aware serving under `policy` (see [`ClassPolicy`]).
    pub fn with_classes(mut self, policy: ClassPolicy) -> Self {
        self.classes = Some(policy);
        self
    }

    /// Each worker's KV-budget share: `kv_budget_bytes` split evenly, the
    /// remainder spread over the first workers, so shares always sum to
    /// the configured total. A nonzero total smaller than the worker
    /// count would leave trailing shares at 0 — i.e. silently unlimited —
    /// so both config validation and [`WorkerPool::run`] reject that
    /// combination loudly instead.
    pub fn per_worker_budgets(&self) -> Vec<usize> {
        let n = self.workers.max(1);
        if self.kv_budget_bytes == 0 {
            return vec![0; n];
        }
        let base = self.kv_budget_bytes / n;
        let rem = self.kv_budget_bytes % n;
        (0..n).map(|i| base + usize::from(i < rem)).collect()
    }

    /// Loud misconfiguration guard for config-driven serving: with a
    /// nonzero budget, at least the smallest request of `requests` must
    /// fit one worker's share. Otherwise *every* request would fall back
    /// to the oversized-request safety valve and the pool would silently
    /// degenerate to budget-less one-at-a-time serving.
    pub fn ensure_requests_fit<E: StepExecutor>(
        &self,
        executor: &E,
        requests: &[TokenRequest],
    ) -> Result<()> {
        if self.kv_budget_bytes == 0 || requests.is_empty() {
            return Ok(());
        }
        let share = self.per_worker_budgets().into_iter().max().unwrap_or(0);
        // admission_bytes is what the scheduler actually gates on: the
        // projected peak for reservation-based executors, only the prompt
        // pages for the paged free-block ones
        let min_need = requests
            .iter()
            .map(|r| executor.admission_bytes(r))
            .min()
            .unwrap_or(0);
        if min_need > share {
            bail!(
                "serve.kv_budget_bytes = {} splits to {share} bytes per worker \
                 ({} workers), smaller than the smallest request's admission \
                 KV need of {min_need} bytes; every request would need the \
                 oversized-request safety valve — raise the budget or reduce \
                 workers",
                self.kv_budget_bytes,
                self.workers.max(1),
            );
        }
        Ok(())
    }
}

/// Lifecycle of one request inside the scheduler. `Queued` and `Finished`
/// are the boundary states (the arrival queue, and the completed list with
/// the KV reservation released); the live set tracks only
/// `Prefill`/`Decoding`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    /// waiting for a slot / KV budget
    Queued,
    /// admitted; its first decode round (which feeds the prompt) has not
    /// completed yet
    Prefill,
    /// producing tokens, one round at a time
    Decoding,
    /// retired; its KV reservation is released
    Finished,
}

/// A request-level fault raised during one decode round. The pool contains
/// it to that request: KV evicted, bounded retry, `Failed` outcome when
/// attempts run out — the rest of the batch is untouched.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepFault {
    /// the request's decode step failed (model error or injected fault)
    Error(String),
    /// non-finite logits detected on the decode path — a poisoned request
    /// must not commit garbage tokens
    NanLogits,
    /// evicted by a paged executor to free KV pages for another live
    /// request — a scheduling decision, not a failure: the pool requeues
    /// it immediately without burning a retry attempt
    Preempted,
}

impl StepFault {
    pub fn describe(&self) -> String {
        match self {
            StepFault::Error(e) => e.clone(),
            StepFault::NanLogits => "non-finite logits on the decode path".to_string(),
            StepFault::Preempted => "preempted to free KV pages".to_string(),
        }
    }
}

/// What one request did during one decode round.
#[derive(Clone, Debug)]
pub struct StepEvent {
    pub id: u64,
    /// tokens committed this round (greedy: 1; speculative: accepted + bonus)
    pub tokens: Vec<u8>,
    /// target verify/decode steps this round (the AL denominator)
    pub steps: usize,
    /// speculative tokens proposed this round
    pub proposed: usize,
    /// speculative tokens accepted this round
    pub accepted: usize,
    pub finished: bool,
    /// request-level fault this round; when set, the other fields are
    /// ignored and the scheduler fails/retries this request only
    pub fault: Option<StepFault>,
}

impl StepEvent {
    /// Event reporting a contained per-request fault.
    pub fn faulted(id: u64, fault: StepFault) -> Self {
        StepEvent {
            id,
            tokens: Vec::new(),
            steps: 0,
            proposed: 0,
            accepted: 0,
            finished: false,
            fault: Some(fault),
        }
    }
}

/// Pluggable compute for one decode round over the live set. The scheduler
/// owns admission, retirement, the virtual clock, and metrics; executors
/// own per-request sessions and the model calls.
pub trait StepExecutor {
    /// Projected peak KV bytes `req` will hold while in flight — the
    /// amount admission control reserves against the budget.
    fn projected_bytes(&self, req: &TokenRequest) -> usize;
    /// Bytes admission control requires free to *start* `req`. Defaults
    /// to the full projected peak (reservation-based admission); paged
    /// executors override it with just the prompt's pages, since decode
    /// growth is claimed page-by-page.
    fn admission_bytes(&self, req: &TokenRequest) -> usize {
        self.projected_bytes(req)
    }
    /// Bytes the executor can actually hand out right now, when it runs
    /// its own allocator: `Some(free)` switches the scheduler to
    /// free-block admission (compare `admission_bytes` against this live
    /// value, reserve nothing); `None` keeps the classic
    /// reserve-the-projected-peak accounting.
    fn free_capacity_bytes(&self) -> Option<usize> {
        None
    }
    /// Allocate per-request decode state. The request's first round (its
    /// Prefill step) runs at the next `step_round`.
    fn admit(&mut self, req: &TokenRequest) -> Result<()>;
    /// The 1-based execution attempt the next `admit` of `id` represents,
    /// announced by the pool just before re-admission. Default: ignored.
    /// The fault injector keys its deterministic draws on it, so a retry
    /// sees fresh draws no matter which worker re-admits the request.
    fn note_attempt(&mut self, _id: u64, _attempt: usize) {}
    /// Advance every admitted request one decode round at virtual time
    /// `now_ms`, returning one event per live request. A per-request
    /// problem must come back as a [`StepFault`] on that request's event
    /// (the pool contains it); an `Err` means the whole worker is lost —
    /// the pool marks it dead and re-admits its live set elsewhere.
    fn step_round(&mut self, rng: &mut Rng, now_ms: f64) -> Result<Vec<StepEvent>>;
    /// Drop a finished request's state, freeing its KV bytes.
    fn retire(&mut self, id: u64);
    /// Resident KV bytes across live sessions (observability + the budget
    /// property test).
    fn live_bytes(&self) -> usize;
    /// Hard cap on concurrently-admittable requests (e.g. the PJRT batch
    /// dimension); `None` = bounded only by `ServeCfg::max_in_flight`.
    fn slot_cap(&self) -> Option<usize> {
        None
    }
    /// Virtual milliseconds of stall observed/injected during the last
    /// round, drained once per round by the pool and added to the
    /// worker's clock (clock inflation). Default: no stall.
    fn take_stall_ms(&mut self) -> f64 {
        0.0
    }
    /// Cumulative count of prompt prefills this executor routed through
    /// the sparse-attention path (class-based compression routing).
    /// Default: executors without a sparse route report 0.
    fn sparse_prefills(&self) -> usize {
        0
    }
}

struct LiveReq {
    /// the original request, kept whole so a faulted attempt can re-enter
    /// the shared queue unchanged
    req: TokenRequest,
    state: ReqState,
    output: Vec<u8>,
    first_token_ms: Option<f64>,
    reserved_bytes: usize,
    /// 1-based execution attempt this admission represents
    attempts: usize,
    /// absolute virtual-time deadline (arrival + effective deadline_ms)
    deadline_abs: Option<f64>,
}

/// One shared-queue entry: a request plus its retry bookkeeping.
struct QueuedReq {
    req: TokenRequest,
    /// attempt number the next admission will be (1 = first try)
    attempt: usize,
    /// earliest virtual time this entry may be admitted: the arrival for
    /// fresh requests, failure time + exponential backoff for retries
    ready_ms: f64,
}

/// Absolute virtual-time deadline for `req` under `cfg`. Precedence,
/// most specific wins: the per-request override, then the per-class
/// default (when `serve.classes:` is configured), then the pool-wide
/// `serve.deadline_ms`; measured from arrival.
fn deadline_abs_of(req: &TokenRequest, cfg: &ServeCfg) -> Option<f64> {
    req.deadline_ms
        .or_else(|| {
            cfg.classes
                .as_ref()
                .and_then(|p| p.slo_of(&req.class).deadline_ms)
        })
        .or(cfg.deadline_ms)
        .map(|d| req.arrival_ms + d)
}

/// Index into `queue` of the entry admission should seat next. Without a
/// class policy this is always 0 — strict FIFO, byte-identical to the
/// class-blind scheduler. With one, the entry with the highest effective
/// priority wins and ties keep queue order (strict FIFO within a class);
/// an entry that has waited at least `aging_ms` since its arrival (as of
/// `now_ms`, or its own ready time if later) competes at the pool's
/// maximum priority, which bounds starvation of low-priority classes.
fn pick_queued(queue: &VecDeque<QueuedReq>, cfg: &ServeCfg, now_ms: f64) -> usize {
    let Some(pol) = cfg.classes.as_ref() else { return 0 };
    let pmax = pol.max_priority();
    let mut best = 0usize;
    let mut best_p = -1i32;
    for (i, q) in queue.iter().enumerate() {
        let waited = now_ms.max(q.ready_ms) - q.req.arrival_ms;
        let p = if waited >= pol.aging_ms { pmax } else { pol.priority_of(&q.req.class) };
        if i32::from(p) > best_p {
            best_p = i32::from(p);
            best = i;
        }
    }
    best
}

/// Exponential virtual-time backoff before attempt `failed_attempt + 1`,
/// clamped to `cfg.max_backoff_ms`. The clamp is what keeps high attempt
/// counts finite: without it `backoff_ms * 2^60` pushes a retry's
/// `ready_ms` so far into the virtual future that the request silently
/// never re-admits (regression-tested in `backoff_stays_finite_and_capped`).
fn retry_backoff(cfg: &ServeCfg, failed_attempt: usize) -> f64 {
    let raw = cfg.retry_backoff_ms * 2f64.powi(failed_attempt.saturating_sub(1).min(60) as i32);
    raw.min(cfg.max_backoff_ms)
}

/// A preemption is deliberately not a failure (it never counts against
/// `max_retries`), which opens a livelock: a paged request whose decode
/// growth can never fit the bounded block pool is preempted and requeued
/// forever. The pool counts *consecutive* preemptions of each request
/// with no pool-wide completion in between; past this many cycles the
/// request fails loudly with the `PoolExhausted` context instead of
/// spinning. Healthy preemption churn resets the counter at every
/// completion, so tight-but-feasible schedules (e.g.
/// `preemption_under_tight_pool_still_completes_every_request`) never
/// trip it, while a genuine never-fits request trips it within a bounded
/// number of rounds.
const MAX_NO_PROGRESS_PREEMPT_CYCLES: usize = 64;

/// Terminal-outcome and throughput bookkeeping shared verbatim by the
/// single-thread virtual-clock twin and the threaded pool (where it
/// lives inside the shared mutex), so both modes classify every event
/// identically — the heart of the cross-mode determinism contract.
#[derive(Default)]
struct PoolLedger {
    completed: Vec<CompletedRequest>,
    total_tokens: usize,
    al_num: f64,
    al_den: f64,
    proposed: usize,
    accepted: usize,
    /// per request id: (`completed.len()` at its last preemption,
    /// consecutive preemptions since without any pool-wide completion) —
    /// the no-progress detector behind [`MAX_NO_PROGRESS_PREEMPT_CYCLES`]
    preempt_cycles: HashMap<u64, (usize, usize)>,
    /// request ids in the order admission seated them (re-admissions
    /// repeat the id). Deterministic in the virtual-clock twin; in the
    /// threaded pool it reflects the actual thread interleaving.
    admitted_order: Vec<u64>,
}

/// Everything the threaded pool shares behind its mutex: the FIFO queue,
/// the outcome ledger, and the pool-wide bookkeeping the twin keeps as
/// `run_inner` locals. Workers take the lock to admit and to apply round
/// events; decode rounds themselves run with the lock released.
struct ThreadShared {
    queue: VecDeque<QueuedReq>,
    ledger: PoolLedger,
    crashed_workers: Vec<(usize, String)>,
    /// per-worker live-set sizes — in-flight sampling + termination test
    live_counts: Vec<usize>,
    /// per-worker `executor.live_bytes()` as of its last state change
    cached_live_bytes: Vec<usize>,
    /// per-worker virtual clocks (timing fields + all-dead shedding)
    clocks: Vec<f64>,
    /// per-worker peak resident KV bytes
    worker_peaks: Vec<usize>,
    /// per-worker cumulative `executor.sparse_prefills()` as of its last
    /// state change — summed into the report at pool teardown
    sparse_prefills: Vec<usize>,
    /// running sum of `cached_live_bytes`
    pool_live_bytes: usize,
    peak_kv_bytes: usize,
    rounds: usize,
    in_flight_sum: usize,
    peak_in_flight: usize,
    /// workers not yet crashed; the last one to die sheds the queue
    alive: usize,
    /// consecutive all-idle wakeups with an unadmitted head — the
    /// loud-hang safety valve
    idle_spins: usize,
    done: bool,
    /// first scheduler invariant error; aborts the run
    fatal: Option<anyhow::Error>,
}

/// Single-worker serve loop — the degenerate [`WorkerPool`] of one worker,
/// kept as the entry point for callers that hand over one concrete
/// executor (`serve_batched`, the PJRT path, unit tests).
pub struct Scheduler;

impl Scheduler {
    /// Run `executor` as a one-worker pool. A single executor can only
    /// staff one worker, so `cfg.workers > 1` is a loud error here (no
    /// silent single-worker fallback); sharded callers go through
    /// [`WorkerPool::run`] with an executor factory.
    pub fn run<E: StepExecutor + Send>(
        requests: Vec<TokenRequest>,
        executor: E,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        if cfg.workers > 1 {
            bail!(
                "Scheduler::run staffs exactly one worker but cfg.workers = {}; \
                 use WorkerPool::run with an executor factory for sharded serving",
                cfg.workers
            );
        }
        let mut slot = Some(executor);
        let one = ServeCfg { workers: 1, ..cfg.clone() };
        WorkerPool::run(
            requests,
            move |_| slot.take().expect("a one-worker pool builds one executor"),
            &one,
            seed,
        )
    }
}

/// One worker's slice of the pool: its executor, KV-budget share, live
/// set, and compute clock.
struct PoolWorker<E: StepExecutor> {
    executor: E,
    rng: Rng,
    /// this worker's position on the shared virtual timeline
    clock_ms: f64,
    live: Vec<LiveReq>,
    reserved_bytes: usize,
    /// KV-budget share (0 = unlimited)
    budget: usize,
    max_in_flight: usize,
    /// max resident KV bytes observed on this worker
    peak_kv_bytes: usize,
    /// this worker's `executor.live_bytes()` as of its last state change
    /// (admission / round / retirement) — lets the pool sample the total
    /// concurrent residency without re-summing every executor each round
    cached_live_bytes: usize,
    /// a crashed worker stays dead for the rest of the run: it takes no
    /// rounds and steals no admissions; its live set was requeued/failed
    dead: bool,
}

/// What the pool does next: run a decode round on a busy worker, or let
/// the designated stealer admit the queue head.
enum PoolAct {
    Round(usize),
    Admit(usize),
}

/// The sharded serve loop: `cfg.workers` independent scheduler loops over
/// one shared FIFO queue with work-stealing admission. All `ServingEngine`
/// entry points are thin policy wrappers over this run (single-worker via
/// [`Scheduler::run`]).
pub struct WorkerPool;

impl WorkerPool {
    /// `make_executor(worker_index)` is called once per worker; executors
    /// typically share one immutable model reference. When `cfg.fault` is
    /// set, every worker's executor is wrapped in a [`FaultInjector`]
    /// seeded from the plan, so chaos runs reproduce deterministically.
    /// `cfg.threads` picks between the single-thread virtual-clock twin
    /// and the OS-thread pool; both produce identical per-request outputs
    /// and terminal outcome kinds.
    pub fn run<E: StepExecutor + Send, F: FnMut(usize) -> E>(
        mut requests: Vec<TokenRequest>,
        mut make_executor: F,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        Self::validate_cfg(cfg)?;
        // ── admission-time compression routing: Multimodal prompts are
        // token-pruned (IDPruner for the visual segment, SAMP for the
        // audio segment) before they ever reach the queue, so every
        // downstream byte count — projected, admission, live KV — is
        // charged for the pruned prompt, not the raw one.
        let mut pruned_prompt_tokens = 0usize;
        if let Some(pol) = &cfg.classes {
            for r in requests.iter_mut() {
                if let RequestClass::Multimodal { visual_tokens, audio_tokens } = r.class {
                    let (kept, dropped) = prune_multimodal_prompt(
                        &r.prompt,
                        visual_tokens,
                        audio_tokens,
                        pol.multimodal_retain,
                    );
                    r.prompt = kept;
                    pruned_prompt_tokens += dropped;
                }
            }
        }
        match cfg.fault.clone() {
            Some(plan) => {
                plan.validate(cfg.workers.max(1))?;
                let wrapped = move |w| FaultInjector::new(make_executor(w), plan.clone(), w);
                if cfg.threads {
                    Self::run_threaded(requests, wrapped, cfg, seed, pruned_prompt_tokens)
                } else {
                    Self::run_inner(requests, wrapped, cfg, seed, pruned_prompt_tokens)
                }
            }
            None if cfg.threads => {
                Self::run_threaded(requests, make_executor, cfg, seed, pruned_prompt_tokens)
            }
            None => Self::run_inner(requests, make_executor, cfg, seed, pruned_prompt_tokens),
        }
    }

    fn run_inner<E: StepExecutor, F: FnMut(usize) -> E>(
        mut requests: Vec<TokenRequest>,
        mut make_executor: F,
        cfg: &ServeCfg,
        seed: u64,
        pruned_prompt_tokens: usize,
    ) -> Result<ServeReport> {
        Self::validate_cfg(cfg)?;
        let max_attempts = cfg.max_retries.saturating_add(1);
        let mut workers = Self::build_workers(&mut make_executor, cfg, seed);

        let n_submitted = requests.len();
        let t0 = Instant::now();
        // stable sort: FIFO among simultaneous arrivals
        requests.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
        let mut queue: VecDeque<QueuedReq> = requests
            .into_iter()
            .map(|req| QueuedReq { ready_ms: req.arrival_ms, attempt: 1, req })
            .collect();
        let mut ledger = PoolLedger::default();
        let mut crashed_workers: Vec<(usize, String)> = Vec::new();
        let mut peak_kv_bytes = 0usize;
        // running sum of every worker's cached_live_bytes
        let mut pool_live_bytes = 0usize;
        // concurrency sampled once per decode round (and maxed at every
        // admission), pool-wide: the utilization numbers the paged
        // executors are meant to move
        let mut rounds = 0usize;
        let mut in_flight_sum = 0usize;
        let mut peak_in_flight = 0usize;

        loop {
            // ── no worker left alive: shed the remaining queue ───────
            // Even total failure returns a report with every request
            // accounted for, rather than an Err that drops the trace.
            if !queue.is_empty() && workers.iter().all(|w| w.dead) {
                let now = workers.iter().map(|w| w.clock_ms).fold(0.0f64, f64::max);
                Self::shed_queue(&mut queue, now, &mut ledger);
                break;
            }
            // ── earliest next event across workers ───────────────────
            // A busy worker can run a round at its current clock; the
            // designated stealer can admit the queue head at
            // max(its clock, head arrival). The earliest acts; ties go to
            // the stealer so admission lands before the round it feeds
            // (the single-worker loop's admit-then-step order).
            let mut best_busy: Option<usize> = None;
            for (i, w) in workers.iter().enumerate() {
                if w.dead || w.live.is_empty() {
                    continue;
                }
                let earlier = match best_busy {
                    None => true,
                    Some(b) => w.clock_ms < workers[b].clock_ms,
                };
                if earlier {
                    best_busy = Some(i);
                }
            }
            // ── class-priority admission: with a class policy, the entry
            // admission seats next is the highest effective priority, not
            // the FIFO head. Aging is judged against the pool's frontier
            // (the earliest clock any surviving worker could steal at).
            // Static batching keeps FIFO chunks — class selection would
            // tear the chunk apart.
            let head_idx = match cfg.policy {
                AdmissionPolicy::Static => 0,
                _ => {
                    let now_floor = workers
                        .iter()
                        .filter(|w| !w.dead)
                        .map(|w| w.clock_ms)
                        .fold(f64::INFINITY, f64::min);
                    pick_queued(&queue, cfg, now_floor)
                }
            };
            let stealer = Self::pick_stealer(&workers, queue.get(head_idx), cfg.policy);

            let act = match (best_busy, stealer) {
                (None, None) => break, // queue drained, every worker idle
                (Some(b), None) => PoolAct::Round(b),
                (None, Some((s, _))) => PoolAct::Admit(s),
                (Some(b), Some((s, start))) => {
                    if start <= workers[b].clock_ms {
                        PoolAct::Admit(s)
                    } else {
                        PoolAct::Round(b)
                    }
                }
            };

            match act {
                // ── work-stealing admission of the queue head ────────
                PoolAct::Admit(s) => {
                    // deadline guard: a head that would start at or past
                    // its deadline is cancelled instead of admitted, so no
                    // KV or compute is spent on a lost cause
                    let expired_head = queue.get(head_idx).map_or(false, |q| {
                        let start = workers[s].clock_ms.max(q.ready_ms);
                        deadline_abs_of(&q.req, cfg).map_or(false, |d| start >= d)
                    });
                    if expired_head {
                        if let Some(q) = queue.remove(head_idx) {
                            let now = workers[s].clock_ms.max(q.ready_ms);
                            let wait = (now - q.req.arrival_ms).max(0.0);
                            ledger.completed.push(CompletedRequest {
                                id: q.req.id,
                                generated: 0,
                                ttft_ms: wait,
                                total_ms: wait,
                                output: Vec::new(),
                                outcome: RequestOutcome::DeadlineExceeded,
                                attempts: q.attempt - 1,
                                class: q.req.class,
                            });
                        }
                        continue;
                    }
                    match cfg.policy {
                        AdmissionPolicy::Static => Self::admit_static_chunk(
                            &mut workers[s],
                            &mut queue,
                            cfg,
                            &mut ledger,
                        )?,
                        _ => {
                            let w = &mut workers[s];
                            let Some(q) = queue.remove(head_idx) else {
                                bail!(
                                    "scheduler invariant broken: worker {s} designated \
                                     stealer with an empty queue"
                                );
                            };
                            // empty-round jump, multi-worker aware: only the
                            // stealer advances, straight to the ready time it
                            // is about to seat, in O(1)
                            if q.ready_ms > w.clock_ms {
                                w.clock_ms = q.ready_ms;
                            }
                            ledger.admitted_order.push(q.req.id);
                            Self::admit_one(w, q, cfg)?;
                        }
                    }
                    let w = &mut workers[s];
                    let now_bytes = w.executor.live_bytes();
                    pool_live_bytes = pool_live_bytes - w.cached_live_bytes + now_bytes;
                    w.cached_live_bytes = now_bytes;
                    peak_in_flight =
                        peak_in_flight.max(workers.iter().map(|w| w.live.len()).sum());
                }

                // ── one measured decode round on one worker ──────────
                PoolAct::Round(b) => {
                    let live_now: usize = workers.iter().map(|w| w.live.len()).sum();
                    rounds += 1;
                    in_flight_sum += live_now;
                    peak_in_flight = peak_in_flight.max(live_now);
                    let stepped = {
                        let w = &mut workers[b];
                        let round_t0 = Instant::now();
                        let result = w.executor.step_round(&mut w.rng, w.clock_ms);
                        // stall injection/observation inflates the clock on
                        // top of the measured compute
                        w.clock_ms += round_t0.elapsed().as_secs_f64() * 1e3
                            + w.executor.take_stall_ms();
                        result
                    };
                    let events = match stepped {
                        Ok(events) => events,
                        Err(err) => {
                            // ── whole-worker crash, contained at the pool:
                            // the worker is dead for the rest of the run;
                            // its live set re-enters the shared queue (with
                            // backoff) or fails, and survivors absorb it
                            // through normal work-stealing admission.
                            let w = &mut workers[b];
                            pool_live_bytes -= w.cached_live_bytes;
                            w.cached_live_bytes = 0;
                            let msg = Self::contain_crash(
                                b,
                                w,
                                err,
                                &mut queue,
                                &mut ledger,
                                cfg,
                                max_attempts,
                            );
                            crashed_workers.push((b, msg));
                            continue;
                        }
                    };
                    let w = &mut workers[b];
                    // pool-wide concurrent residency, sampled post-round /
                    // pre-retirement: other workers' caches are current
                    // (refreshed on their every admission/round), so only
                    // worker b needs a fresh read
                    let round_bytes = w.executor.live_bytes();
                    peak_kv_bytes = peak_kv_bytes
                        .max(pool_live_bytes - w.cached_live_bytes + round_bytes);
                    w.peak_kv_bytes = w.peak_kv_bytes.max(round_bytes);

                    // retire finished, book metrics on this worker's clock
                    Self::apply_round_events(
                        b,
                        w,
                        events,
                        &mut queue,
                        &mut ledger,
                        cfg,
                        max_attempts,
                    )?;
                    // refresh the cache post-retirement so the next
                    // sample sees the freed bytes
                    let now_bytes = w.executor.live_bytes();
                    pool_live_bytes = pool_live_bytes - w.cached_live_bytes + now_bytes;
                    w.cached_live_bytes = now_bytes;
                }
            }
        }

        let completed = Self::finalize_completed(ledger.completed, n_submitted)?;
        let makespan_ms = workers
            .iter()
            .map(|w| w.clock_ms)
            .fold(0.0f64, f64::max);
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            makespan_ms,
            total_tokens: ledger.total_tokens,
            mean_al: if ledger.al_den == 0.0 {
                0.0
            } else {
                ledger.al_num / ledger.al_den
            },
            proposed: ledger.proposed,
            accepted: ledger.accepted,
            peak_kv_bytes,
            worker_peak_kv_bytes: workers.iter().map(|w| w.peak_kv_bytes).collect(),
            crashed_workers,
            peak_in_flight,
            mean_in_flight: if rounds == 0 {
                0.0
            } else {
                in_flight_sum as f64 / rounds as f64
            },
            pruned_prompt_tokens,
            sparse_prefills: workers.iter().map(|w| w.executor.sparse_prefills()).sum(),
            admitted_order: ledger.admitted_order,
        })
    }

    /// Config validation shared by both pool modes.
    fn validate_cfg(cfg: &ServeCfg) -> Result<()> {
        let n_workers = cfg.workers.max(1);
        if let Some(policy) = &cfg.classes {
            policy.validate()?;
        }
        if let Some(d) = cfg.deadline_ms {
            if d.is_nan() || d <= 0.0 {
                bail!(
                    "serve.deadline_ms must be > 0 when set, got {d}; \
                     drop the knob for no deadline"
                );
            }
        }
        if cfg.retry_backoff_ms.is_nan() || cfg.retry_backoff_ms < 0.0 {
            bail!(
                "serve.retry_backoff_ms must be a non-negative number, got {}",
                cfg.retry_backoff_ms
            );
        }
        if !cfg.max_backoff_ms.is_finite() || cfg.max_backoff_ms < 0.0 {
            bail!(
                "serve.max_backoff_ms must be a finite non-negative number, got {} \
                 (the cap is what keeps exponential retry backoff admissible)",
                cfg.max_backoff_ms
            );
        }
        if cfg.kv_budget_bytes > 0 && cfg.kv_budget_bytes < n_workers {
            // enforced here as well as at config validation: a split that
            // leaves any worker a zero share would make that worker
            // silently unlimited and the pool's resident KV could exceed
            // the configured total
            bail!(
                "kv_budget_bytes = {} splits to zero across {n_workers} workers; \
                 raise the budget, reduce workers, or set 0 for unlimited",
                cfg.kv_budget_bytes
            );
        }
        Ok(())
    }

    /// Staff the pool: one executor, RNG stream, KV-budget share, and
    /// clock per worker — identical staffing in both pool modes.
    fn build_workers<E: StepExecutor, F: FnMut(usize) -> E>(
        make_executor: &mut F,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Vec<PoolWorker<E>> {
        let n_workers = cfg.workers.max(1);
        let budgets = cfg.per_worker_budgets();
        (0..n_workers)
            .map(|w| {
                let executor = make_executor(w);
                let mut max_in_flight = match cfg.policy {
                    AdmissionPolicy::Sequential => 1,
                    _ => cfg.max_in_flight.max(1),
                };
                if let Some(cap) = executor.slot_cap() {
                    max_in_flight = max_in_flight.min(cap.max(1));
                }
                PoolWorker {
                    executor,
                    // worker 0 keeps the bare seed, so a one-worker pool is
                    // bit-identical to the historical single scheduler
                    rng: Rng::new(seed ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                    clock_ms: 0.0,
                    live: Vec::new(),
                    reserved_bytes: 0,
                    budget: budgets[w],
                    max_in_flight,
                    peak_kv_bytes: 0,
                    cached_live_bytes: 0,
                    dead: false,
                }
            })
            .collect()
    }

    /// Apply one round's events to worker `b`, then run its between-round
    /// deadline sweep. This is the pool's entire per-request outcome
    /// logic, shared verbatim between the single-thread twin and the
    /// threaded pool (which calls it under the shared lock): fault
    /// containment, retry backoff, preemption-livelock detection,
    /// retirement, and deadline cancellation classify identically in both
    /// modes.
    fn apply_round_events<E: StepExecutor>(
        b: usize,
        w: &mut PoolWorker<E>,
        events: Vec<StepEvent>,
        queue: &mut VecDeque<QueuedReq>,
        ledger: &mut PoolLedger,
        cfg: &ServeCfg,
        max_attempts: usize,
    ) -> Result<()> {
        let now = w.clock_ms;
        for ev in events {
            let Some(idx) = w.live.iter().position(|l| l.req.id == ev.id) else {
                bail!(
                    "scheduler invariant broken on worker {b}: step event \
                     for request {} that was never admitted there",
                    ev.id
                );
            };
            // ── contained per-request fault: evict, retry/fail ──
            if let Some(fault) = ev.fault {
                let l = w.live.swap_remove(idx);
                w.executor.retire(l.req.id);
                w.reserved_bytes -= l.reserved_bytes;
                // a preemption (paged executor freeing pages
                // for another live request) is a scheduling
                // decision, not a failure: requeue with no
                // backoff and never convert it to `Failed`.
                // The attempt number still advances so the
                // fault injector keys fresh draws.
                if fault == StepFault::Preempted {
                    // ── no-progress cycle detector: preemptions never
                    // count against max_retries, so a request whose KV
                    // growth can never fit must be failed here or it
                    // would requeue forever ──
                    let done_now = ledger.completed.len();
                    let cell = ledger
                        .preempt_cycles
                        .entry(l.req.id)
                        .or_insert((done_now, 0));
                    if cell.0 == done_now {
                        cell.1 += 1;
                    } else {
                        *cell = (done_now, 1);
                    }
                    if cell.1 > MAX_NO_PROGRESS_PREEMPT_CYCLES {
                        let cycles = cell.1;
                        ledger.completed.push(CompletedRequest {
                            id: l.req.id,
                            generated: 0,
                            ttft_ms: (l.first_token_ms.unwrap_or(now) - l.req.arrival_ms)
                                .max(0.0),
                            total_ms: (now - l.req.arrival_ms).max(0.0),
                            output: Vec::new(),
                            outcome: RequestOutcome::Failed {
                                error: format!(
                                    "request {} preempted {cycles} consecutive times \
                                     with no pool-wide completion — {}: its decode \
                                     growth cannot fit the block pool; raise \
                                     kv_budget_bytes or lower max_new_tokens",
                                    l.req.id, POOL_EXHAUSTED_PREFIX
                                ),
                            },
                            attempts: l.attempts,
                            class: l.req.class,
                        });
                        continue;
                    }
                    queue.push_back(QueuedReq {
                        ready_ms: now,
                        attempt: l.attempts + 1,
                        req: l.req,
                    });
                    continue;
                }
                if l.attempts < max_attempts {
                    let backoff = retry_backoff(cfg, l.attempts);
                    queue.push_back(QueuedReq {
                        ready_ms: now + backoff,
                        attempt: l.attempts + 1,
                        req: l.req,
                    });
                } else {
                    ledger.completed.push(CompletedRequest {
                        id: l.req.id,
                        generated: 0,
                        ttft_ms: (l.first_token_ms.unwrap_or(now) - l.req.arrival_ms)
                            .max(0.0),
                        total_ms: (now - l.req.arrival_ms).max(0.0),
                        output: Vec::new(),
                        outcome: RequestOutcome::Failed {
                            error: format!(
                                "request {} on worker {b}: {}",
                                l.req.id,
                                fault.describe()
                            ),
                        },
                        attempts: l.attempts,
                        class: l.req.class,
                    });
                }
                continue;
            }
            {
                let l = &mut w.live[idx];
                debug_assert!(
                    matches!(l.state, ReqState::Prefill | ReqState::Decoding),
                    "step event for a request outside Prefill/Decoding"
                );
                if !ev.tokens.is_empty() {
                    if l.first_token_ms.is_none() {
                        l.first_token_ms = Some(now);
                    }
                    l.state = ReqState::Decoding;
                }
                ledger.total_tokens += ev.tokens.len();
                ledger.al_num += ev.tokens.len() as f64;
                ledger.al_den += ev.steps as f64;
                ledger.proposed += ev.proposed;
                ledger.accepted += ev.accepted;
                l.output.extend_from_slice(&ev.tokens);
            }
            if ev.finished {
                let l = w.live.swap_remove(idx);
                w.executor.retire(l.req.id);
                w.reserved_bytes -= l.reserved_bytes;
                ledger.completed.push(CompletedRequest {
                    id: l.req.id,
                    generated: l.output.len(),
                    ttft_ms: l.first_token_ms.unwrap_or(now) - l.req.arrival_ms,
                    total_ms: now - l.req.arrival_ms,
                    output: l.output,
                    outcome: RequestOutcome::Completed,
                    attempts: l.attempts,
                    class: l.req.class,
                });
            }
        }
        // ── deadline sweep between rounds on this worker's
        // clock: cancel past-deadline requests, keep partial
        // output, evict KV immediately ──
        let mut i = 0;
        while i < w.live.len() {
            let expired = w.live[i].deadline_abs.map_or(false, |d| w.clock_ms >= d);
            if !expired {
                i += 1;
                continue;
            }
            let l = w.live.swap_remove(i);
            w.executor.retire(l.req.id);
            w.reserved_bytes -= l.reserved_bytes;
            ledger.completed.push(CompletedRequest {
                id: l.req.id,
                generated: l.output.len(),
                ttft_ms: (l.first_token_ms.unwrap_or(w.clock_ms) - l.req.arrival_ms)
                    .max(0.0),
                total_ms: (w.clock_ms - l.req.arrival_ms).max(0.0),
                output: l.output,
                outcome: RequestOutcome::DeadlineExceeded,
                attempts: l.attempts,
                class: l.req.class,
            });
        }
        Ok(())
    }

    /// Whole-worker crash containment, shared by both modes: mark the
    /// worker dead, requeue its live set with backoff (or fail requests
    /// out of attempts), and return the crash message for the report.
    /// Pool residency bookkeeping (`pool_live_bytes`) is the caller's
    /// job, since it lives in different places per mode.
    fn contain_crash<E: StepExecutor>(
        b: usize,
        w: &mut PoolWorker<E>,
        err: anyhow::Error,
        queue: &mut VecDeque<QueuedReq>,
        ledger: &mut PoolLedger,
        cfg: &ServeCfg,
        max_attempts: usize,
    ) -> String {
        w.dead = true;
        let msg = match err.downcast_ref::<WorkerCrash>() {
            Some(c) => c.to_string(),
            None => format!("{err:#}"),
        };
        w.reserved_bytes = 0;
        let now = w.clock_ms;
        for l in std::mem::take(&mut w.live) {
            w.executor.retire(l.req.id);
            if l.attempts < max_attempts {
                let backoff = retry_backoff(cfg, l.attempts);
                queue.push_back(QueuedReq {
                    ready_ms: now + backoff,
                    attempt: l.attempts + 1,
                    req: l.req,
                });
            } else {
                ledger.completed.push(CompletedRequest {
                    id: l.req.id,
                    generated: 0,
                    ttft_ms: (l.first_token_ms.unwrap_or(now) - l.req.arrival_ms).max(0.0),
                    total_ms: (now - l.req.arrival_ms).max(0.0),
                    output: Vec::new(),
                    outcome: RequestOutcome::Failed {
                        error: format!(
                            "request {} lost: worker {b} crashed: {msg}",
                            l.req.id
                        ),
                    },
                    attempts: l.attempts,
                    class: l.req.class,
                });
            }
        }
        msg
    }

    /// Account every still-queued request as `Shed` at time `now` — the
    /// all-workers-dead drain, shared by both modes so even total failure
    /// returns a report with every request accounted for.
    fn shed_queue(queue: &mut VecDeque<QueuedReq>, now: f64, ledger: &mut PoolLedger) {
        for q in queue.drain(..) {
            let wait = (now - q.req.arrival_ms).max(0.0);
            ledger.completed.push(CompletedRequest {
                id: q.req.id,
                generated: 0,
                ttft_ms: wait,
                total_ms: wait,
                output: Vec::new(),
                outcome: RequestOutcome::Shed,
                attempts: q.attempt - 1,
                class: q.req.class,
            });
        }
    }

    /// Exactly-once invariants + stable id order, shared by both modes.
    fn finalize_completed(
        mut completed: Vec<CompletedRequest>,
        n_submitted: usize,
    ) -> Result<Vec<CompletedRequest>> {
        if completed.len() != n_submitted {
            bail!(
                "scheduler invariant broken: {} of {n_submitted} requests reached a \
                 terminal outcome",
                completed.len()
            );
        }
        completed.sort_by_key(|c| c.id);
        for pair in completed.windows(2) {
            if pair[0].id == pair[1].id {
                bail!(
                    "scheduler invariant broken: request {} has more than one \
                     terminal outcome",
                    pair[0].id
                );
            }
        }
        Ok(completed)
    }

    /// Threaded-mode room check for the queue head on one worker — the
    /// per-worker body of [`Self::pick_stealer`]'s `has_room`. The
    /// oversized valve is per-share here: a head larger than this
    /// worker's budget share only seats alone. In the twin the valve
    /// engages when the head fits *no* worker; shares are split evenly,
    /// so the two conditions coincide (modulo the ±1-byte remainder
    /// spread), and per-share is the conservative direction — it never
    /// admits a head the twin's valve would have held back.
    fn has_room<E: StepExecutor>(
        w: &PoolWorker<E>,
        head: &QueuedReq,
        policy: AdmissionPolicy,
    ) -> bool {
        if w.dead {
            return false;
        }
        match policy {
            // a static chunk only forms on a drained worker
            AdmissionPolicy::Static => w.live.is_empty(),
            _ => {
                if w.live.len() >= w.max_in_flight {
                    false
                } else if w.budget != 0
                    && w.executor.admission_bytes(&head.req) > w.budget
                {
                    w.live.is_empty()
                } else if w.budget == 0 {
                    true
                } else {
                    match w.executor.free_capacity_bytes() {
                        // free-block admission: gate on the pages the
                        // pool can hand out *now*, not a reservation
                        Some(free) => w.executor.admission_bytes(&head.req) <= free,
                        None => {
                            w.reserved_bytes + w.executor.admission_bytes(&head.req)
                                <= w.budget
                        }
                    }
                }
            }
        }
    }

    /// The OS-thread pool: the same shared-FIFO scheduler run on real
    /// threads, one per worker. The queue, outcome ledger, and pool-wide
    /// bookkeeping live behind one mutex+condvar; decode rounds run with
    /// the lock released, and every admission/outcome decision goes
    /// through the exact handlers the single-thread twin uses, so
    /// per-request outputs and terminal outcome kinds are identical
    /// across modes — only the timing fields measure real parallel wall
    /// clock here instead of the virtual interleaving.
    fn run_threaded<E, F>(
        mut requests: Vec<TokenRequest>,
        mut make_executor: F,
        cfg: &ServeCfg,
        seed: u64,
        pruned_prompt_tokens: usize,
    ) -> Result<ServeReport>
    where
        E: StepExecutor + Send,
        F: FnMut(usize) -> E,
    {
        Self::validate_cfg(cfg)?;
        let n_workers = cfg.workers.max(1);
        let max_attempts = cfg.max_retries.saturating_add(1);
        let workers = Self::build_workers(&mut make_executor, cfg, seed);

        let n_submitted = requests.len();
        let t0 = Instant::now();
        // stable sort: FIFO among simultaneous arrivals
        requests.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
        let queue: VecDeque<QueuedReq> = requests
            .into_iter()
            .map(|req| QueuedReq { ready_ms: req.arrival_ms, attempt: 1, req })
            .collect();
        let sync = (
            Mutex::new(ThreadShared {
                queue,
                ledger: PoolLedger::default(),
                crashed_workers: Vec::new(),
                live_counts: vec![0; n_workers],
                cached_live_bytes: vec![0; n_workers],
                clocks: vec![0.0; n_workers],
                worker_peaks: vec![0; n_workers],
                sparse_prefills: vec![0; n_workers],
                pool_live_bytes: 0,
                peak_kv_bytes: 0,
                rounds: 0,
                in_flight_sum: 0,
                peak_in_flight: 0,
                alive: n_workers,
                idle_spins: 0,
                done: false,
                fatal: None,
            }),
            Condvar::new(),
        );

        std::thread::scope(|s| {
            for (i, w) in workers.into_iter().enumerate() {
                let sync = &sync;
                s.spawn(move || Self::worker_thread(i, w, sync, cfg, max_attempts));
            }
        });

        let shared = match sync.0.into_inner() {
            Ok(sh) => sh,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(err) = shared.fatal {
            return Err(err);
        }
        let completed = Self::finalize_completed(shared.ledger.completed, n_submitted)?;
        let makespan_ms = shared.clocks.iter().copied().fold(0.0f64, f64::max);
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            makespan_ms,
            total_tokens: shared.ledger.total_tokens,
            mean_al: if shared.ledger.al_den == 0.0 {
                0.0
            } else {
                shared.ledger.al_num / shared.ledger.al_den
            },
            proposed: shared.ledger.proposed,
            accepted: shared.ledger.accepted,
            peak_kv_bytes: shared.peak_kv_bytes,
            worker_peak_kv_bytes: shared.worker_peaks,
            crashed_workers: shared.crashed_workers,
            peak_in_flight: shared.peak_in_flight,
            mean_in_flight: if shared.rounds == 0 {
                0.0
            } else {
                shared.in_flight_sum as f64 / shared.rounds as f64
            },
            pruned_prompt_tokens,
            sparse_prefills: shared.sparse_prefills.iter().sum(),
            admitted_order: shared.ledger.admitted_order,
        })
    }

    /// One pool worker's thread body. Mirrors the twin's loop shape:
    /// admit from the shared FIFO (strict head-only order, this worker's
    /// room rules, deadline guard on the head), run one decode round with
    /// the lock released, apply the round's events under the lock through
    /// the shared handlers. A crash kills only this thread: its live set
    /// is requeued/failed by [`Self::contain_crash`] and survivors absorb
    /// the load; the last dying worker sheds the remaining queue.
    fn worker_thread<E: StepExecutor>(
        i: usize,
        mut w: PoolWorker<E>,
        sync: &(Mutex<ThreadShared>, Condvar),
        cfg: &ServeCfg,
        max_attempts: usize,
    ) {
        let (lock, cv) = sync;
        let mut guard = match lock.lock() {
            // a poisoned lock means a peer thread panicked; the scope
            // propagates that panic, so just stand down
            Ok(g) => g,
            Err(_) => return,
        };
        loop {
            if guard.done || guard.fatal.is_some() {
                cv.notify_all();
                return;
            }
            // ── admission from the shared queue: strict FIFO without a
            // class policy, class-priority selection with one (aging
            // judged on this worker's clock) ──────────────────────────
            loop {
                let head_idx = match cfg.policy {
                    AdmissionPolicy::Static => 0,
                    _ => pick_queued(&guard.queue, cfg, w.clock_ms),
                };
                // deadline guard: a head that would start at or past its
                // deadline is cancelled instead of admitted (twin rule)
                let expired = guard.queue.get(head_idx).map_or(false, |q| {
                    let start = w.clock_ms.max(q.ready_ms);
                    deadline_abs_of(&q.req, cfg).map_or(false, |d| start >= d)
                });
                if expired {
                    if let Some(q) = guard.queue.remove(head_idx) {
                        let now = w.clock_ms.max(q.ready_ms);
                        let wait = (now - q.req.arrival_ms).max(0.0);
                        guard.ledger.completed.push(CompletedRequest {
                            id: q.req.id,
                            generated: 0,
                            ttft_ms: wait,
                            total_ms: wait,
                            output: Vec::new(),
                            outcome: RequestOutcome::DeadlineExceeded,
                            attempts: q.attempt - 1,
                            class: q.req.class,
                        });
                        guard.idle_spins = 0;
                    }
                    continue;
                }
                let admissible = match guard.queue.get(head_idx) {
                    None => false,
                    Some(head) => Self::has_room(&w, head, cfg.policy),
                };
                if !admissible {
                    break;
                }
                match cfg.policy {
                    AdmissionPolicy::Static => {
                        let sh = &mut *guard;
                        if let Err(e) = Self::admit_static_chunk(
                            &mut w,
                            &mut sh.queue,
                            cfg,
                            &mut sh.ledger,
                        ) {
                            guard.fatal = Some(e);
                            guard.done = true;
                            cv.notify_all();
                            return;
                        }
                    }
                    _ => {
                        let Some(q) = guard.queue.remove(head_idx) else { break };
                        // idle/earliest-start jump, straight to the ready
                        // time this worker is about to seat
                        if q.ready_ms > w.clock_ms {
                            w.clock_ms = q.ready_ms;
                        }
                        guard.ledger.admitted_order.push(q.req.id);
                        if let Err(e) = Self::admit_one(&mut w, q, cfg) {
                            guard.fatal = Some(e);
                            guard.done = true;
                            cv.notify_all();
                            return;
                        }
                    }
                }
                guard.idle_spins = 0;
                let now_bytes = w.executor.live_bytes();
                guard.pool_live_bytes =
                    guard.pool_live_bytes - guard.cached_live_bytes[i] + now_bytes;
                guard.cached_live_bytes[i] = now_bytes;
                guard.live_counts[i] = w.live.len();
                guard.clocks[i] = w.clock_ms;
                let live_now: usize = guard.live_counts.iter().sum();
                guard.peak_in_flight = guard.peak_in_flight.max(live_now);
                if matches!(cfg.policy, AdmissionPolicy::Static) {
                    break; // one chunk per drained worker, as in the twin
                }
            }

            if !w.live.is_empty() {
                // ── one decode round, lock released ──────────────────
                guard.rounds += 1;
                let live_now: usize = guard.live_counts.iter().sum();
                guard.in_flight_sum += live_now;
                guard.peak_in_flight = guard.peak_in_flight.max(live_now);
                drop(guard);
                let round_t0 = Instant::now();
                let stepped = w.executor.step_round(&mut w.rng, w.clock_ms);
                // stall injection/observation inflates the clock on top
                // of the measured compute
                w.clock_ms +=
                    round_t0.elapsed().as_secs_f64() * 1e3 + w.executor.take_stall_ms();
                guard = match lock.lock() {
                    Ok(g) => g,
                    Err(_) => return,
                };
                guard.clocks[i] = w.clock_ms;
                match stepped {
                    Ok(events) => {
                        // pool-wide concurrent residency, sampled
                        // post-round / pre-retirement
                        let round_bytes = w.executor.live_bytes();
                        let others = guard.pool_live_bytes - guard.cached_live_bytes[i];
                        guard.peak_kv_bytes = guard.peak_kv_bytes.max(others + round_bytes);
                        w.peak_kv_bytes = w.peak_kv_bytes.max(round_bytes);
                        guard.worker_peaks[i] = w.peak_kv_bytes;
                        let sh = &mut *guard;
                        if let Err(e) = Self::apply_round_events(
                            i,
                            &mut w,
                            events,
                            &mut sh.queue,
                            &mut sh.ledger,
                            cfg,
                            max_attempts,
                        ) {
                            sh.fatal = Some(e);
                            sh.done = true;
                            cv.notify_all();
                            return;
                        }
                        let now_bytes = w.executor.live_bytes();
                        guard.pool_live_bytes =
                            guard.pool_live_bytes - guard.cached_live_bytes[i] + now_bytes;
                        guard.cached_live_bytes[i] = now_bytes;
                        guard.live_counts[i] = w.live.len();
                        guard.sparse_prefills[i] = w.executor.sparse_prefills();
                        guard.idle_spins = 0;
                        // wake idle peers: retirements may have freed
                        // room, requeues may have repopulated the head
                        cv.notify_all();
                    }
                    Err(err) => {
                        // ── whole-worker crash = this thread dies ────
                        guard.pool_live_bytes -= guard.cached_live_bytes[i];
                        guard.cached_live_bytes[i] = 0;
                        guard.live_counts[i] = 0;
                        guard.sparse_prefills[i] = w.executor.sparse_prefills();
                        let sh = &mut *guard;
                        let msg = Self::contain_crash(
                            i,
                            &mut w,
                            err,
                            &mut sh.queue,
                            &mut sh.ledger,
                            cfg,
                            max_attempts,
                        );
                        sh.crashed_workers.push((i, msg));
                        sh.alive -= 1;
                        if sh.alive == 0 && !sh.queue.is_empty() {
                            // last worker standing just died: shed what's
                            // left so every request stays accounted for
                            let now = sh.clocks.iter().copied().fold(0.0f64, f64::max);
                            Self::shed_queue(&mut sh.queue, now, &mut sh.ledger);
                        }
                        cv.notify_all();
                        return;
                    }
                }
                continue;
            }

            // ── idle: terminate, or wait for work / peer progress ────
            let live_total: usize = guard.live_counts.iter().sum();
            if guard.queue.is_empty() && live_total == 0 {
                guard.done = true;
                cv.notify_all();
                return;
            }
            if live_total == 0 && !guard.queue.is_empty() {
                // every worker idle yet nobody admitted the head: spin a
                // bounded number of times so an impossible head becomes a
                // loud invariant error, not a silent hang (the twin's
                // equivalent ends in its terminal-outcome-count bail)
                guard.idle_spins += 1;
                if guard.idle_spins > 50_000 {
                    guard.fatal = Some(anyhow!(
                        "threaded pool stuck: no worker can admit the queue head \
                         ({} queued, {} of {} workers alive)",
                        guard.queue.len(),
                        guard.alive,
                        guard.live_counts.len()
                    ));
                    guard.done = true;
                    cv.notify_all();
                    return;
                }
            }
            guard = match cv.wait_timeout(guard, Duration::from_millis(1)) {
                Ok((g, _)) => g,
                Err(_) => return,
            };
        }
    }

    /// The worker that should admit the queue head, and when it could
    /// start it: the minimum over workers with room of
    /// `max(worker clock, arrival)` (ties → fewest live, then index).
    /// `None` while no worker has room — the head then waits, strictly
    /// FIFO, for the next retirement; admission never skips past it.
    ///
    /// Admitting at that minimum is safe: any worker currently without
    /// room frees it no earlier than its own clock, which is never below
    /// the chosen start (the pool always acts on the earliest event
    /// first), so no deferred assignment could start the head sooner.
    fn pick_stealer<E: StepExecutor>(
        workers: &[PoolWorker<E>],
        head: Option<&QueuedReq>,
        policy: AdmissionPolicy,
    ) -> Option<(usize, f64)> {
        let head = head?;
        // oversized-request safety valve, pool edition: a head that fits
        // no surviving worker's budget share can only ever run alone, so
        // it becomes admissible exactly on idle workers
        let fits_nowhere = workers.iter().filter(|w| !w.dead).all(|w| {
            w.budget != 0 && w.executor.admission_bytes(&head.req) > w.budget
        });
        let mut best: Option<(usize, f64, usize)> = None;
        for (i, w) in workers.iter().enumerate() {
            if w.dead {
                continue;
            }
            let has_room = match policy {
                // a static chunk only forms on a drained worker
                AdmissionPolicy::Static => w.live.is_empty(),
                _ => {
                    if w.live.len() >= w.max_in_flight {
                        false
                    } else if fits_nowhere {
                        w.live.is_empty()
                    } else if w.budget == 0 {
                        true
                    } else {
                        match w.executor.free_capacity_bytes() {
                            // free-block admission: gate on the pages the
                            // pool can hand out *now*, not a reservation
                            Some(free) => {
                                w.executor.admission_bytes(&head.req) <= free
                            }
                            None => {
                                w.reserved_bytes
                                    + w.executor.admission_bytes(&head.req)
                                    <= w.budget
                            }
                        }
                    }
                }
            };
            if !has_room {
                continue;
            }
            let start = w.clock_ms.max(head.ready_ms);
            let better = match best {
                None => true,
                Some((_, bs, bl)) => {
                    start < bs || (start == bs && w.live.len() < bl)
                }
            };
            if better {
                best = Some((i, start, w.live.len()));
            }
        }
        best.map(|(i, s, _)| (i, s))
    }

    /// Admit one request to `w`. Reservation-based executors reserve the
    /// request's admission bytes against the worker share; free-block
    /// executors reserve nothing — their pool is the live source of truth.
    fn admit_one<E: StepExecutor>(
        w: &mut PoolWorker<E>,
        q: QueuedReq,
        cfg: &ServeCfg,
    ) -> Result<()> {
        let need = if w.executor.free_capacity_bytes().is_some() {
            0
        } else {
            w.executor.admission_bytes(&q.req)
        };
        w.executor.note_attempt(q.req.id, q.attempt);
        w.executor.admit(&q.req)?;
        w.reserved_bytes += need;
        let deadline_abs = deadline_abs_of(&q.req, cfg);
        w.live.push(LiveReq {
            state: ReqState::Prefill,
            output: Vec::new(),
            first_token_ms: None,
            reserved_bytes: need,
            attempts: q.attempt,
            deadline_abs,
            req: q.req,
        });
        Ok(())
    }

    /// Classic static batching on one drained worker: jump the clock to
    /// the last arrival of the requests the next chunk can actually seat
    /// (slot cap AND KV-budget share), then admit the whole chunk — so
    /// chunks neither degenerate to size 1 on staggered traces nor wait
    /// for arrivals the budget could never seat.
    fn admit_static_chunk<E: StepExecutor>(
        w: &mut PoolWorker<E>,
        queue: &mut VecDeque<QueuedReq>,
        cfg: &ServeCfg,
        ledger: &mut PoolLedger,
    ) -> Result<()> {
        let mut k = 0usize;
        let mut sum = 0usize;
        for q in queue.iter().take(w.max_in_flight) {
            let need = w.executor.admission_bytes(&q.req);
            let fits = w.budget == 0
                || sum + need <= w.budget
                || (k == 0 && need > w.budget);
            if !fits {
                break;
            }
            sum += need;
            k += 1;
        }
        let chunk_ready = queue
            .iter()
            .take(k)
            .map(|q| q.ready_ms)
            .fold(f64::NEG_INFINITY, f64::max);
        if chunk_ready > w.clock_ms {
            w.clock_ms = chunk_ready;
        }
        for _ in 0..k {
            let Some(q) = queue.pop_front() else {
                bail!("scheduler invariant broken: static chunk outran the queue");
            };
            ledger.admitted_order.push(q.req.id);
            Self::admit_one(w, q, cfg)?;
        }
        Ok(())
    }
}

// ─────────────────────────────────────────────────────────────────────
// Executors
// ─────────────────────────────────────────────────────────────────────

struct GreedySlot<T: SessionModel> {
    id: u64,
    prompt: Vec<u8>,
    sess: T::Session,
    /// tokens still to generate; 0 at admission means the request can
    /// never start (empty prompt / no context room) and finishes empty
    remaining: usize,
    last: Option<Vec<f32>>,
    /// route the prompt prefill through the sparse-attention path
    /// (LongContext class under a class policy); decode is untouched
    sparse: bool,
}

/// Greedy KV-session decoding: per request, one prompt prefill then one
/// cached decode step per round — per-request output bit-identical to
/// `VanillaDecoder` (and to the old static `serve_batched` loop).
pub struct GreedyExecutor<'a, T: SessionModel> {
    model: &'a T,
    sampler: Sampler,
    slots: Vec<GreedySlot<T>>,
    /// class policy for admission-time compression routing: LongContext
    /// prompts prefill through the STeM-masked sparse path
    classes: Option<ClassPolicy>,
    sparse_prefills: usize,
}

impl<'a, T: SessionModel> GreedyExecutor<'a, T> {
    pub fn new(model: &'a T) -> Self {
        GreedyExecutor {
            model,
            sampler: Sampler::Greedy,
            slots: Vec::new(),
            classes: None,
            sparse_prefills: 0,
        }
    }

    /// Enable class-based compression routing (no-op when `None`).
    pub fn with_class_policy(mut self, classes: Option<ClassPolicy>) -> Self {
        self.classes = classes;
        self
    }

    /// Most tokens this request's session can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.model.max_t())
    }
}

impl<T: SessionModel> StepExecutor for GreedyExecutor<'_, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req) * self.model.kv_bytes_per_token()
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.model.max_t().saturating_sub(req.prompt.len()))
        };
        self.slots.push(GreedySlot {
            id: req.id,
            prompt: req.prompt.clone(),
            // sized to the projected peak, so the session's resident
            // allocation is what admission reserved against the budget
            sess: self.model.new_session_bounded(self.peak_tokens(req)),
            remaining: budget,
            last: None,
            sparse: self.classes.is_some()
                && matches!(req.class, RequestClass::LongContext)
                && req.prompt.len() > 1,
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
        let model = self.model;
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            if slot.remaining == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                    fault: None,
                });
                continue;
            }
            // Prefill state: the first round feeds the whole prompt —
            // through the STeM-masked sparse path for LongContext slots
            // whose session supports it (prefill-compute savings; decode
            // stays dense). Per-slot errors are contained as
            // request-level faults — one poisoned request must not take
            // down the batch.
            if slot.last.is_none() {
                let fed = if slot.sparse && slot.sess.sparse_prefill_capable() {
                    let pol = self.classes.as_ref().expect("sparse slot implies a policy");
                    self.sparse_prefills += 1;
                    slot.sess.extend_sparse(
                        model,
                        &slot.prompt,
                        pol.sparse_block,
                        pol.sparse_budget,
                    )
                } else {
                    slot.sess.extend(model, &slot.prompt)
                };
                match fed {
                    Ok(mut rows) => slot.last = rows.pop(),
                    Err(e) => {
                        events.push(StepEvent::faulted(
                            slot.id,
                            StepFault::Error(format!(
                                "request {}: prompt prefill failed: {e:#}",
                                slot.id
                            )),
                        ));
                        continue;
                    }
                }
            }
            let next = match slot.last.as_ref() {
                Some(row) if row.iter().all(|x| x.is_finite()) => {
                    self.sampler.sample(row, rng)
                }
                Some(_) => {
                    events.push(StepEvent::faulted(slot.id, StepFault::NanLogits));
                    continue;
                }
                None => {
                    events.push(StepEvent::faulted(
                        slot.id,
                        StepFault::Error(format!(
                            "request {}: prefill produced no logits row",
                            slot.id
                        )),
                    ));
                    continue;
                }
            };
            slot.remaining -= 1;
            let finished = slot.remaining == 0;
            // like VanillaDecoder, the final committed token is never fed back
            slot.last = if finished {
                None
            } else {
                match slot.sess.extend(model, &[next]) {
                    Ok(mut rows) => match rows.pop() {
                        Some(row) => Some(row),
                        None => {
                            events.push(StepEvent::faulted(
                                slot.id,
                                StepFault::Error(format!(
                                    "request {}: decode step produced no logits row",
                                    slot.id
                                )),
                            ));
                            continue;
                        }
                    },
                    Err(e) => {
                        events.push(StepEvent::faulted(
                            slot.id,
                            StepFault::Error(format!(
                                "request {}: decode step failed: {e:#}",
                                slot.id
                            )),
                        ));
                        continue;
                    }
                }
            };
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
                fault: None,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.sess.kv_bytes()).sum()
    }

    fn sparse_prefills(&self) -> usize {
        self.sparse_prefills
    }
}

struct SpecSlot<D: SessionModel, T: SessionModel> {
    id: u64,
    seq: Vec<u8>,
    budget: usize,
    generated: usize,
    dsess: D::Session,
    tsess: T::Session,
}

/// Speculative draft-propose / target-verify decoding threaded through the
/// continuous loop: each request keeps a draft and a target KV session;
/// one round = one verify step (catch-up + γ proposals + bonus), with both
/// caches rolled back to the accepted prefix — per-request output
/// bit-identical to `SpecDecoder::generate`.
pub struct SpecExecutor<'a, D: SessionModel, T: SessionModel> {
    draft: &'a D,
    target: &'a T,
    gamma: usize,
    sampler: Sampler,
    slots: Vec<SpecSlot<D, T>>,
}

impl<'a, D: SessionModel, T: SessionModel> SpecExecutor<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize) -> Self {
        SpecExecutor { draft, target, gamma, sampler: Sampler::Greedy, slots: Vec::new() }
    }

    fn limit(&self) -> usize {
        self.target.max_t().min(self.draft.max_t())
    }

    /// Most tokens this request's sessions can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.limit())
    }
}

impl<D: SessionModel, T: SessionModel> StepExecutor for SpecExecutor<'_, D, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req)
            * (self.target.kv_bytes_per_token() + self.draft.kv_bytes_per_token())
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.limit().saturating_sub(req.prompt.len()))
        };
        let peak_t = self.peak_tokens(req);
        self.slots.push(SpecSlot {
            id: req.id,
            seq: req.prompt.clone(),
            budget,
            generated: 0,
            dsess: self.draft.new_session_bounded(peak_t),
            tsess: self.target.new_session_bounded(peak_t),
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
        let draft = self.draft;
        let target = self.target;
        let gamma = self.gamma;
        let limit = self.limit();
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            // saturating: an over-long prompt admits with budget 0 and the
            // limit term must not underflow before the room hits 0
            let room = limit
                .saturating_sub(slot.seq.len())
                .min(gamma)
                .min(slot.budget.saturating_sub(slot.generated));
            if room == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                    fault: None,
                });
                continue;
            }
            // one shared verify step: draft catch-up + γ proposals, single
            // target pass, greedy acceptance + bonus, rollback — the same
            // function SpecDecoder::generate runs per iteration. A verify
            // error is contained to this request, not the whole batch.
            let step = spec_verify_step(
                draft,
                target,
                &mut slot.dsess,
                &mut slot.tsess,
                &mut slot.seq,
                room,
                slot.budget - slot.generated,
                limit,
                &self.sampler,
                rng,
            );
            let (tokens, proposed, accepted) = match step {
                Ok(v) => v,
                Err(e) => {
                    events.push(StepEvent::faulted(
                        slot.id,
                        StepFault::Error(format!(
                            "request {}: speculative verify step failed: {e:#}",
                            slot.id
                        )),
                    ));
                    continue;
                }
            };
            slot.generated += tokens.len();

            let finished = slot.generated >= slot.budget || slot.seq.len() >= limit;
            events.push(StepEvent {
                id: slot.id,
                tokens,
                steps: 1,
                proposed,
                accepted,
                finished,
                fault: None,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.dsess.kv_bytes() + s.tsess.kv_bytes())
            .sum()
    }
}

struct PjrtSlot {
    id: u64,
    seq: Vec<u8>,
    prompt_len: usize,
    max_new: usize,
}

/// Joint batched greedy decoding over a b>1 PJRT executable: every live
/// request occupies one batch row and the whole set advances one token per
/// joint forward. Slot count is capped by the executable's batch dim.
pub struct PjrtBatchExecutor<'a> {
    exe: &'a ModelExecutable,
    slots: Vec<PjrtSlot>,
}

impl<'a> PjrtBatchExecutor<'a> {
    pub fn new(exe: &'a ModelExecutable) -> Self {
        PjrtBatchExecutor { exe, slots: Vec::new() }
    }
}

impl StepExecutor for PjrtBatchExecutor<'_> {
    fn projected_bytes(&self, _req: &TokenRequest) -> usize {
        0 // the executable re-forwards per round; no resident KV state
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        self.slots.push(PjrtSlot {
            id: req.id,
            seq: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
        });
        Ok(())
    }

    fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
        let (b, seq_t, vocab) = (self.exe.batch, self.exe.seq_t, self.exe.vocab);
        // pack the live set into the batch (free rows stay zero)
        let mut tokens = vec![0i32; b * seq_t];
        for (ri, slot) in self.slots.iter().enumerate() {
            for (i, &t) in slot.seq.iter().enumerate().take(seq_t) {
                tokens[ri * seq_t + i] = t as i32;
            }
        }
        // a failed joint forward loses every row at once — that is a
        // worker-level crash, so propagate it and let the pool requeue
        let logits = self.exe.run(&tokens)?;
        let mut events = Vec::with_capacity(self.slots.len());
        for (ri, slot) in self.slots.iter_mut().enumerate() {
            let done = slot.seq.is_empty()
                || slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            if done {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                    fault: None,
                });
                continue;
            }
            let pos = slot.seq.len() - 1;
            let off = ri * seq_t * vocab + pos * vocab;
            let row = &logits[off..off + vocab];
            if !row.iter().all(|x| x.is_finite()) {
                events.push(StepEvent::faulted(slot.id, StepFault::NanLogits));
                continue;
            }
            let next = argmax(row) as u8;
            slot.seq.push(next);
            let finished = slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
                fault: None,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        0
    }

    fn slot_cap(&self) -> Option<usize> {
        Some(self.exe.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec_decode::engine::tests_support::ToyModel;

    fn reqs(n: usize, gap_ms: f64, max_new: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: max_new,
                arrival_ms: i as f64 * gap_ms,
                deadline_ms: None,
                class: Default::default(),
            })
            .collect()
    }

    #[test]
    fn continuous_matches_sequential_outputs_on_toy_model() {
        let target = ToyModel::new(3);
        let seq = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::sequential(),
            0,
        )
        .unwrap();
        let cont = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        assert_eq!(seq.completed.len(), 6);
        assert_eq!(cont.completed.len(), 6);
        assert_eq!(seq.total_tokens, cont.total_tokens);
        for (a, b) in seq.completed.iter().zip(&cont.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "continuous changed request {}", a.id);
        }
    }

    #[test]
    fn empty_rounds_jump_to_next_arrival_in_o1() {
        let target = ToyModel::new(1);
        let mut requests = reqs(2, 0.0, 4);
        // a gap the old clock_ms += 1.0 busy-advance would crawl across
        // one millisecond at a time (1e9 iterations)
        requests[1].arrival_ms = 1e9;
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 2);
        // the late request starts right at its arrival: no residual queueing
        assert!(report.completed[1].ttft_ms < 1e6, "{}", report.completed[1].ttft_ms);
    }

    #[test]
    fn zero_budget_requests_finish_empty() {
        let target = ToyModel::new(2);
        let mut requests = reqs(3, 1.0, 5);
        requests[1].max_new_tokens = 0;
        requests[2].prompt = vec![1u8; 64]; // fills max_t: no room to decode
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.completed[0].generated, 5);
        assert_eq!(report.completed[1].generated, 0);
        assert_eq!(report.completed[2].generated, 0);
    }

    /// Mock executor with synthetic KV accounting: each request reserves a
    /// fixed byte count and runs for `max_new_tokens` rounds.
    struct FakeExec {
        bytes_per_req: usize,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for FakeExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            self.bytes_per_req
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len() * self.bytes_per_req
        }
    }

    #[test]
    fn kv_budget_caps_concurrency_without_starvation() {
        let exec = FakeExec { bytes_per_req: 100, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // fits 2 of 100
        let report = Scheduler::run(reqs(7, 0.0, 3), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 7, "every request must complete");
        assert!(report.peak_kv_bytes <= 250, "peak {} > budget", report.peak_kv_bytes);
    }

    #[test]
    fn oversized_request_admitted_alone_not_starved() {
        let exec = FakeExec { bytes_per_req: 1000, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // smaller than one request
        let report = Scheduler::run(reqs(3, 0.0, 2), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 3, "safety valve must prevent deadlock");
    }

    #[test]
    fn static_policy_drains_chunks() {
        let target = ToyModel::new(3);
        let report = Scheduler::run(
            reqs(5, 0.0, 6),
            GreedyExecutor::new(&target),
            &ServeCfg::static_batch(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 5);
        assert!(report.completed.iter().all(|c| c.generated == 6));
    }

    #[test]
    fn static_policy_waits_to_fill_chunks_on_staggered_arrivals() {
        let exec = FakeExec { bytes_per_req: 1, live: Vec::new() };
        // arrivals 10 ms apart: a chunk of 2 must wait for its second
        // member instead of degenerating to batch size 1
        let report = Scheduler::run(reqs(4, 10.0, 3), exec, &ServeCfg::static_batch(2), 0).unwrap();
        assert_eq!(report.completed.len(), 4);
        // request 0 (arrival 0) only starts once request 1 (arrival 10)
        // has arrived, so its first token lands after the 10 ms wait
        assert!(
            report.completed[0].ttft_ms >= 10.0,
            "chunk started before it filled: ttft {}",
            report.completed[0].ttft_ms
        );
    }

    #[test]
    fn pool_idle_worker_jumps_to_earliest_event_across_workers() {
        // Per-worker capacity 1; r0 occupies worker 0 from t=0 and the
        // next arrival is 1e9 ms away. The empty-round jump must move only
        // the idle worker, straight to the arrival it is about to seat, in
        // O(1) (this test would effectively hang on a busy-advance) — and
        // the busy worker's in-flight request must not be dragged to the
        // far-future arrival time.
        let target = ToyModel::new(1);
        let mut requests = reqs(2, 0.0, 6);
        requests[1].arrival_ms = 1e9;
        let cfg = ServeCfg::continuous(1).with_workers(2);
        let report =
            WorkerPool::run(requests, |_| GreedyExecutor::new(&target), &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 2);
        assert!(
            report.completed[0].total_ms < 1e6,
            "busy worker dragged to the far arrival: {}",
            report.completed[0].total_ms
        );
        assert!(
            report.completed[1].ttft_ms < 1e6,
            "late arrival queued behind an idle worker: {}",
            report.completed[1].ttft_ms
        );
        // the stealer's clock lands on the arrival it seated
        assert!(report.makespan_ms >= 1e9);
    }

    #[test]
    fn pool_steals_work_across_workers_with_identical_outputs() {
        // 6 simultaneous arrivals, per-worker capacity 1: three workers
        // drain the shared queue in parallel lanes; outputs stay
        // bit-identical to the single-worker run, nothing duplicated or
        // dropped.
        let target = ToyModel::new(3);
        let one = WorkerPool::run(
            reqs(6, 0.0, 8),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::continuous(1),
            0,
        )
        .unwrap();
        let three = WorkerPool::run(
            reqs(6, 0.0, 8),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::continuous(1).with_workers(3),
            0,
        )
        .unwrap();
        assert_eq!(three.completed.len(), 6);
        assert_eq!(three.workers(), 3);
        assert_eq!(one.total_tokens, three.total_tokens);
        for (a, b) in one.completed.iter().zip(&three.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "sharding changed request {}", a.id);
        }
    }

    #[test]
    fn pool_splits_budget_and_respects_worker_shares() {
        // 500 total bytes over 2 workers = 250 each: at most 2 of the
        // 100-byte requests in flight per worker, never more.
        let cfg = ServeCfg::continuous(8).with_budget(500).with_workers(2);
        assert_eq!(cfg.per_worker_budgets(), vec![250, 250]);
        let report = WorkerPool::run(
            reqs(9, 0.0, 3),
            |_| FakeExec { bytes_per_req: 100, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 9, "every request must complete");
        for (w, peak) in report.worker_peak_kv_bytes.iter().enumerate() {
            assert!(*peak <= 250, "worker {w} peak {peak} > share 250");
        }
        assert!(report.peak_kv_bytes <= 500, "pool peak {}", report.peak_kv_bytes);
    }

    #[test]
    fn pool_oversized_request_runs_alone_on_an_idle_worker() {
        // 1000-byte requests fit no worker's 200-byte share: the safety
        // valve routes each to an idle worker alone; nothing starves and
        // no worker ever holds two at once.
        let cfg = ServeCfg::continuous(8).with_budget(400).with_workers(2);
        let report = WorkerPool::run(
            reqs(4, 0.0, 2),
            |_| FakeExec { bytes_per_req: 1000, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 4, "safety valve must prevent starvation");
        for peak in &report.worker_peak_kv_bytes {
            assert!(*peak <= 1000, "oversized request must run alone: peak {peak}");
        }
    }

    #[test]
    fn scheduler_run_rejects_multi_worker_configs() {
        // one executor cannot staff two workers; no silent fallback to 1
        let target = ToyModel::new(1);
        let r = Scheduler::run(
            reqs(1, 0.0, 2),
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(2).with_workers(2),
            0,
        );
        assert!(r.is_err(), "Scheduler::run must reject workers > 1 loudly");
    }

    #[test]
    fn pool_rejects_budget_that_splits_to_zero() {
        // programmatic configs bypass YAML validation; the pool itself
        // must refuse shares of zero rather than run workers unlimited
        let cfg = ServeCfg::continuous(4).with_budget(3).with_workers(8);
        let r = WorkerPool::run(
            reqs(2, 0.0, 2),
            |_| FakeExec { bytes_per_req: 1, live: Vec::new() },
            &cfg,
            0,
        );
        assert!(r.is_err(), "zero shares must be rejected, not silently unlimited");
    }

    #[test]
    fn per_worker_budget_split_covers_total() {
        let cfg = ServeCfg::continuous(4).with_budget(1003).with_workers(4);
        let shares = cfg.per_worker_budgets();
        assert_eq!(shares.len(), 4);
        assert_eq!(shares.iter().sum::<usize>(), 1003);
        // unlimited stays unlimited on every worker
        assert_eq!(ServeCfg::continuous(4).per_worker_budgets(), vec![0]);
    }

    #[test]
    fn ensure_requests_fit_flags_budget_below_smallest_request() {
        let exec = FakeExec { bytes_per_req: 100, live: Vec::new() };
        let trace = reqs(3, 0.0, 2);
        // 90 bytes per worker: even the smallest request (100 bytes)
        // would need the safety valve — reject loudly
        let bad = ServeCfg::continuous(4).with_budget(180).with_workers(2);
        assert!(bad.ensure_requests_fit(&exec, &trace).is_err());
        let ok = ServeCfg::continuous(4).with_budget(200).with_workers(2);
        assert!(ok.ensure_requests_fit(&exec, &trace).is_ok());
        // unlimited budget always fits
        assert!(ServeCfg::continuous(4).ensure_requests_fit(&exec, &trace).is_ok());
    }

    #[test]
    fn pool_static_policy_drains_parallel_chunks() {
        let target = ToyModel::new(3);
        let report = WorkerPool::run(
            reqs(6, 0.0, 5),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::static_batch(2).with_workers(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 6);
        assert!(report.completed.iter().all(|c| c.generated == 5));
    }

    #[test]
    fn admission_policy_parses() {
        assert_eq!(AdmissionPolicy::parse("continuous").unwrap(), AdmissionPolicy::Continuous);
        assert_eq!(AdmissionPolicy::parse("static").unwrap(), AdmissionPolicy::Static);
        assert_eq!(AdmissionPolicy::parse("sequential").unwrap(), AdmissionPolicy::Sequential);
        assert!(AdmissionPolicy::parse("magic").is_err());
        assert_eq!(AdmissionPolicy::Continuous.name(), "continuous");
    }

    // ── fault tolerance ──────────────────────────────────────────────

    use std::collections::HashMap;

    /// FakeExec variant that faults `victim`'s first execution attempt.
    struct FlakyExec {
        victim: u64,
        admits: HashMap<u64, usize>,
        live: Vec<(u64, usize)>,
    }

    impl FlakyExec {
        fn new(victim: u64) -> Self {
            FlakyExec { victim, admits: HashMap::new(), live: Vec::new() }
        }
    }

    impl StepExecutor for FlakyExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            *self.admits.entry(req.id).or_insert(0) += 1;
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                if *id == self.victim && self.admits.get(id) == Some(&1) {
                    events.push(StepEvent::faulted(
                        *id,
                        StepFault::Error("flaky step".into()),
                    ));
                    continue;
                }
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }
    }

    /// FakeExec variant whose every round fails (a dead worker).
    struct CrashExec {
        crash: bool,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for CrashExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            if self.crash {
                bail!("induced worker crash");
            }
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }
    }

    /// FakeExec variant that stalls the worker clock by a fixed virtual
    /// time every round (deterministic clock inflation).
    struct StallExec {
        stall_ms: f64,
        pending: f64,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for StallExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            self.pending += self.stall_ms;
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }

        fn take_stall_ms(&mut self) -> f64 {
            let s = self.pending;
            self.pending = 0.0;
            s
        }
    }

    #[test]
    fn fault_free_run_reports_all_completed_first_attempt() {
        let exec = FakeExec { bytes_per_req: 1, live: Vec::new() };
        let report = Scheduler::run(reqs(5, 1.0, 3), exec, &ServeCfg::continuous(4), 0).unwrap();
        assert_eq!(report.goodput(), 5);
        assert!(report.crashed_workers.is_empty());
        for c in &report.completed {
            assert_eq!(c.outcome, RequestOutcome::Completed);
            assert_eq!(c.attempts, 1);
        }
    }

    #[test]
    fn faulted_request_retries_and_completes() {
        let cfg = ServeCfg::continuous(4).with_retries(1).with_backoff(0.5);
        let report = Scheduler::run(reqs(4, 0.0, 3), FlakyExec::new(2), &cfg, 0).unwrap();
        assert_eq!(report.goodput(), 4, "retry must recover the flaky request");
        let victim = &report.completed[2];
        assert_eq!(victim.id, 2);
        assert_eq!(victim.outcome, RequestOutcome::Completed);
        assert_eq!(victim.attempts, 2, "one fault, one successful retry");
        assert_eq!(victim.generated, 3, "retried output is a full fresh decode");
        for c in report.completed.iter().filter(|c| c.id != 2) {
            assert_eq!(c.attempts, 1, "fault containment must not touch request {}", c.id);
        }
    }

    #[test]
    fn fault_without_retry_budget_fails_only_that_request() {
        let cfg = ServeCfg::continuous(4); // max_retries = 0
        let report = Scheduler::run(reqs(4, 0.0, 3), FlakyExec::new(1), &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 4, "every request gets a terminal outcome");
        assert_eq!(report.goodput(), 3);
        let failed = &report.completed[1];
        assert_eq!(failed.id, 1);
        assert_eq!(failed.attempts, 1);
        match &failed.outcome {
            RequestOutcome::Failed { error } => {
                assert!(error.contains("request 1"), "error names the request: {error}");
                assert!(error.contains("flaky step"), "error keeps the cause: {error}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn crashed_worker_requeues_to_survivors() {
        let cfg = ServeCfg::continuous(2).with_workers(2).with_retries(2).with_backoff(0.0);
        let report = WorkerPool::run(
            reqs(6, 0.0, 3),
            |w| CrashExec { crash: w == 1, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.goodput(), 6, "survivors must absorb the crashed worker's load");
        assert_eq!(report.crashed_workers.len(), 1);
        assert_eq!(report.crashed_workers[0].0, 1);
        assert!(
            report.completed.iter().any(|c| c.attempts > 1),
            "worker 1 admitted something before crashing, so retries must show"
        );
    }

    #[test]
    fn all_workers_crashed_still_returns_full_accounting() {
        let cfg = ServeCfg::continuous(2); // one worker, no retries
        let report = Scheduler::run(
            reqs(4, 0.0, 3),
            CrashExec { crash: true, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 4, "total failure still accounts for every request");
        assert_eq!(report.goodput(), 0);
        let counts = report.outcome_counts();
        assert_eq!(counts.failed, 2, "the two admitted requests fail with the worker");
        assert_eq!(counts.shed, 2, "the queued remainder is shed");
        assert_eq!(report.crashed_workers.len(), 1);
    }

    #[test]
    fn expired_queued_request_is_cancelled_before_admission() {
        // per-request deadline of 0 ms can never be met: it must be
        // cancelled at admission time without spending KV or compute
        let mut requests = reqs(3, 0.0, 3);
        requests[1].deadline_ms = Some(0.0);
        let exec = FakeExec { bytes_per_req: 1, live: Vec::new() };
        let report = Scheduler::run(requests, exec, &ServeCfg::continuous(1), 0).unwrap();
        assert_eq!(report.completed.len(), 3);
        let cancelled = &report.completed[1];
        assert_eq!(cancelled.outcome, RequestOutcome::DeadlineExceeded);
        assert_eq!(cancelled.generated, 0);
        assert_eq!(cancelled.attempts, 0, "never admitted");
        assert_eq!(report.goodput(), 2);
    }

    #[test]
    fn stall_inflates_clock_and_deadline_cancels_midflight() {
        // 10 ms of injected stall per round against a 15 ms deadline: the
        // request decodes one round, then the sweep cancels it with its
        // partial output kept and its KV reservation released
        let mut requests = reqs(1, 0.0, 8);
        requests[0].deadline_ms = Some(15.0);
        let exec = StallExec { stall_ms: 10.0, pending: 0.0, live: Vec::new() };
        let report = Scheduler::run(requests, exec, &ServeCfg::continuous(1), 0).unwrap();
        let c = &report.completed[0];
        assert_eq!(c.outcome, RequestOutcome::DeadlineExceeded);
        assert!(
            c.generated >= 1 && c.generated < 8,
            "partial output kept on cancellation, got {}",
            c.generated
        );
        assert!(c.total_ms >= 15.0, "cancelled on the inflated clock: {}", c.total_ms);
        assert!(report.makespan_ms >= 20.0, "stall inflates the worker clock");
    }

    #[test]
    fn pool_deadline_default_applies_to_all_requests() {
        let cfg = ServeCfg::continuous(1).with_deadline(f64::MIN_POSITIVE);
        let exec = StallExec { stall_ms: 5.0, pending: 0.0, live: Vec::new() };
        // head admitted at start == arrival (not yet past the tiny
        // deadline), then swept after its first stalled round
        let report = Scheduler::run(reqs(2, 0.0, 4), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 2);
        assert!(report
            .completed
            .iter()
            .all(|c| c.outcome == RequestOutcome::DeadlineExceeded));
    }

    #[test]
    fn pool_rejects_nonpositive_deadline_and_negative_backoff() {
        let mk = || FakeExec { bytes_per_req: 1, live: Vec::new() };
        let bad_deadline = ServeCfg { deadline_ms: Some(0.0), ..ServeCfg::continuous(2) };
        assert!(Scheduler::run(reqs(1, 0.0, 2), mk(), &bad_deadline, 0).is_err());
        let bad_backoff = ServeCfg::continuous(2).with_backoff(-1.0);
        assert!(Scheduler::run(reqs(1, 0.0, 2), mk(), &bad_backoff, 0).is_err());
    }

    #[test]
    fn injected_faults_via_cfg_reach_terminal_outcomes() {
        // cfg.fault wraps every worker's executor in a FaultInjector; a
        // high error rate with retries still ends in full accounting
        let plan = FaultPlan::default().with_step_errors(0.5);
        let cfg = ServeCfg::continuous(2)
            .with_workers(2)
            .with_retries(4)
            .with_backoff(0.1)
            .with_faults(plan);
        let report = WorkerPool::run(
            reqs(8, 0.0, 3),
            |_| FakeExec { bytes_per_req: 1, live: Vec::new() },
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 8);
        let counts = report.outcome_counts();
        assert_eq!(
            counts.completed + counts.failed + counts.deadline_exceeded + counts.shed,
            8
        );
    }

    #[test]
    fn backoff_stays_finite_and_capped() {
        // regression: retry_backoff used to compute backoff * 2^(attempt-1)
        // unclamped, so a deep retry ladder pushed ready_ms to infinity
        // and the request silently never re-admitted
        let cfg = ServeCfg::continuous(2).with_backoff(1.0);
        for attempt in [1usize, 10, 61, 80, 1_000, 1 << 20, usize::MAX] {
            let b = retry_backoff(&cfg, attempt);
            assert!(b.is_finite(), "attempt {attempt} overflowed to {b}");
            assert!(b >= 0.0, "attempt {attempt} went negative: {b}");
            assert!(
                b <= cfg.max_backoff_ms,
                "attempt {attempt} escaped the clamp: {b} > {}",
                cfg.max_backoff_ms
            );
        }
        // plain doubling below the cap is untouched
        assert_eq!(retry_backoff(&cfg, 1), 1.0);
        assert_eq!(retry_backoff(&cfg, 3), 4.0);
        // a tight explicit cap wins as soon as doubling crosses it
        let tight = ServeCfg::continuous(2).with_backoff(100.0).with_max_backoff(150.0);
        assert_eq!(retry_backoff(&tight, 1), 100.0);
        assert_eq!(retry_backoff(&tight, 5), 150.0);
    }

    /// Faults every step of request `victim` until `faults_left` runs
    /// out, then decodes it normally — drives the retry ladder deep
    /// enough that an unclamped exponential backoff would overflow.
    struct DeepFlakyExec {
        victim: u64,
        faults_left: usize,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for DeepFlakyExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                if *id == self.victim && self.faults_left > 0 {
                    self.faults_left -= 1;
                    events.push(StepEvent::faulted(
                        *id,
                        StepFault::Error("deep flake".into()),
                    ));
                    continue;
                }
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }
    }

    #[test]
    fn deep_retry_ladder_recovers_within_finite_virtual_time() {
        // 80 consecutive faults: without the max_backoff_ms clamp the
        // final retry's ready_ms would sit at 1.0 * 2^79 ms ≈ 6e23 —
        // the request would never re-admit. With the clamp every wait
        // is <= max_backoff_ms and the 81st attempt completes.
        let cfg = ServeCfg::continuous(2).with_retries(80).with_backoff(1.0);
        let exec = DeepFlakyExec { victim: 0, faults_left: 80, live: Vec::new() };
        let report = Scheduler::run(reqs(2, 0.0, 3), exec, &cfg, 0).unwrap();
        assert_eq!(report.goodput(), 2, "both requests must complete");
        let victim = report.completed.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(victim.attempts, 81, "80 faults then one clean attempt");
        assert!(report.makespan_ms.is_finite(), "{}", report.makespan_ms);
        assert!(
            report.makespan_ms <= 81.0 * cfg.max_backoff_ms,
            "capped backoff bounds the total wait: {}",
            report.makespan_ms
        );
    }

    /// Paged-executor stand-in whose victim request cannot fit the pool:
    /// every round it preempts the victim (first `preempts_left` times)
    /// while the other requests decode normally.
    struct NeverFitsExec {
        victim: u64,
        preempts_left: usize,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for NeverFitsExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                if *id == self.victim && self.preempts_left > 0 {
                    self.preempts_left -= 1;
                    events.push(StepEvent::faulted(*id, StepFault::Preempted));
                    continue;
                }
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![9],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }
    }

    #[test]
    fn never_fitting_request_fails_loudly_instead_of_livelocking() {
        // preemptions never count against max_retries, so before the
        // no-progress detector this schedule spun forever: the victim is
        // preempted and requeued every round once its peers have drained
        let exec = NeverFitsExec { victim: 1, preempts_left: usize::MAX, live: Vec::new() };
        let report =
            Scheduler::run(reqs(3, 0.0, 3), exec, &ServeCfg::continuous(2), 0).unwrap();
        assert_eq!(report.completed.len(), 3, "every request gets one terminal outcome");
        assert_eq!(report.goodput(), 2);
        let victim = report.completed.iter().find(|c| c.id == 1).unwrap();
        match &victim.outcome {
            RequestOutcome::Failed { error } => {
                assert!(
                    error.contains(POOL_EXHAUSTED_PREFIX),
                    "failure must carry the pool-exhausted context: {error}"
                );
                assert!(
                    error.contains("preempted"),
                    "failure must name the preemption cycle: {error}"
                );
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn never_fitting_request_fails_loudly_in_threaded_mode_too() {
        // the detector lives in apply_round_events, shared by both modes:
        // the OS-thread pool must classify the livelock identically
        let exec = NeverFitsExec { victim: 1, preempts_left: usize::MAX, live: Vec::new() };
        let cfg = ServeCfg::continuous(2).with_threads(true);
        let report = Scheduler::run(reqs(3, 0.0, 3), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.goodput(), 2);
        let victim = report.completed.iter().find(|c| c.id == 1).unwrap();
        match &victim.outcome {
            RequestOutcome::Failed { error } => {
                assert!(error.contains(POOL_EXHAUSTED_PREFIX), "{error}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn bounded_preemption_churn_is_not_flagged_as_livelock() {
        // property at the detector's boundary: exactly the threshold
        // count of consecutive no-progress preemptions, then the pool
        // frees up — feasible churn must never be converted to Failed
        let exec = NeverFitsExec {
            victim: 0,
            preempts_left: MAX_NO_PROGRESS_PREEMPT_CYCLES,
            live: Vec::new(),
        };
        let report =
            Scheduler::run(reqs(2, 0.0, 3), exec, &ServeCfg::continuous(2), 0).unwrap();
        assert_eq!(report.goodput(), 2, "threshold-grazing churn still completes");
        let victim = report.completed.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(victim.outcome, RequestOutcome::Completed);
        assert_eq!(victim.generated, 3, "retried decode is a full fresh pass");
    }
}
