//! Sharded continuous-batching scheduler with KV-memory admission control.
//!
//! One loop serves every path: a per-request state machine
//!
//!     Queued ──admit──▶ Prefill ──first step──▶ Decoding ──▶ Finished
//!
//! driven by a [`WorkerPool`] of `workers` independent scheduler loops —
//! each with its own [`StepExecutor`], KV-budget share, live set, and
//! compute clock — pulling from **one shared FIFO queue**. Between every
//! decode round a worker retires finished requests, and admission is
//! **work-stealing**: the worker that can start the queue head earliest
//! (an idle worker jumps its clock to the arrival in O(1)) steals it,
//! under that worker's KV-memory budget share (projected from [`KvCache`]
//! bytes accounting). A request that fits *no* worker's budget share is
//! routed to an idle least-loaded worker to run alone (safety valve)
//! instead of starving. Static batching, sequential serving, and the
//! single-worker [`Scheduler`] are degenerate configurations of the same
//! loop (see [`AdmissionPolicy`] / [`ServeCfg::workers`]), which is what
//! unifies the time model across `ServingEngine::serve` / `serve_batched`
//! / `serve_batched_pjrt` / sharded serving.
//!
//! Compute is pluggable through [`StepExecutor`]: greedy KV-session
//! decoding ([`GreedyExecutor`]), speculative draft+target sessions with
//! rollback ([`SpecExecutor`]), or a joint batched forward over a PJRT
//! executable ([`PjrtBatchExecutor`]).
//!
//! Time model (unified across all paths): request *arrivals* are virtual
//! (from the workload trace) on one global timeline; compute occupies
//! real wall-clock measured around each decode round **on the worker
//! that ran it**, so worker clocks advance independently (parallel
//! replicas on the virtual timeline). An empty round jumps straight to
//! the earliest next event across workers — never further than the
//! arrival the jumping worker is about to admit — in O(1) (no
//! busy-advance). Per-request TTFT = first-token round end − arrival,
//! total = finish round end − arrival, on the same timeline everywhere,
//! so sharded reports compare directly against single-worker ones.
//!
//! Because every executor decodes each request in its own session(s),
//! per-request outputs are **bit-identical** for every worker count and
//! admission interleaving (property-tested in
//! `tests/test_sharded_props.rs`).
//!
//! [`KvCache`]: crate::models::KvCache

use crate::data::TokenRequest;
use crate::models::Sampler;
use crate::runtime::ModelExecutable;
use crate::spec_decode::{spec_verify_step, DecodeSession, SessionModel};
use crate::tensor::ops::argmax;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

use super::engine::{CompletedRequest, ServeReport};

/// When the scheduler may move a request from Queued to Prefill.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit whenever a slot and KV budget are free — between every decode
    /// round. This is continuous batching.
    Continuous,
    /// Admit only when no request is in flight, up to `max_in_flight` at
    /// once: classic static batching (the whole chunk drains before the
    /// next one forms).
    Static,
    /// One request at a time, in arrival order (`max_in_flight` is forced
    /// to 1): the old per-request serve loop.
    Sequential,
}

impl AdmissionPolicy {
    /// Parse a config/CLI name ("continuous" | "static" | "sequential").
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "continuous" => AdmissionPolicy::Continuous,
            "static" => AdmissionPolicy::Static,
            "sequential" => AdmissionPolicy::Sequential,
            other => bail!(
                "unknown admission policy `{other}` (continuous | static | sequential)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Continuous => "continuous",
            AdmissionPolicy::Static => "static",
            AdmissionPolicy::Sequential => "sequential",
        }
    }
}

/// Scheduler configuration — the `serve:` section of a YAML config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeCfg {
    pub policy: AdmissionPolicy,
    /// concurrent-request cap **per worker** (executors may clamp it
    /// further, e.g. to the PJRT batch dimension)
    pub max_in_flight: usize,
    /// Total KV-memory admission budget in bytes, split evenly across
    /// `workers`; 0 = unlimited. Admission reserves each request's
    /// *projected peak* KV bytes up front against its worker's share —
    /// and sessions are allocated at exactly that bound
    /// (`new_session_bounded`) — so both observable and resident KV
    /// memory stay within every worker's share. A request projected over
    /// every worker's share is admitted alone on an idle worker (safety
    /// valve) rather than starving.
    pub kv_budget_bytes: usize,
    /// Number of scheduler workers sharing the FIFO queue (work-stealing
    /// admission). 1 = the classic single-worker scheduler; 0 is invalid
    /// and rejected at config validation.
    pub workers: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Continuous,
            max_in_flight: 8,
            kv_budget_bytes: 0,
            workers: 1,
        }
    }
}

impl ServeCfg {
    pub fn continuous(max_in_flight: usize) -> Self {
        ServeCfg { max_in_flight, ..ServeCfg::default() }
    }

    pub fn sequential() -> Self {
        ServeCfg { policy: AdmissionPolicy::Sequential, max_in_flight: 1, ..ServeCfg::default() }
    }

    pub fn static_batch(max_batch: usize) -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Static,
            max_in_flight: max_batch,
            ..ServeCfg::default()
        }
    }

    pub fn with_budget(mut self, kv_budget_bytes: usize) -> Self {
        self.kv_budget_bytes = kv_budget_bytes;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Each worker's KV-budget share: `kv_budget_bytes` split evenly, the
    /// remainder spread over the first workers, so shares always sum to
    /// the configured total. A nonzero total smaller than the worker
    /// count would leave trailing shares at 0 — i.e. silently unlimited —
    /// so both config validation and [`WorkerPool::run`] reject that
    /// combination loudly instead.
    pub fn per_worker_budgets(&self) -> Vec<usize> {
        let n = self.workers.max(1);
        if self.kv_budget_bytes == 0 {
            return vec![0; n];
        }
        let base = self.kv_budget_bytes / n;
        let rem = self.kv_budget_bytes % n;
        (0..n).map(|i| base + usize::from(i < rem)).collect()
    }

    /// Loud misconfiguration guard for config-driven serving: with a
    /// nonzero budget, at least the smallest request of `requests` must
    /// fit one worker's share. Otherwise *every* request would fall back
    /// to the oversized-request safety valve and the pool would silently
    /// degenerate to budget-less one-at-a-time serving.
    pub fn ensure_requests_fit<E: StepExecutor>(
        &self,
        executor: &E,
        requests: &[TokenRequest],
    ) -> Result<()> {
        if self.kv_budget_bytes == 0 || requests.is_empty() {
            return Ok(());
        }
        let share = self.per_worker_budgets().into_iter().max().unwrap_or(0);
        let min_need = requests
            .iter()
            .map(|r| executor.projected_bytes(r))
            .min()
            .unwrap_or(0);
        if min_need > share {
            bail!(
                "serve.kv_budget_bytes = {} splits to {share} bytes per worker \
                 ({} workers), smaller than the smallest request's projected \
                 peak KV of {min_need} bytes; every request would need the \
                 oversized-request safety valve — raise the budget or reduce \
                 workers",
                self.kv_budget_bytes,
                self.workers.max(1),
            );
        }
        Ok(())
    }
}

/// Lifecycle of one request inside the scheduler. `Queued` and `Finished`
/// are the boundary states (the arrival queue, and the completed list with
/// the KV reservation released); the live set tracks only
/// `Prefill`/`Decoding`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    /// waiting for a slot / KV budget
    Queued,
    /// admitted; its first decode round (which feeds the prompt) has not
    /// completed yet
    Prefill,
    /// producing tokens, one round at a time
    Decoding,
    /// retired; its KV reservation is released
    Finished,
}

/// What one request did during one decode round.
#[derive(Clone, Debug)]
pub struct StepEvent {
    pub id: u64,
    /// tokens committed this round (greedy: 1; speculative: accepted + bonus)
    pub tokens: Vec<u8>,
    /// target verify/decode steps this round (the AL denominator)
    pub steps: usize,
    /// speculative tokens proposed this round
    pub proposed: usize,
    /// speculative tokens accepted this round
    pub accepted: usize,
    pub finished: bool,
}

/// Pluggable compute for one decode round over the live set. The scheduler
/// owns admission, retirement, the virtual clock, and metrics; executors
/// own per-request sessions and the model calls.
pub trait StepExecutor {
    /// Projected peak KV bytes `req` will hold while in flight — the
    /// amount admission control reserves against the budget.
    fn projected_bytes(&self, req: &TokenRequest) -> usize;
    /// Allocate per-request decode state. The request's first round (its
    /// Prefill step) runs at the next `step_round`.
    fn admit(&mut self, req: &TokenRequest) -> Result<()>;
    /// Advance every admitted request one decode round, returning one
    /// event per live request.
    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>>;
    /// Drop a finished request's state, freeing its KV bytes.
    fn retire(&mut self, id: u64);
    /// Resident KV bytes across live sessions (observability + the budget
    /// property test).
    fn live_bytes(&self) -> usize;
    /// Hard cap on concurrently-admittable requests (e.g. the PJRT batch
    /// dimension); `None` = bounded only by `ServeCfg::max_in_flight`.
    fn slot_cap(&self) -> Option<usize> {
        None
    }
}

struct LiveReq {
    id: u64,
    arrival_ms: f64,
    state: ReqState,
    output: Vec<u8>,
    first_token_ms: Option<f64>,
    reserved_bytes: usize,
}

/// Single-worker serve loop — the degenerate [`WorkerPool`] of one worker,
/// kept as the entry point for callers that hand over one concrete
/// executor (`serve_batched`, the PJRT path, unit tests).
pub struct Scheduler;

impl Scheduler {
    /// Run `executor` as a one-worker pool. A single executor can only
    /// staff one worker, so `cfg.workers > 1` is a loud error here (no
    /// silent single-worker fallback); sharded callers go through
    /// [`WorkerPool::run`] with an executor factory.
    pub fn run<E: StepExecutor>(
        requests: Vec<TokenRequest>,
        executor: E,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        if cfg.workers > 1 {
            bail!(
                "Scheduler::run staffs exactly one worker but cfg.workers = {}; \
                 use WorkerPool::run with an executor factory for sharded serving",
                cfg.workers
            );
        }
        let mut slot = Some(executor);
        let one = ServeCfg { workers: 1, ..cfg.clone() };
        WorkerPool::run(
            requests,
            move |_| slot.take().expect("a one-worker pool builds one executor"),
            &one,
            seed,
        )
    }
}

/// One worker's slice of the pool: its executor, KV-budget share, live
/// set, and compute clock.
struct PoolWorker<E: StepExecutor> {
    executor: E,
    rng: Rng,
    /// this worker's position on the shared virtual timeline
    clock_ms: f64,
    live: Vec<LiveReq>,
    reserved_bytes: usize,
    /// KV-budget share (0 = unlimited)
    budget: usize,
    max_in_flight: usize,
    /// max resident KV bytes observed on this worker
    peak_kv_bytes: usize,
    /// this worker's `executor.live_bytes()` as of its last state change
    /// (admission / round / retirement) — lets the pool sample the total
    /// concurrent residency without re-summing every executor each round
    cached_live_bytes: usize,
}

/// What the pool does next: run a decode round on a busy worker, or let
/// the designated stealer admit the queue head.
enum PoolAct {
    Round(usize),
    Admit(usize),
}

/// The sharded serve loop: `cfg.workers` independent scheduler loops over
/// one shared FIFO queue with work-stealing admission. All `ServingEngine`
/// entry points are thin policy wrappers over this run (single-worker via
/// [`Scheduler::run`]).
pub struct WorkerPool;

impl WorkerPool {
    /// `make_executor(worker_index)` is called once per worker; executors
    /// typically share one immutable model reference.
    pub fn run<E: StepExecutor, F: FnMut(usize) -> E>(
        mut requests: Vec<TokenRequest>,
        mut make_executor: F,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        let n_workers = cfg.workers.max(1);
        if cfg.kv_budget_bytes > 0 && cfg.kv_budget_bytes < n_workers {
            // enforced here as well as at config validation: a split that
            // leaves any worker a zero share would make that worker
            // silently unlimited and the pool's resident KV could exceed
            // the configured total
            bail!(
                "kv_budget_bytes = {} splits to zero across {n_workers} workers; \
                 raise the budget, reduce workers, or set 0 for unlimited",
                cfg.kv_budget_bytes
            );
        }
        let budgets = cfg.per_worker_budgets();
        let mut workers: Vec<PoolWorker<E>> = (0..n_workers)
            .map(|w| {
                let executor = make_executor(w);
                let mut max_in_flight = match cfg.policy {
                    AdmissionPolicy::Sequential => 1,
                    _ => cfg.max_in_flight.max(1),
                };
                if let Some(cap) = executor.slot_cap() {
                    max_in_flight = max_in_flight.min(cap.max(1));
                }
                PoolWorker {
                    executor,
                    // worker 0 keeps the bare seed, so a one-worker pool is
                    // bit-identical to the historical single scheduler
                    rng: Rng::new(seed ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                    clock_ms: 0.0,
                    live: Vec::new(),
                    reserved_bytes: 0,
                    budget: budgets[w],
                    max_in_flight,
                    peak_kv_bytes: 0,
                    cached_live_bytes: 0,
                }
            })
            .collect();

        let n_submitted = requests.len();
        let t0 = Instant::now();
        // stable sort: FIFO among simultaneous arrivals
        requests.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
        let mut queue: VecDeque<TokenRequest> = requests.into();
        let mut completed: Vec<CompletedRequest> = Vec::new();
        let mut total_tokens = 0usize;
        let mut al_num = 0.0f64;
        let mut al_den = 0.0f64;
        let mut proposed = 0usize;
        let mut accepted = 0usize;
        let mut peak_kv_bytes = 0usize;
        // running sum of every worker's cached_live_bytes
        let mut pool_live_bytes = 0usize;

        loop {
            // ── earliest next event across workers ───────────────────
            // A busy worker can run a round at its current clock; the
            // designated stealer can admit the queue head at
            // max(its clock, head arrival). The earliest acts; ties go to
            // the stealer so admission lands before the round it feeds
            // (the single-worker loop's admit-then-step order).
            let mut best_busy: Option<usize> = None;
            for (i, w) in workers.iter().enumerate() {
                if w.live.is_empty() {
                    continue;
                }
                let earlier = match best_busy {
                    None => true,
                    Some(b) => w.clock_ms < workers[b].clock_ms,
                };
                if earlier {
                    best_busy = Some(i);
                }
            }
            let stealer = Self::pick_stealer(&workers, queue.front(), cfg.policy);

            let act = match (best_busy, stealer) {
                (None, None) => break, // queue drained, every worker idle
                (Some(b), None) => PoolAct::Round(b),
                (None, Some((s, _))) => PoolAct::Admit(s),
                (Some(b), Some((s, start))) => {
                    if start <= workers[b].clock_ms {
                        PoolAct::Admit(s)
                    } else {
                        PoolAct::Round(b)
                    }
                }
            };

            match act {
                // ── work-stealing admission of the queue head ────────
                PoolAct::Admit(s) => {
                    match cfg.policy {
                        AdmissionPolicy::Static => {
                            Self::admit_static_chunk(&mut workers[s], &mut queue)?
                        }
                        _ => {
                            let w = &mut workers[s];
                            let req =
                                queue.pop_front().expect("stealer needs a queue head");
                            // empty-round jump, multi-worker aware: only the
                            // stealer advances, straight to the arrival it is
                            // about to seat, in O(1)
                            if req.arrival_ms > w.clock_ms {
                                w.clock_ms = req.arrival_ms;
                            }
                            Self::admit_one(w, req)?;
                        }
                    }
                    let w = &mut workers[s];
                    let now_bytes = w.executor.live_bytes();
                    pool_live_bytes = pool_live_bytes - w.cached_live_bytes + now_bytes;
                    w.cached_live_bytes = now_bytes;
                }

                // ── one measured decode round on one worker ──────────
                PoolAct::Round(b) => {
                    let events = {
                        let w = &mut workers[b];
                        let round_t0 = Instant::now();
                        let events = w.executor.step_round(&mut w.rng)?;
                        w.clock_ms += round_t0.elapsed().as_secs_f64() * 1e3;
                        events
                    };
                    let w = &mut workers[b];
                    // pool-wide concurrent residency, sampled post-round /
                    // pre-retirement: other workers' caches are current
                    // (refreshed on their every admission/round), so only
                    // worker b needs a fresh read
                    let round_bytes = w.executor.live_bytes();
                    peak_kv_bytes = peak_kv_bytes
                        .max(pool_live_bytes - w.cached_live_bytes + round_bytes);
                    w.peak_kv_bytes = w.peak_kv_bytes.max(round_bytes);

                    // retire finished, book metrics on this worker's clock
                    let now = w.clock_ms;
                    for ev in events {
                        let idx = w
                            .live
                            .iter()
                            .position(|l| l.id == ev.id)
                            .expect("step event for a request that was never admitted");
                        {
                            let l = &mut w.live[idx];
                            debug_assert!(
                                matches!(l.state, ReqState::Prefill | ReqState::Decoding),
                                "step event for a request outside Prefill/Decoding"
                            );
                            if !ev.tokens.is_empty() {
                                if l.first_token_ms.is_none() {
                                    l.first_token_ms = Some(now);
                                }
                                l.state = ReqState::Decoding;
                            }
                            total_tokens += ev.tokens.len();
                            al_num += ev.tokens.len() as f64;
                            al_den += ev.steps as f64;
                            proposed += ev.proposed;
                            accepted += ev.accepted;
                            l.output.extend_from_slice(&ev.tokens);
                        }
                        if ev.finished {
                            let l = w.live.swap_remove(idx);
                            w.executor.retire(l.id);
                            w.reserved_bytes -= l.reserved_bytes;
                            completed.push(CompletedRequest {
                                id: l.id,
                                generated: l.output.len(),
                                ttft_ms: l.first_token_ms.unwrap_or(now) - l.arrival_ms,
                                total_ms: now - l.arrival_ms,
                                output: l.output,
                            });
                        }
                    }
                    // refresh the cache post-retirement so the next
                    // sample sees the freed bytes
                    let now_bytes = w.executor.live_bytes();
                    pool_live_bytes = pool_live_bytes - w.cached_live_bytes + now_bytes;
                    w.cached_live_bytes = now_bytes;
                }
            }
        }

        if completed.len() != n_submitted {
            bail!(
                "scheduler invariant broken: {} of {n_submitted} requests completed",
                completed.len()
            );
        }
        completed.sort_by_key(|c| c.id);
        let makespan_ms = workers
            .iter()
            .map(|w| w.clock_ms)
            .fold(0.0f64, f64::max);
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            makespan_ms,
            total_tokens,
            mean_al: if al_den == 0.0 { 0.0 } else { al_num / al_den },
            proposed,
            accepted,
            peak_kv_bytes,
            worker_peak_kv_bytes: workers.iter().map(|w| w.peak_kv_bytes).collect(),
        })
    }

    /// The worker that should admit the queue head, and when it could
    /// start it: the minimum over workers with room of
    /// `max(worker clock, arrival)` (ties → fewest live, then index).
    /// `None` while no worker has room — the head then waits, strictly
    /// FIFO, for the next retirement; admission never skips past it.
    ///
    /// Admitting at that minimum is safe: any worker currently without
    /// room frees it no earlier than its own clock, which is never below
    /// the chosen start (the pool always acts on the earliest event
    /// first), so no deferred assignment could start the head sooner.
    fn pick_stealer<E: StepExecutor>(
        workers: &[PoolWorker<E>],
        head: Option<&TokenRequest>,
        policy: AdmissionPolicy,
    ) -> Option<(usize, f64)> {
        let head = head?;
        // oversized-request safety valve, pool edition: a head that fits
        // no worker's budget share can only ever run alone, so it becomes
        // admissible exactly on idle workers
        let fits_nowhere = workers.iter().all(|w| {
            w.budget != 0 && w.executor.projected_bytes(head) > w.budget
        });
        let mut best: Option<(usize, f64, usize)> = None;
        for (i, w) in workers.iter().enumerate() {
            let has_room = match policy {
                // a static chunk only forms on a drained worker
                AdmissionPolicy::Static => w.live.is_empty(),
                _ => {
                    if w.live.len() >= w.max_in_flight {
                        false
                    } else if fits_nowhere {
                        w.live.is_empty()
                    } else {
                        w.budget == 0
                            || w.reserved_bytes + w.executor.projected_bytes(head)
                                <= w.budget
                    }
                }
            };
            if !has_room {
                continue;
            }
            let start = w.clock_ms.max(head.arrival_ms);
            let better = match best {
                None => true,
                Some((_, bs, bl)) => {
                    start < bs || (start == bs && w.live.len() < bl)
                }
            };
            if better {
                best = Some((i, start, w.live.len()));
            }
        }
        best.map(|(i, s, _)| (i, s))
    }

    /// Admit one request to `w`, reserving its projected peak KV bytes.
    fn admit_one<E: StepExecutor>(w: &mut PoolWorker<E>, req: TokenRequest) -> Result<()> {
        let need = w.executor.projected_bytes(&req);
        w.executor.admit(&req)?;
        w.reserved_bytes += need;
        w.live.push(LiveReq {
            id: req.id,
            arrival_ms: req.arrival_ms,
            state: ReqState::Prefill,
            output: Vec::new(),
            first_token_ms: None,
            reserved_bytes: need,
        });
        Ok(())
    }

    /// Classic static batching on one drained worker: jump the clock to
    /// the last arrival of the requests the next chunk can actually seat
    /// (slot cap AND KV-budget share), then admit the whole chunk — so
    /// chunks neither degenerate to size 1 on staggered traces nor wait
    /// for arrivals the budget could never seat.
    fn admit_static_chunk<E: StepExecutor>(
        w: &mut PoolWorker<E>,
        queue: &mut VecDeque<TokenRequest>,
    ) -> Result<()> {
        let mut k = 0usize;
        let mut sum = 0usize;
        for r in queue.iter().take(w.max_in_flight) {
            let need = w.executor.projected_bytes(r);
            let fits = w.budget == 0
                || sum + need <= w.budget
                || (k == 0 && need > w.budget);
            if !fits {
                break;
            }
            sum += need;
            k += 1;
        }
        let chunk_arrival = queue
            .iter()
            .take(k)
            .map(|r| r.arrival_ms)
            .fold(f64::NEG_INFINITY, f64::max);
        if chunk_arrival > w.clock_ms {
            w.clock_ms = chunk_arrival;
        }
        for _ in 0..k {
            let req = queue.pop_front().expect("chunk counted from the queue");
            Self::admit_one(w, req)?;
        }
        Ok(())
    }
}

// ─────────────────────────────────────────────────────────────────────
// Executors
// ─────────────────────────────────────────────────────────────────────

struct GreedySlot<T: SessionModel> {
    id: u64,
    prompt: Vec<u8>,
    sess: T::Session,
    /// tokens still to generate; 0 at admission means the request can
    /// never start (empty prompt / no context room) and finishes empty
    remaining: usize,
    last: Option<Vec<f32>>,
}

/// Greedy KV-session decoding: per request, one prompt prefill then one
/// cached decode step per round — per-request output bit-identical to
/// `VanillaDecoder` (and to the old static `serve_batched` loop).
pub struct GreedyExecutor<'a, T: SessionModel> {
    model: &'a T,
    sampler: Sampler,
    slots: Vec<GreedySlot<T>>,
}

impl<'a, T: SessionModel> GreedyExecutor<'a, T> {
    pub fn new(model: &'a T) -> Self {
        GreedyExecutor { model, sampler: Sampler::Greedy, slots: Vec::new() }
    }

    /// Most tokens this request's session can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.model.max_t())
    }
}

impl<T: SessionModel> StepExecutor for GreedyExecutor<'_, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req) * self.model.kv_bytes_per_token()
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.model.max_t().saturating_sub(req.prompt.len()))
        };
        self.slots.push(GreedySlot {
            id: req.id,
            prompt: req.prompt.clone(),
            // sized to the projected peak, so the session's resident
            // allocation is what admission reserved against the budget
            sess: self.model.new_session_bounded(self.peak_tokens(req)),
            remaining: budget,
            last: None,
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let model = self.model;
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            if slot.remaining == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            // Prefill state: the first round feeds the whole prompt
            if slot.last.is_none() {
                slot.last = slot.sess.extend(model, &slot.prompt)?.pop();
            }
            let next = {
                let row = slot.last.as_ref().expect("non-empty prompt yields a logits row");
                self.sampler.sample(row, rng)
            };
            slot.remaining -= 1;
            let finished = slot.remaining == 0;
            // like VanillaDecoder, the final committed token is never fed back
            slot.last = if finished {
                None
            } else {
                Some(slot.sess.extend(model, &[next])?.pop().unwrap())
            };
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.sess.kv_bytes()).sum()
    }
}

struct SpecSlot<D: SessionModel, T: SessionModel> {
    id: u64,
    seq: Vec<u8>,
    budget: usize,
    generated: usize,
    dsess: D::Session,
    tsess: T::Session,
}

/// Speculative draft-propose / target-verify decoding threaded through the
/// continuous loop: each request keeps a draft and a target KV session;
/// one round = one verify step (catch-up + γ proposals + bonus), with both
/// caches rolled back to the accepted prefix — per-request output
/// bit-identical to `SpecDecoder::generate`.
pub struct SpecExecutor<'a, D: SessionModel, T: SessionModel> {
    draft: &'a D,
    target: &'a T,
    gamma: usize,
    sampler: Sampler,
    slots: Vec<SpecSlot<D, T>>,
}

impl<'a, D: SessionModel, T: SessionModel> SpecExecutor<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize) -> Self {
        SpecExecutor { draft, target, gamma, sampler: Sampler::Greedy, slots: Vec::new() }
    }

    fn limit(&self) -> usize {
        self.target.max_t().min(self.draft.max_t())
    }

    /// Most tokens this request's sessions can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.limit())
    }
}

impl<D: SessionModel, T: SessionModel> StepExecutor for SpecExecutor<'_, D, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req)
            * (self.target.kv_bytes_per_token() + self.draft.kv_bytes_per_token())
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.limit().saturating_sub(req.prompt.len()))
        };
        let peak_t = self.peak_tokens(req);
        self.slots.push(SpecSlot {
            id: req.id,
            seq: req.prompt.clone(),
            budget,
            generated: 0,
            dsess: self.draft.new_session_bounded(peak_t),
            tsess: self.target.new_session_bounded(peak_t),
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let draft = self.draft;
        let target = self.target;
        let gamma = self.gamma;
        let limit = self.limit();
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            // saturating: an over-long prompt admits with budget 0 and the
            // limit term must not underflow before the room hits 0
            let room = limit
                .saturating_sub(slot.seq.len())
                .min(gamma)
                .min(slot.budget.saturating_sub(slot.generated));
            if room == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            // one shared verify step: draft catch-up + γ proposals, single
            // target pass, greedy acceptance + bonus, rollback — the same
            // function SpecDecoder::generate runs per iteration
            let (tokens, proposed, accepted) = spec_verify_step(
                draft,
                target,
                &mut slot.dsess,
                &mut slot.tsess,
                &mut slot.seq,
                room,
                slot.budget - slot.generated,
                limit,
                &self.sampler,
                rng,
            )?;
            slot.generated += tokens.len();

            let finished = slot.generated >= slot.budget || slot.seq.len() >= limit;
            events.push(StepEvent {
                id: slot.id,
                tokens,
                steps: 1,
                proposed,
                accepted,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.dsess.kv_bytes() + s.tsess.kv_bytes())
            .sum()
    }
}

struct PjrtSlot {
    id: u64,
    seq: Vec<u8>,
    prompt_len: usize,
    max_new: usize,
}

/// Joint batched greedy decoding over a b>1 PJRT executable: every live
/// request occupies one batch row and the whole set advances one token per
/// joint forward. Slot count is capped by the executable's batch dim.
pub struct PjrtBatchExecutor<'a> {
    exe: &'a ModelExecutable,
    slots: Vec<PjrtSlot>,
}

impl<'a> PjrtBatchExecutor<'a> {
    pub fn new(exe: &'a ModelExecutable) -> Self {
        PjrtBatchExecutor { exe, slots: Vec::new() }
    }
}

impl StepExecutor for PjrtBatchExecutor<'_> {
    fn projected_bytes(&self, _req: &TokenRequest) -> usize {
        0 // the executable re-forwards per round; no resident KV state
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        self.slots.push(PjrtSlot {
            id: req.id,
            seq: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
        });
        Ok(())
    }

    fn step_round(&mut self, _rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let (b, seq_t, vocab) = (self.exe.batch, self.exe.seq_t, self.exe.vocab);
        // pack the live set into the batch (free rows stay zero)
        let mut tokens = vec![0i32; b * seq_t];
        for (ri, slot) in self.slots.iter().enumerate() {
            for (i, &t) in slot.seq.iter().enumerate().take(seq_t) {
                tokens[ri * seq_t + i] = t as i32;
            }
        }
        let logits = self.exe.run(&tokens)?;
        let mut events = Vec::with_capacity(self.slots.len());
        for (ri, slot) in self.slots.iter_mut().enumerate() {
            let done = slot.seq.is_empty()
                || slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            if done {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            let pos = slot.seq.len() - 1;
            let off = ri * seq_t * vocab + pos * vocab;
            let next = argmax(&logits[off..off + vocab]) as u8;
            slot.seq.push(next);
            let finished = slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        0
    }

    fn slot_cap(&self) -> Option<usize> {
        Some(self.exe.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec_decode::engine::tests_support::ToyModel;

    fn reqs(n: usize, gap_ms: f64, max_new: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: max_new,
                arrival_ms: i as f64 * gap_ms,
            })
            .collect()
    }

    #[test]
    fn continuous_matches_sequential_outputs_on_toy_model() {
        let target = ToyModel::new(3);
        let seq = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::sequential(),
            0,
        )
        .unwrap();
        let cont = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        assert_eq!(seq.completed.len(), 6);
        assert_eq!(cont.completed.len(), 6);
        assert_eq!(seq.total_tokens, cont.total_tokens);
        for (a, b) in seq.completed.iter().zip(&cont.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "continuous changed request {}", a.id);
        }
    }

    #[test]
    fn empty_rounds_jump_to_next_arrival_in_o1() {
        let target = ToyModel::new(1);
        let mut requests = reqs(2, 0.0, 4);
        // a gap the old clock_ms += 1.0 busy-advance would crawl across
        // one millisecond at a time (1e9 iterations)
        requests[1].arrival_ms = 1e9;
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 2);
        // the late request starts right at its arrival: no residual queueing
        assert!(report.completed[1].ttft_ms < 1e6, "{}", report.completed[1].ttft_ms);
    }

    #[test]
    fn zero_budget_requests_finish_empty() {
        let target = ToyModel::new(2);
        let mut requests = reqs(3, 1.0, 5);
        requests[1].max_new_tokens = 0;
        requests[2].prompt = vec![1u8; 64]; // fills max_t: no room to decode
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.completed[0].generated, 5);
        assert_eq!(report.completed[1].generated, 0);
        assert_eq!(report.completed[2].generated, 0);
    }

    /// Mock executor with synthetic KV accounting: each request reserves a
    /// fixed byte count and runs for `max_new_tokens` rounds.
    struct FakeExec {
        bytes_per_req: usize,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for FakeExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            self.bytes_per_req
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len() * self.bytes_per_req
        }
    }

    #[test]
    fn kv_budget_caps_concurrency_without_starvation() {
        let exec = FakeExec { bytes_per_req: 100, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // fits 2 of 100
        let report = Scheduler::run(reqs(7, 0.0, 3), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 7, "every request must complete");
        assert!(report.peak_kv_bytes <= 250, "peak {} > budget", report.peak_kv_bytes);
    }

    #[test]
    fn oversized_request_admitted_alone_not_starved() {
        let exec = FakeExec { bytes_per_req: 1000, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // smaller than one request
        let report = Scheduler::run(reqs(3, 0.0, 2), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 3, "safety valve must prevent deadlock");
    }

    #[test]
    fn static_policy_drains_chunks() {
        let target = ToyModel::new(3);
        let report = Scheduler::run(
            reqs(5, 0.0, 6),
            GreedyExecutor::new(&target),
            &ServeCfg::static_batch(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 5);
        assert!(report.completed.iter().all(|c| c.generated == 6));
    }

    #[test]
    fn static_policy_waits_to_fill_chunks_on_staggered_arrivals() {
        let exec = FakeExec { bytes_per_req: 1, live: Vec::new() };
        // arrivals 10 ms apart: a chunk of 2 must wait for its second
        // member instead of degenerating to batch size 1
        let report = Scheduler::run(reqs(4, 10.0, 3), exec, &ServeCfg::static_batch(2), 0).unwrap();
        assert_eq!(report.completed.len(), 4);
        // request 0 (arrival 0) only starts once request 1 (arrival 10)
        // has arrived, so its first token lands after the 10 ms wait
        assert!(
            report.completed[0].ttft_ms >= 10.0,
            "chunk started before it filled: ttft {}",
            report.completed[0].ttft_ms
        );
    }

    #[test]
    fn pool_idle_worker_jumps_to_earliest_event_across_workers() {
        // Per-worker capacity 1; r0 occupies worker 0 from t=0 and the
        // next arrival is 1e9 ms away. The empty-round jump must move only
        // the idle worker, straight to the arrival it is about to seat, in
        // O(1) (this test would effectively hang on a busy-advance) — and
        // the busy worker's in-flight request must not be dragged to the
        // far-future arrival time.
        let target = ToyModel::new(1);
        let mut requests = reqs(2, 0.0, 6);
        requests[1].arrival_ms = 1e9;
        let cfg = ServeCfg::continuous(1).with_workers(2);
        let report =
            WorkerPool::run(requests, |_| GreedyExecutor::new(&target), &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 2);
        assert!(
            report.completed[0].total_ms < 1e6,
            "busy worker dragged to the far arrival: {}",
            report.completed[0].total_ms
        );
        assert!(
            report.completed[1].ttft_ms < 1e6,
            "late arrival queued behind an idle worker: {}",
            report.completed[1].ttft_ms
        );
        // the stealer's clock lands on the arrival it seated
        assert!(report.makespan_ms >= 1e9);
    }

    #[test]
    fn pool_steals_work_across_workers_with_identical_outputs() {
        // 6 simultaneous arrivals, per-worker capacity 1: three workers
        // drain the shared queue in parallel lanes; outputs stay
        // bit-identical to the single-worker run, nothing duplicated or
        // dropped.
        let target = ToyModel::new(3);
        let one = WorkerPool::run(
            reqs(6, 0.0, 8),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::continuous(1),
            0,
        )
        .unwrap();
        let three = WorkerPool::run(
            reqs(6, 0.0, 8),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::continuous(1).with_workers(3),
            0,
        )
        .unwrap();
        assert_eq!(three.completed.len(), 6);
        assert_eq!(three.workers(), 3);
        assert_eq!(one.total_tokens, three.total_tokens);
        for (a, b) in one.completed.iter().zip(&three.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "sharding changed request {}", a.id);
        }
    }

    #[test]
    fn pool_splits_budget_and_respects_worker_shares() {
        // 500 total bytes over 2 workers = 250 each: at most 2 of the
        // 100-byte requests in flight per worker, never more.
        let cfg = ServeCfg::continuous(8).with_budget(500).with_workers(2);
        assert_eq!(cfg.per_worker_budgets(), vec![250, 250]);
        let report = WorkerPool::run(
            reqs(9, 0.0, 3),
            |_| FakeExec { bytes_per_req: 100, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 9, "every request must complete");
        for (w, peak) in report.worker_peak_kv_bytes.iter().enumerate() {
            assert!(*peak <= 250, "worker {w} peak {peak} > share 250");
        }
        assert!(report.peak_kv_bytes <= 500, "pool peak {}", report.peak_kv_bytes);
    }

    #[test]
    fn pool_oversized_request_runs_alone_on_an_idle_worker() {
        // 1000-byte requests fit no worker's 200-byte share: the safety
        // valve routes each to an idle worker alone; nothing starves and
        // no worker ever holds two at once.
        let cfg = ServeCfg::continuous(8).with_budget(400).with_workers(2);
        let report = WorkerPool::run(
            reqs(4, 0.0, 2),
            |_| FakeExec { bytes_per_req: 1000, live: Vec::new() },
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 4, "safety valve must prevent starvation");
        for peak in &report.worker_peak_kv_bytes {
            assert!(*peak <= 1000, "oversized request must run alone: peak {peak}");
        }
    }

    #[test]
    fn scheduler_run_rejects_multi_worker_configs() {
        // one executor cannot staff two workers; no silent fallback to 1
        let target = ToyModel::new(1);
        let r = Scheduler::run(
            reqs(1, 0.0, 2),
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(2).with_workers(2),
            0,
        );
        assert!(r.is_err(), "Scheduler::run must reject workers > 1 loudly");
    }

    #[test]
    fn pool_rejects_budget_that_splits_to_zero() {
        // programmatic configs bypass YAML validation; the pool itself
        // must refuse shares of zero rather than run workers unlimited
        let cfg = ServeCfg::continuous(4).with_budget(3).with_workers(8);
        let r = WorkerPool::run(
            reqs(2, 0.0, 2),
            |_| FakeExec { bytes_per_req: 1, live: Vec::new() },
            &cfg,
            0,
        );
        assert!(r.is_err(), "zero shares must be rejected, not silently unlimited");
    }

    #[test]
    fn per_worker_budget_split_covers_total() {
        let cfg = ServeCfg::continuous(4).with_budget(1003).with_workers(4);
        let shares = cfg.per_worker_budgets();
        assert_eq!(shares.len(), 4);
        assert_eq!(shares.iter().sum::<usize>(), 1003);
        // unlimited stays unlimited on every worker
        assert_eq!(ServeCfg::continuous(4).per_worker_budgets(), vec![0]);
    }

    #[test]
    fn ensure_requests_fit_flags_budget_below_smallest_request() {
        let exec = FakeExec { bytes_per_req: 100, live: Vec::new() };
        let trace = reqs(3, 0.0, 2);
        // 90 bytes per worker: even the smallest request (100 bytes)
        // would need the safety valve — reject loudly
        let bad = ServeCfg::continuous(4).with_budget(180).with_workers(2);
        assert!(bad.ensure_requests_fit(&exec, &trace).is_err());
        let ok = ServeCfg::continuous(4).with_budget(200).with_workers(2);
        assert!(ok.ensure_requests_fit(&exec, &trace).is_ok());
        // unlimited budget always fits
        assert!(ServeCfg::continuous(4).ensure_requests_fit(&exec, &trace).is_ok());
    }

    #[test]
    fn pool_static_policy_drains_parallel_chunks() {
        let target = ToyModel::new(3);
        let report = WorkerPool::run(
            reqs(6, 0.0, 5),
            |_| GreedyExecutor::new(&target),
            &ServeCfg::static_batch(2).with_workers(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 6);
        assert!(report.completed.iter().all(|c| c.generated == 5));
    }

    #[test]
    fn admission_policy_parses() {
        assert_eq!(AdmissionPolicy::parse("continuous").unwrap(), AdmissionPolicy::Continuous);
        assert_eq!(AdmissionPolicy::parse("static").unwrap(), AdmissionPolicy::Static);
        assert_eq!(AdmissionPolicy::parse("sequential").unwrap(), AdmissionPolicy::Sequential);
        assert!(AdmissionPolicy::parse("magic").is_err());
        assert_eq!(AdmissionPolicy::Continuous.name(), "continuous");
    }
}
