//! Continuous-batching scheduler with KV-memory admission control.
//!
//! One loop serves every path: a per-request state machine
//!
//!     Queued ──admit──▶ Prefill ──first step──▶ Decoding ──▶ Finished
//!
//! driven by a [`Scheduler`] that, **between every decode round**, retires
//! finished requests and admits queued ones under a configurable KV-memory
//! budget (projected from [`KvCache`] bytes accounting), so a long-running
//! decode no longer blocks newly arrived short requests. Static batching
//! and sequential serving are degenerate configurations of the same loop
//! (see [`AdmissionPolicy`]), which is what unifies the time model across
//! `ServingEngine::serve` / `serve_batched` / `serve_batched_pjrt`.
//!
//! Compute is pluggable through [`StepExecutor`]: greedy KV-session
//! decoding ([`GreedyExecutor`]), speculative draft+target sessions with
//! rollback ([`SpecExecutor`]), or a joint batched forward over a PJRT
//! executable ([`PjrtBatchExecutor`]).
//!
//! Time model (unified across all paths): request *arrivals* are virtual
//! (from the workload trace); compute occupies real wall-clock measured
//! around each decode round. The virtual clock advances by the measured
//! round time; an empty round jumps straight to the next arrival in O(1)
//! (no busy-advance). Per-request TTFT = first-token round end − arrival,
//! total = finish round end − arrival, on the same clock everywhere.
//!
//! [`KvCache`]: crate::models::KvCache

use crate::data::TokenRequest;
use crate::models::Sampler;
use crate::runtime::ModelExecutable;
use crate::spec_decode::{spec_verify_step, DecodeSession, SessionModel};
use crate::tensor::ops::argmax;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

use super::engine::{CompletedRequest, ServeReport};

/// When the scheduler may move a request from Queued to Prefill.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit whenever a slot and KV budget are free — between every decode
    /// round. This is continuous batching.
    Continuous,
    /// Admit only when no request is in flight, up to `max_in_flight` at
    /// once: classic static batching (the whole chunk drains before the
    /// next one forms).
    Static,
    /// One request at a time, in arrival order (`max_in_flight` is forced
    /// to 1): the old per-request serve loop.
    Sequential,
}

impl AdmissionPolicy {
    /// Parse a config/CLI name ("continuous" | "static" | "sequential").
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "continuous" => AdmissionPolicy::Continuous,
            "static" => AdmissionPolicy::Static,
            "sequential" => AdmissionPolicy::Sequential,
            other => bail!(
                "unknown admission policy `{other}` (continuous | static | sequential)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Continuous => "continuous",
            AdmissionPolicy::Static => "static",
            AdmissionPolicy::Sequential => "sequential",
        }
    }
}

/// Scheduler configuration — the `serve:` section of a YAML config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeCfg {
    pub policy: AdmissionPolicy,
    /// concurrent-request cap (executors may clamp it further, e.g. to the
    /// PJRT batch dimension)
    pub max_in_flight: usize,
    /// KV-memory admission budget in bytes; 0 = unlimited. Admission
    /// reserves each request's *projected peak* KV bytes up front — and
    /// sessions are allocated at exactly that bound (`new_session_bounded`)
    /// — so both observable and resident KV memory stay within the budget.
    /// A single request projected over the whole budget is admitted alone
    /// (safety valve) rather than starving.
    pub kv_budget_bytes: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Continuous,
            max_in_flight: 8,
            kv_budget_bytes: 0,
        }
    }
}

impl ServeCfg {
    pub fn continuous(max_in_flight: usize) -> Self {
        ServeCfg { max_in_flight, ..ServeCfg::default() }
    }

    pub fn sequential() -> Self {
        ServeCfg { policy: AdmissionPolicy::Sequential, max_in_flight: 1, ..ServeCfg::default() }
    }

    pub fn static_batch(max_batch: usize) -> Self {
        ServeCfg {
            policy: AdmissionPolicy::Static,
            max_in_flight: max_batch,
            ..ServeCfg::default()
        }
    }

    pub fn with_budget(mut self, kv_budget_bytes: usize) -> Self {
        self.kv_budget_bytes = kv_budget_bytes;
        self
    }
}

/// Lifecycle of one request inside the scheduler. `Queued` and `Finished`
/// are the boundary states (the arrival queue, and the completed list with
/// the KV reservation released); the live set tracks only
/// `Prefill`/`Decoding`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    /// waiting for a slot / KV budget
    Queued,
    /// admitted; its first decode round (which feeds the prompt) has not
    /// completed yet
    Prefill,
    /// producing tokens, one round at a time
    Decoding,
    /// retired; its KV reservation is released
    Finished,
}

/// What one request did during one decode round.
#[derive(Clone, Debug)]
pub struct StepEvent {
    pub id: u64,
    /// tokens committed this round (greedy: 1; speculative: accepted + bonus)
    pub tokens: Vec<u8>,
    /// target verify/decode steps this round (the AL denominator)
    pub steps: usize,
    /// speculative tokens proposed this round
    pub proposed: usize,
    /// speculative tokens accepted this round
    pub accepted: usize,
    pub finished: bool,
}

/// Pluggable compute for one decode round over the live set. The scheduler
/// owns admission, retirement, the virtual clock, and metrics; executors
/// own per-request sessions and the model calls.
pub trait StepExecutor {
    /// Projected peak KV bytes `req` will hold while in flight — the
    /// amount admission control reserves against the budget.
    fn projected_bytes(&self, req: &TokenRequest) -> usize;
    /// Allocate per-request decode state. The request's first round (its
    /// Prefill step) runs at the next `step_round`.
    fn admit(&mut self, req: &TokenRequest) -> Result<()>;
    /// Advance every admitted request one decode round, returning one
    /// event per live request.
    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>>;
    /// Drop a finished request's state, freeing its KV bytes.
    fn retire(&mut self, id: u64);
    /// Resident KV bytes across live sessions (observability + the budget
    /// property test).
    fn live_bytes(&self) -> usize;
    /// Hard cap on concurrently-admittable requests (e.g. the PJRT batch
    /// dimension); `None` = bounded only by `ServeCfg::max_in_flight`.
    fn slot_cap(&self) -> Option<usize> {
        None
    }
}

struct LiveReq {
    id: u64,
    arrival_ms: f64,
    state: ReqState,
    output: Vec<u8>,
    first_token_ms: Option<f64>,
    reserved_bytes: usize,
}

/// The one serve loop. All `ServingEngine` entry points are thin policy
/// wrappers over [`Scheduler::run`].
pub struct Scheduler;

impl Scheduler {
    pub fn run<E: StepExecutor>(
        mut requests: Vec<TokenRequest>,
        mut executor: E,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        let mut rng = Rng::new(seed);
        // stable sort: FIFO among simultaneous arrivals
        requests.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
        let mut max_in_flight = match cfg.policy {
            AdmissionPolicy::Sequential => 1,
            _ => cfg.max_in_flight.max(1),
        };
        if let Some(cap) = executor.slot_cap() {
            max_in_flight = max_in_flight.min(cap.max(1));
        }

        let t0 = Instant::now();
        let mut clock_ms = 0.0f64;
        let mut queue: VecDeque<TokenRequest> = requests.into();
        let mut live: Vec<LiveReq> = Vec::new();
        let mut reserved_bytes = 0usize;
        let mut completed: Vec<CompletedRequest> = Vec::new();
        let mut total_tokens = 0usize;
        let mut al_num = 0.0f64;
        let mut al_den = 0.0f64;
        let mut proposed = 0usize;
        let mut accepted = 0usize;
        let mut peak_kv_bytes = 0usize;

        loop {
            // ── between-round admission ──────────────────────────────
            let may_admit = match cfg.policy {
                AdmissionPolicy::Static => {
                    // classic static batching waits for the whole chunk:
                    // jump the clock to the last arrival of the requests
                    // the next chunk can actually admit (slot cap AND KV
                    // budget), so chunks neither degenerate to size 1 on
                    // staggered traces nor wait for arrivals the budget
                    // could never seat
                    if live.is_empty() && !queue.is_empty() {
                        let mut k = 0usize;
                        let mut sum = 0usize;
                        for r in queue.iter().take(max_in_flight) {
                            let need = executor.projected_bytes(r);
                            let fits = cfg.kv_budget_bytes == 0
                                || sum + need <= cfg.kv_budget_bytes
                                || (k == 0 && need > cfg.kv_budget_bytes);
                            if !fits {
                                break;
                            }
                            sum += need;
                            k += 1;
                        }
                        let chunk_arrival = queue
                            .iter()
                            .take(k)
                            .map(|r| r.arrival_ms)
                            .fold(f64::NEG_INFINITY, f64::max);
                        clock_ms = clock_ms.max(chunk_arrival);
                    }
                    live.is_empty()
                }
                _ => true,
            };
            if may_admit {
                while live.len() < max_in_flight {
                    let Some(head) = queue.front() else { break };
                    if head.arrival_ms > clock_ms {
                        break;
                    }
                    let need = executor.projected_bytes(head);
                    let fits = cfg.kv_budget_bytes == 0
                        || reserved_bytes + need <= cfg.kv_budget_bytes
                        // oversized-request safety valve: a request that
                        // could never fit runs alone instead of starving
                        || (live.is_empty() && need > cfg.kv_budget_bytes);
                    if !fits {
                        // strict FIFO: never admit past a blocked head, so
                        // freed bytes always reach the oldest request
                        break;
                    }
                    let req = queue.pop_front().unwrap();
                    executor.admit(&req)?;
                    reserved_bytes += need;
                    live.push(LiveReq {
                        id: req.id,
                        arrival_ms: req.arrival_ms,
                        state: ReqState::Prefill,
                        output: Vec::new(),
                        first_token_ms: None,
                        reserved_bytes: need,
                    });
                }
            }

            if live.is_empty() {
                let Some(head) = queue.front() else { break };
                // empty round: jump the clock straight to the next arrival
                // in O(1) — the worker sleeps until then
                clock_ms = clock_ms.max(head.arrival_ms);
                continue;
            }

            // ── one measured decode round over the live set ──────────
            let round_t0 = Instant::now();
            let events = executor.step_round(&mut rng)?;
            clock_ms += round_t0.elapsed().as_secs_f64() * 1e3;
            peak_kv_bytes = peak_kv_bytes.max(executor.live_bytes());

            // ── retire finished, book metrics on the shared clock ────
            for ev in events {
                let idx = live
                    .iter()
                    .position(|l| l.id == ev.id)
                    .expect("step event for a request that was never admitted");
                {
                    let l = &mut live[idx];
                    debug_assert!(
                        matches!(l.state, ReqState::Prefill | ReqState::Decoding),
                        "step event for a request outside Prefill/Decoding"
                    );
                    if !ev.tokens.is_empty() {
                        if l.first_token_ms.is_none() {
                            l.first_token_ms = Some(clock_ms);
                        }
                        l.state = ReqState::Decoding;
                    }
                    total_tokens += ev.tokens.len();
                    al_num += ev.tokens.len() as f64;
                    al_den += ev.steps as f64;
                    proposed += ev.proposed;
                    accepted += ev.accepted;
                    l.output.extend_from_slice(&ev.tokens);
                }
                if ev.finished {
                    let l = live.swap_remove(idx);
                    executor.retire(l.id);
                    reserved_bytes -= l.reserved_bytes;
                    completed.push(CompletedRequest {
                        id: l.id,
                        generated: l.output.len(),
                        ttft_ms: l.first_token_ms.unwrap_or(clock_ms) - l.arrival_ms,
                        total_ms: clock_ms - l.arrival_ms,
                        output: l.output,
                    });
                }
            }
        }

        completed.sort_by_key(|c| c.id);
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            total_tokens,
            mean_al: if al_den == 0.0 { 0.0 } else { al_num / al_den },
            proposed,
            accepted,
            peak_kv_bytes,
        })
    }
}

// ─────────────────────────────────────────────────────────────────────
// Executors
// ─────────────────────────────────────────────────────────────────────

struct GreedySlot<T: SessionModel> {
    id: u64,
    prompt: Vec<u8>,
    sess: T::Session,
    /// tokens still to generate; 0 at admission means the request can
    /// never start (empty prompt / no context room) and finishes empty
    remaining: usize,
    last: Option<Vec<f32>>,
}

/// Greedy KV-session decoding: per request, one prompt prefill then one
/// cached decode step per round — per-request output bit-identical to
/// `VanillaDecoder` (and to the old static `serve_batched` loop).
pub struct GreedyExecutor<'a, T: SessionModel> {
    model: &'a T,
    sampler: Sampler,
    slots: Vec<GreedySlot<T>>,
}

impl<'a, T: SessionModel> GreedyExecutor<'a, T> {
    pub fn new(model: &'a T) -> Self {
        GreedyExecutor { model, sampler: Sampler::Greedy, slots: Vec::new() }
    }

    /// Most tokens this request's session can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.model.max_t())
    }
}

impl<T: SessionModel> StepExecutor for GreedyExecutor<'_, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req) * self.model.kv_bytes_per_token()
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.model.max_t().saturating_sub(req.prompt.len()))
        };
        self.slots.push(GreedySlot {
            id: req.id,
            prompt: req.prompt.clone(),
            // sized to the projected peak, so the session's resident
            // allocation is what admission reserved against the budget
            sess: self.model.new_session_bounded(self.peak_tokens(req)),
            remaining: budget,
            last: None,
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let model = self.model;
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            if slot.remaining == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            // Prefill state: the first round feeds the whole prompt
            if slot.last.is_none() {
                slot.last = slot.sess.extend(model, &slot.prompt)?.pop();
            }
            let next = {
                let row = slot.last.as_ref().expect("non-empty prompt yields a logits row");
                self.sampler.sample(row, rng)
            };
            slot.remaining -= 1;
            let finished = slot.remaining == 0;
            // like VanillaDecoder, the final committed token is never fed back
            slot.last = if finished {
                None
            } else {
                Some(slot.sess.extend(model, &[next])?.pop().unwrap())
            };
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.sess.kv_bytes()).sum()
    }
}

struct SpecSlot<D: SessionModel, T: SessionModel> {
    id: u64,
    seq: Vec<u8>,
    budget: usize,
    generated: usize,
    dsess: D::Session,
    tsess: T::Session,
}

/// Speculative draft-propose / target-verify decoding threaded through the
/// continuous loop: each request keeps a draft and a target KV session;
/// one round = one verify step (catch-up + γ proposals + bonus), with both
/// caches rolled back to the accepted prefix — per-request output
/// bit-identical to `SpecDecoder::generate`.
pub struct SpecExecutor<'a, D: SessionModel, T: SessionModel> {
    draft: &'a D,
    target: &'a T,
    gamma: usize,
    sampler: Sampler,
    slots: Vec<SpecSlot<D, T>>,
}

impl<'a, D: SessionModel, T: SessionModel> SpecExecutor<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize) -> Self {
        SpecExecutor { draft, target, gamma, sampler: Sampler::Greedy, slots: Vec::new() }
    }

    fn limit(&self) -> usize {
        self.target.max_t().min(self.draft.max_t())
    }

    /// Most tokens this request's sessions can come to hold.
    fn peak_tokens(&self, req: &TokenRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.limit())
    }
}

impl<D: SessionModel, T: SessionModel> StepExecutor for SpecExecutor<'_, D, T> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.peak_tokens(req)
            * (self.target.kv_bytes_per_token() + self.draft.kv_bytes_per_token())
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.limit().saturating_sub(req.prompt.len()))
        };
        let peak_t = self.peak_tokens(req);
        self.slots.push(SpecSlot {
            id: req.id,
            seq: req.prompt.clone(),
            budget,
            generated: 0,
            dsess: self.draft.new_session_bounded(peak_t),
            tsess: self.target.new_session_bounded(peak_t),
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let draft = self.draft;
        let target = self.target;
        let gamma = self.gamma;
        let limit = self.limit();
        let mut events = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            // saturating: an over-long prompt admits with budget 0 and the
            // limit term must not underflow before the room hits 0
            let room = limit
                .saturating_sub(slot.seq.len())
                .min(gamma)
                .min(slot.budget.saturating_sub(slot.generated));
            if room == 0 {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            // one shared verify step: draft catch-up + γ proposals, single
            // target pass, greedy acceptance + bonus, rollback — the same
            // function SpecDecoder::generate runs per iteration
            let (tokens, proposed, accepted) = spec_verify_step(
                draft,
                target,
                &mut slot.dsess,
                &mut slot.tsess,
                &mut slot.seq,
                room,
                slot.budget - slot.generated,
                limit,
                &self.sampler,
                rng,
            )?;
            slot.generated += tokens.len();

            let finished = slot.generated >= slot.budget || slot.seq.len() >= limit;
            events.push(StepEvent {
                id: slot.id,
                tokens,
                steps: 1,
                proposed,
                accepted,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.dsess.kv_bytes() + s.tsess.kv_bytes())
            .sum()
    }
}

struct PjrtSlot {
    id: u64,
    seq: Vec<u8>,
    prompt_len: usize,
    max_new: usize,
}

/// Joint batched greedy decoding over a b>1 PJRT executable: every live
/// request occupies one batch row and the whole set advances one token per
/// joint forward. Slot count is capped by the executable's batch dim.
pub struct PjrtBatchExecutor<'a> {
    exe: &'a ModelExecutable,
    slots: Vec<PjrtSlot>,
}

impl<'a> PjrtBatchExecutor<'a> {
    pub fn new(exe: &'a ModelExecutable) -> Self {
        PjrtBatchExecutor { exe, slots: Vec::new() }
    }
}

impl StepExecutor for PjrtBatchExecutor<'_> {
    fn projected_bytes(&self, _req: &TokenRequest) -> usize {
        0 // the executable re-forwards per round; no resident KV state
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        self.slots.push(PjrtSlot {
            id: req.id,
            seq: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
        });
        Ok(())
    }

    fn step_round(&mut self, _rng: &mut Rng) -> Result<Vec<StepEvent>> {
        let (b, seq_t, vocab) = (self.exe.batch, self.exe.seq_t, self.exe.vocab);
        // pack the live set into the batch (free rows stay zero)
        let mut tokens = vec![0i32; b * seq_t];
        for (ri, slot) in self.slots.iter().enumerate() {
            for (i, &t) in slot.seq.iter().enumerate().take(seq_t) {
                tokens[ri * seq_t + i] = t as i32;
            }
        }
        let logits = self.exe.run(&tokens)?;
        let mut events = Vec::with_capacity(self.slots.len());
        for (ri, slot) in self.slots.iter_mut().enumerate() {
            let done = slot.seq.is_empty()
                || slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            if done {
                events.push(StepEvent {
                    id: slot.id,
                    tokens: Vec::new(),
                    steps: 0,
                    proposed: 0,
                    accepted: 0,
                    finished: true,
                });
                continue;
            }
            let pos = slot.seq.len() - 1;
            let off = ri * seq_t * vocab + pos * vocab;
            let next = argmax(&logits[off..off + vocab]) as u8;
            slot.seq.push(next);
            let finished = slot.seq.len() >= seq_t
                || slot.seq.len() - slot.prompt_len >= slot.max_new;
            events.push(StepEvent {
                id: slot.id,
                tokens: vec![next],
                steps: 1,
                proposed: 0,
                accepted: 0,
                finished,
            });
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        0
    }

    fn slot_cap(&self) -> Option<usize> {
        Some(self.exe.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec_decode::engine::tests_support::ToyModel;

    fn reqs(n: usize, gap_ms: f64, max_new: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: max_new,
                arrival_ms: i as f64 * gap_ms,
            })
            .collect()
    }

    #[test]
    fn continuous_matches_sequential_outputs_on_toy_model() {
        let target = ToyModel::new(3);
        let seq = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::sequential(),
            0,
        )
        .unwrap();
        let cont = Scheduler::run(
            reqs(6, 2.0, 10),
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        assert_eq!(seq.completed.len(), 6);
        assert_eq!(cont.completed.len(), 6);
        assert_eq!(seq.total_tokens, cont.total_tokens);
        for (a, b) in seq.completed.iter().zip(&cont.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "continuous changed request {}", a.id);
        }
    }

    #[test]
    fn empty_rounds_jump_to_next_arrival_in_o1() {
        let target = ToyModel::new(1);
        let mut requests = reqs(2, 0.0, 4);
        // a gap the old clock_ms += 1.0 busy-advance would crawl across
        // one millisecond at a time (1e9 iterations)
        requests[1].arrival_ms = 1e9;
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 2);
        // the late request starts right at its arrival: no residual queueing
        assert!(report.completed[1].ttft_ms < 1e6, "{}", report.completed[1].ttft_ms);
    }

    #[test]
    fn zero_budget_requests_finish_empty() {
        let target = ToyModel::new(2);
        let mut requests = reqs(3, 1.0, 5);
        requests[1].max_new_tokens = 0;
        requests[2].prompt = vec![1u8; 64]; // fills max_t: no room to decode
        let report = Scheduler::run(
            requests,
            GreedyExecutor::new(&target),
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.completed[0].generated, 5);
        assert_eq!(report.completed[1].generated, 0);
        assert_eq!(report.completed[2].generated, 0);
    }

    /// Mock executor with synthetic KV accounting: each request reserves a
    /// fixed byte count and runs for `max_new_tokens` rounds.
    struct FakeExec {
        bytes_per_req: usize,
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for FakeExec {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            self.bytes_per_req
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![7],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len() * self.bytes_per_req
        }
    }

    #[test]
    fn kv_budget_caps_concurrency_without_starvation() {
        let exec = FakeExec { bytes_per_req: 100, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // fits 2 of 100
        let report = Scheduler::run(reqs(7, 0.0, 3), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 7, "every request must complete");
        assert!(report.peak_kv_bytes <= 250, "peak {} > budget", report.peak_kv_bytes);
    }

    #[test]
    fn oversized_request_admitted_alone_not_starved() {
        let exec = FakeExec { bytes_per_req: 1000, live: Vec::new() };
        let cfg = ServeCfg::continuous(8).with_budget(250); // smaller than one request
        let report = Scheduler::run(reqs(3, 0.0, 2), exec, &cfg, 0).unwrap();
        assert_eq!(report.completed.len(), 3, "safety valve must prevent deadlock");
    }

    #[test]
    fn static_policy_drains_chunks() {
        let target = ToyModel::new(3);
        let report = Scheduler::run(
            reqs(5, 0.0, 6),
            GreedyExecutor::new(&target),
            &ServeCfg::static_batch(2),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 5);
        assert!(report.completed.iter().all(|c| c.generated == 6));
    }

    #[test]
    fn static_policy_waits_to_fill_chunks_on_staggered_arrivals() {
        let exec = FakeExec { bytes_per_req: 1, live: Vec::new() };
        // arrivals 10 ms apart: a chunk of 2 must wait for its second
        // member instead of degenerating to batch size 1
        let report = Scheduler::run(reqs(4, 10.0, 3), exec, &ServeCfg::static_batch(2), 0).unwrap();
        assert_eq!(report.completed.len(), 4);
        // request 0 (arrival 0) only starts once request 1 (arrival 10)
        // has arrived, so its first token lands after the 10 ms wait
        assert!(
            report.completed[0].ttft_ms >= 10.0,
            "chunk started before it filled: ttft {}",
            report.completed[0].ttft_ms
        );
    }

    #[test]
    fn admission_policy_parses() {
        assert_eq!(AdmissionPolicy::parse("continuous").unwrap(), AdmissionPolicy::Continuous);
        assert_eq!(AdmissionPolicy::parse("static").unwrap(), AdmissionPolicy::Static);
        assert_eq!(AdmissionPolicy::parse("sequential").unwrap(), AdmissionPolicy::Sequential);
        assert!(AdmissionPolicy::parse("magic").is_err());
        assert_eq!(AdmissionPolicy::Continuous.name(), "continuous");
    }
}
