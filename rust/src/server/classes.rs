//! Workload classes for SLO-aware serving.
//!
//! Production traffic is a mix of interactive chat, long-context
//! prefill, multimodal, and offline batch requests, each with its own
//! latency objective. A [`RequestClass`] tag rides on every
//! [`TokenRequest`](crate::data::TokenRequest); a [`ClassPolicy`]
//! (from `serve.classes:`) gives each class an SLO + priority and
//! drives three scheduler behaviors:
//!
//! - **class-priority admission** over the shared FIFO (strict FIFO
//!   within a class, an aging bound so Batch can never starve);
//! - **admission-time compression routing**: LongContext prompts
//!   prefill through the STeM-masked sparse-attention path, and
//!   Multimodal prompts are pruned (IDPruner for the visual segment,
//!   Samp for the audio segment) *before* KV admission so the pool is
//!   charged for the pruned prompt;
//! - **priority-aware preemption**: on KV pressure, victims are chosen
//!   by (priority, progress) instead of progress alone.
//!
//! With `serve.classes:` absent everything here is inert and the pool
//! behaves exactly as before.

use crate::token_prune::audio::Samp;
use crate::token_prune::visual::IdPruner;
use crate::token_prune::{PruneContext, Pruner, Reducer};

/// Workload class carried on every request. [`Default`] is
/// `Interactive`, so untagged traffic keeps today's behavior.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestClass {
    /// Interactive chat: short prompts, tight TTFT.
    Interactive,
    /// Long-context prefill: routed through the STeM sparse-attention
    /// prefill path when a [`ClassPolicy`] is configured.
    LongContext,
    /// Multimodal: the leading `visual_tokens` prompt bytes are a visual
    /// segment and the next `audio_tokens` an audio segment; both are
    /// token-pruned at admission when a [`ClassPolicy`] is configured.
    Multimodal { visual_tokens: usize, audio_tokens: usize },
    /// Offline batch: lowest priority, protected from starvation by the
    /// policy's aging bound.
    Batch,
}

impl Default for RequestClass {
    fn default() -> Self {
        RequestClass::Interactive
    }
}

impl RequestClass {
    /// Stable grouping key (multimodal token counts are per-request
    /// payload, not identity).
    pub fn name(&self) -> &'static str {
        match self {
            RequestClass::Interactive => "interactive",
            RequestClass::LongContext => "long_context",
            RequestClass::Multimodal { .. } => "multimodal",
            RequestClass::Batch => "batch",
        }
    }

    /// All class names, in report order.
    pub const NAMES: [&'static str; 4] =
        ["interactive", "long_context", "multimodal", "batch"];
}

/// Per-class service-level objective + scheduling priority.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSlo {
    /// Time-to-first-token objective (virtual-clock ms).
    pub ttft_slo_ms: f64,
    /// End-to-end latency objective (virtual-clock ms).
    pub latency_slo_ms: f64,
    /// Per-class default deadline. Precedence: per-request
    /// `deadline_ms` > this > pool-wide `serve.deadline_ms`.
    pub deadline_ms: Option<f64>,
    /// Admission priority; higher wins the next admission slot.
    pub priority: u8,
}

impl ClassSlo {
    pub fn new(ttft_slo_ms: f64, latency_slo_ms: f64, priority: u8) -> Self {
        ClassSlo { ttft_slo_ms, latency_slo_ms, deadline_ms: None, priority }
    }
}

/// The `serve.classes:` policy: per-class SLOs plus the knobs for the
/// aging bound and the admission-time compression routing.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassPolicy {
    pub interactive: ClassSlo,
    pub long_context: ClassSlo,
    pub multimodal: ClassSlo,
    pub batch: ClassSlo,
    /// Starvation bound: a queued request that has waited this long
    /// (virtual-clock ms since arrival) competes at the maximum
    /// priority, so low-priority classes always eventually run.
    pub aging_ms: f64,
    /// STeM block size for the LongContext sparse-prefill route.
    pub sparse_block: usize,
    /// Fraction of causal key blocks each query block keeps in the
    /// LongContext sparse-prefill route.
    pub sparse_budget: f64,
    /// Fraction of each multimodal segment retained by admission-time
    /// token pruning.
    pub multimodal_retain: f64,
}

impl Default for ClassPolicy {
    fn default() -> Self {
        ClassPolicy {
            interactive: ClassSlo::new(50.0, 500.0, 3),
            long_context: ClassSlo::new(500.0, 5_000.0, 1),
            multimodal: ClassSlo::new(200.0, 2_000.0, 2),
            batch: ClassSlo::new(10_000.0, 60_000.0, 0),
            aging_ms: 500.0,
            sparse_block: 16,
            sparse_budget: 0.5,
            multimodal_retain: 0.5,
        }
    }
}

impl ClassPolicy {
    pub fn slo_of(&self, class: &RequestClass) -> &ClassSlo {
        match class {
            RequestClass::Interactive => &self.interactive,
            RequestClass::LongContext => &self.long_context,
            RequestClass::Multimodal { .. } => &self.multimodal,
            RequestClass::Batch => &self.batch,
        }
    }

    pub fn slo_of_name(&self, name: &str) -> &ClassSlo {
        match name {
            "interactive" => &self.interactive,
            "long_context" => &self.long_context,
            "multimodal" => &self.multimodal,
            "batch" => &self.batch,
            other => panic!("unknown request class {other:?}"),
        }
    }

    pub fn priority_of(&self, class: &RequestClass) -> u8 {
        self.slo_of(class).priority
    }

    /// The priority an aged-out request competes at.
    pub fn max_priority(&self) -> u8 {
        [&self.interactive, &self.long_context, &self.multimodal, &self.batch]
            .iter()
            .map(|s| s.priority)
            .max()
            .unwrap()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, slo) in [
            ("interactive", &self.interactive),
            ("long_context", &self.long_context),
            ("multimodal", &self.multimodal),
            ("batch", &self.batch),
        ] {
            anyhow::ensure!(
                slo.ttft_slo_ms > 0.0 && slo.latency_slo_ms > 0.0,
                "serve.classes.{name}: SLOs must be > 0"
            );
            if let Some(d) = slo.deadline_ms {
                anyhow::ensure!(d > 0.0, "serve.classes.{name}.deadline_ms must be > 0");
            }
        }
        anyhow::ensure!(
            self.aging_ms >= 0.0 && self.aging_ms.is_finite(),
            "serve.classes.aging_ms must be finite and >= 0"
        );
        anyhow::ensure!(self.sparse_block > 0, "serve.classes.sparse_block must be > 0");
        anyhow::ensure!(
            self.sparse_budget > 0.0 && self.sparse_budget <= 1.0,
            "serve.classes.sparse_budget must be in (0, 1]"
        );
        anyhow::ensure!(
            self.multimodal_retain > 0.0 && self.multimodal_retain <= 1.0,
            "serve.classes.multimodal_retain must be in (0, 1]"
        );
        Ok(())
    }
}

// ─────────────────────────────────────────────────────────────────────
// Admission-time multimodal prompt pruning
// ─────────────────────────────────────────────────────────────────────

/// Deterministic per-token feature for admission-time pruning: a tiny
/// embedding of (token byte, position) so similarity structure follows
/// the token content, with a positional ramp so order still matters.
fn token_feature(b: u8, pos: usize) -> Vec<f32> {
    let x = b as f32 / 255.0;
    vec![
        (x * std::f32::consts::TAU).sin(),
        (x * std::f32::consts::TAU).cos(),
        ((b % 17) as f32) / 16.0,
        pos as f32 * 0.01,
    ]
}

/// Deterministic per-token importance (always non-empty: the Samp
/// reducer indexes it directly).
fn token_importance(seg: &[u8]) -> Vec<f32> {
    seg.iter().map(|&b| 0.05 + ((b % 31) as f32) / 31.0).collect()
}

/// Prune a multimodal prompt at admission: the leading `visual_tokens`
/// bytes go through IDPruner, the next `audio_tokens` through Samp's
/// merge-then-prune, and the text tail is kept verbatim. Each segment
/// retains `ceil(len * retain)` tokens (at least 1). Returns the pruned
/// prompt and the number of tokens dropped.
pub fn prune_multimodal_prompt(
    prompt: &[u8],
    visual_tokens: usize,
    audio_tokens: usize,
    retain: f64,
) -> (Vec<u8>, usize) {
    let vis_n = visual_tokens.min(prompt.len());
    let aud_n = audio_tokens.min(prompt.len() - vis_n);
    let (vis, rest) = prompt.split_at(vis_n);
    let (aud, text) = rest.split_at(aud_n);

    let keep_n = |n: usize| (((n as f64) * retain).ceil() as usize).clamp(1, n.max(1));

    let mut out = Vec::with_capacity(prompt.len());
    if !vis.is_empty() {
        let feats: Vec<Vec<f32>> =
            vis.iter().enumerate().map(|(i, &b)| token_feature(b, i)).collect();
        let imp = token_importance(vis);
        let ctx = PruneContext { features: &feats, importance: &imp, retain: keep_n(vis.len()) };
        for i in IdPruner::default().apply(&ctx) {
            out.push(vis[i]);
        }
    }
    if !aud.is_empty() {
        let feats: Vec<Vec<f32>> =
            aud.iter().enumerate().map(|(i, &b)| token_feature(b, i)).collect();
        let imp = token_importance(aud);
        let ctx = PruneContext { features: &feats, importance: &imp, retain: keep_n(aud.len()) };
        let mut reduced = Samp::default().reduce(&ctx);
        reduced.truncate(keep_n(aud.len()));
        for r in reduced {
            out.push(aud[r.first_pos]);
        }
    }
    out.extend_from_slice(text);
    let pruned = prompt.len() - out.len();
    (out, pruned)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_orders_priorities() {
        let p = ClassPolicy::default();
        assert!(p.interactive.priority > p.multimodal.priority);
        assert!(p.multimodal.priority > p.long_context.priority);
        assert!(p.long_context.priority > p.batch.priority);
        assert_eq!(p.max_priority(), p.interactive.priority);
        p.validate().unwrap();
    }

    #[test]
    fn slo_lookup_matches_class() {
        let p = ClassPolicy::default();
        assert_eq!(
            p.slo_of(&RequestClass::Multimodal { visual_tokens: 4, audio_tokens: 0 }),
            &p.multimodal
        );
        assert_eq!(p.slo_of(&RequestClass::Batch), &p.batch);
        for n in RequestClass::NAMES {
            let _ = p.slo_of_name(n);
        }
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut p = ClassPolicy::default();
        p.sparse_budget = 0.0;
        assert!(p.validate().is_err());
        let mut p = ClassPolicy::default();
        p.multimodal_retain = 1.5;
        assert!(p.validate().is_err());
        let mut p = ClassPolicy::default();
        p.interactive.ttft_slo_ms = 0.0;
        assert!(p.validate().is_err());
        let mut p = ClassPolicy::default();
        p.aging_ms = f64::NAN;
        assert!(p.validate().is_err());
    }

    #[test]
    fn multimodal_prune_keeps_text_tail_and_is_deterministic() {
        let prompt: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(37)).collect();
        let (a, dropped_a) = prune_multimodal_prompt(&prompt, 24, 16, 0.5);
        let (b, dropped_b) = prune_multimodal_prompt(&prompt, 24, 16, 0.5);
        assert_eq!(a, b, "admission pruning must be deterministic");
        assert_eq!(dropped_a, dropped_b);
        assert!(dropped_a > 0, "a 0.5 retain must drop tokens");
        assert_eq!(a.len() + dropped_a, prompt.len());
        // the text tail (last 24 bytes) survives verbatim
        assert!(a.ends_with(&prompt[40..]));
        // pruned segments keep at least the retain fraction's worth
        assert!(a.len() >= 24 + 12 + 8);
    }

    #[test]
    fn multimodal_prune_clamps_oversized_segments() {
        let prompt = vec![7u8; 10];
        let (out, dropped) = prune_multimodal_prompt(&prompt, 100, 100, 0.5);
        assert_eq!(out.len() + dropped, 10);
        assert!(!out.is_empty());
        // retain 1.0 is the identity on the visual path
        let (all, d) = prune_multimodal_prompt(&prompt, 10, 0, 1.0);
        assert_eq!(all, prompt);
        assert_eq!(d, 0);
    }
}
