//! Deterministic fault injection for the serving pool.
//!
//! [`FaultInjector`] wraps any [`StepExecutor`] and, driven by a seeded
//! [`FaultPlan`], emits per-request step errors, NaN/poisoned logits,
//! worker stalls (virtual-clock inflation), and whole-worker crashes at
//! chosen virtual times. Per-request draws are keyed on
//! `(plan seed, request id, attempt, round)` — *not* on the shared
//! decode RNG or wall time — so a given request faults at the same point
//! of the same attempt for every worker count and admission
//! interleaving, which is what makes the chaos property tests
//! (`tests/test_fault_props.rs`) reproducible, and retried attempts see
//! fresh draws so bounded retry actually recovers.
//!
//! The injector is engaged by [`ServeCfg::fault`]: `WorkerPool::run`
//! wraps every worker's executor when a plan is present, and builds the
//! bare executor otherwise — a fault-free config runs byte-identical to
//! the pre-injection scheduler.
//!
//! [`ServeCfg::fault`]: super::scheduler::ServeCfg

use super::scheduler::{StepEvent, StepExecutor, StepFault};
use crate::data::TokenRequest;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::fmt;

/// Kill one worker the first time its clock reaches `at_ms`.
#[derive(Clone, Debug, PartialEq)]
pub struct CrashPoint {
    pub worker: usize,
    /// virtual time (ms) at/after which the worker's next round crashes
    pub at_ms: f64,
}

/// A reproducible chaos profile — the `serve.fault:` YAML block.
///
/// Rates are per live request per round, in `[0, 1]`. All fields default
/// to "no fault", so `FaultPlan::default()` is a valid no-op plan and
/// each knob can be enabled independently.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// seed for every injection draw (independent of the decode seed)
    pub seed: u64,
    /// probability a request's round is replaced by a step error
    pub step_error_rate: f64,
    /// probability a request's round is replaced by poisoned (NaN) logits
    pub nan_rate: f64,
    /// probability a worker's round additionally stalls by `stall_ms`
    pub stall_rate: f64,
    /// virtual milliseconds added to the worker clock per stall
    pub stall_ms: f64,
    /// scheduled whole-worker crashes
    pub crashes: Vec<CrashPoint>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            step_error_rate: 0.0,
            nan_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 0.0,
            crashes: Vec::new(),
        }
    }
}

impl FaultPlan {
    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_step_errors(mut self, rate: f64) -> Self {
        self.step_error_rate = rate;
        self
    }

    pub fn with_nan(mut self, rate: f64) -> Self {
        self.nan_rate = rate;
        self
    }

    pub fn with_stalls(mut self, rate: f64, stall_ms: f64) -> Self {
        self.stall_rate = rate;
        self.stall_ms = stall_ms;
        self
    }

    pub fn with_crash(mut self, worker: usize, at_ms: f64) -> Self {
        self.crashes.push(CrashPoint { worker, at_ms });
        self
    }

    /// True when the plan injects nothing (all rates zero, no crashes).
    pub fn is_noop(&self) -> bool {
        self.step_error_rate == 0.0
            && self.nan_rate == 0.0
            && self.stall_rate == 0.0
            && self.crashes.is_empty()
    }

    /// Reject malformed plans loudly: rates outside `[0, 1]`, negative
    /// stall/crash times, or a crash aimed at a worker the pool does not
    /// have (`workers` is the pool size).
    pub fn validate(&self, workers: usize) -> Result<()> {
        for (name, rate) in [
            ("step_error_rate", self.step_error_rate),
            ("nan_rate", self.nan_rate),
            ("stall_rate", self.stall_rate),
        ] {
            if rate.is_nan() || !(0.0..=1.0).contains(&rate) {
                bail!("fault.{name} must be a probability in [0, 1], got {rate}");
            }
        }
        if self.stall_ms.is_nan() || self.stall_ms < 0.0 {
            bail!("fault.stall_ms must be >= 0, got {}", self.stall_ms);
        }
        for c in &self.crashes {
            if c.worker >= workers {
                bail!(
                    "fault.crash_worker {} is out of range for a pool of {workers} \
                     worker(s)",
                    c.worker
                );
            }
            if c.at_ms.is_nan() || c.at_ms < 0.0 {
                bail!("fault.crash_at_ms must be >= 0, got {}", c.at_ms);
            }
        }
        Ok(())
    }
}

/// Typed error for an injected whole-worker crash, so the pool (and the
/// crash log in `ServeReport::crashed_workers`) can tell scheduled chaos
/// from a real executor failure.
#[derive(Clone, Debug)]
pub struct WorkerCrash {
    pub worker: usize,
    /// worker clock when the crash fired
    pub at_ms: f64,
}

impl fmt::Display for WorkerCrash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected crash of worker {} at virtual t={:.3} ms",
            self.worker, self.at_ms
        )
    }
}

impl std::error::Error for WorkerCrash {}

/// A [`StepExecutor`] wrapper that injects the faults of a [`FaultPlan`].
///
/// Healthy events coming out of the inner executor are deterministically
/// replaced with [`StepFault`]s; pure-retirement events (no compute this
/// round) are never faulted. Crash checks run before the inner round so a
/// scheduled crash loses the round's work, like a real one.
pub struct FaultInjector<E: StepExecutor> {
    inner: E,
    plan: FaultPlan,
    worker: usize,
    /// worker-local stream for stall draws (worker-level, not per-request)
    stall_rng: Rng,
    /// per-request admission count = the attempt currently executing
    admits: HashMap<u64, usize>,
    /// rounds stepped in the current attempt, per live request
    rounds: HashMap<u64, u64>,
    pending_stall_ms: f64,
    crashed: bool,
}

impl<E: StepExecutor> FaultInjector<E> {
    pub fn new(inner: E, plan: FaultPlan, worker: usize) -> Self {
        let stall_rng = Rng::new(
            plan.seed ^ 0xFA17_5EED ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        FaultInjector {
            inner,
            plan,
            worker,
            stall_rng,
            admits: HashMap::new(),
            rounds: HashMap::new(),
            pending_stall_ms: 0.0,
            crashed: false,
        }
    }

    /// Deterministic uniform draw for one (request, attempt, round, fault
    /// kind) tuple — independent of worker count and interleaving.
    fn draw(&self, id: u64, attempt: usize, round: u64, salt: u64) -> f64 {
        let mut h = self.plan.seed ^ 0x5EED_FA17;
        h ^= id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= (attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
        h ^= round.wrapping_mul(0x8CB9_2BA7_2F3D_8DD7);
        h ^= salt.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(h).f64()
    }
}

impl<E: StepExecutor> StepExecutor for FaultInjector<E> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        self.inner.projected_bytes(req)
    }

    fn admission_bytes(&self, req: &TokenRequest) -> usize {
        self.inner.admission_bytes(req)
    }

    fn free_capacity_bytes(&self) -> Option<usize> {
        self.inner.free_capacity_bytes()
    }

    fn note_attempt(&mut self, id: u64, attempt: usize) {
        // keyed draws depend on the attempt number; the pool announces it
        // before every (re-)admission so a retry picked up by a *different*
        // worker still sees fresh draws instead of replaying attempt 1
        self.admits.insert(id, attempt);
        self.inner.note_attempt(id, attempt);
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        // default to attempt 1 for direct (non-pool) users that never
        // call note_attempt; a prior note_attempt wins
        self.admits.entry(req.id).or_insert(1);
        self.rounds.insert(req.id, 0);
        self.inner.admit(req)
    }

    fn step_round(&mut self, rng: &mut Rng, now_ms: f64) -> Result<Vec<StepEvent>> {
        if !self.crashed
            && self
                .plan
                .crashes
                .iter()
                .any(|c| c.worker == self.worker && now_ms >= c.at_ms)
        {
            self.crashed = true;
            return Err(anyhow::Error::new(WorkerCrash {
                worker: self.worker,
                at_ms: now_ms,
            }));
        }
        if self.plan.stall_rate > 0.0 && self.stall_rng.f64() < self.plan.stall_rate {
            self.pending_stall_ms += self.plan.stall_ms;
        }
        let mut events = self.inner.step_round(rng, now_ms)?;
        for ev in &mut events {
            // never fault an already-faulted event or a pure-retirement
            // event (steps == 0 means no compute ran for it this round)
            if ev.fault.is_some() || ev.steps == 0 {
                continue;
            }
            let attempt = self.admits.get(&ev.id).copied().unwrap_or(1);
            let round = {
                let r = self.rounds.entry(ev.id).or_insert(0);
                let current = *r;
                *r += 1;
                current
            };
            if self.plan.step_error_rate > 0.0
                && self.draw(ev.id, attempt, round, 1) < self.plan.step_error_rate
            {
                *ev = StepEvent::faulted(
                    ev.id,
                    StepFault::Error(format!(
                        "injected step fault (request {}, attempt {attempt}, \
                         round {round})",
                        ev.id
                    )),
                );
            } else if self.plan.nan_rate > 0.0
                && self.draw(ev.id, attempt, round, 2) < self.plan.nan_rate
            {
                *ev = StepEvent::faulted(ev.id, StepFault::NanLogits);
            }
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.rounds.remove(&id);
        self.inner.retire(id);
    }

    fn live_bytes(&self) -> usize {
        self.inner.live_bytes()
    }

    fn slot_cap(&self) -> Option<usize> {
        self.inner.slot_cap()
    }

    fn take_stall_ms(&mut self) -> f64 {
        let s = self.pending_stall_ms + self.inner.take_stall_ms();
        self.pending_stall_ms = 0.0;
        s
    }

    fn sparse_prefills(&self) -> usize {
        self.inner.sparse_prefills()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal inner executor: every live request decodes one token per
    /// round for `max_new_tokens` rounds.
    struct Inner {
        live: Vec<(u64, usize)>,
    }

    impl StepExecutor for Inner {
        fn projected_bytes(&self, _req: &TokenRequest) -> usize {
            1
        }

        fn admit(&mut self, req: &TokenRequest) -> Result<()> {
            self.live.push((req.id, req.max_new_tokens.max(1)));
            Ok(())
        }

        fn step_round(&mut self, _rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
            let mut events = Vec::new();
            for (id, left) in &mut self.live {
                *left -= 1;
                events.push(StepEvent {
                    id: *id,
                    tokens: vec![9],
                    steps: 1,
                    proposed: 0,
                    accepted: 0,
                    finished: *left == 0,
                    fault: None,
                });
            }
            Ok(events)
        }

        fn retire(&mut self, id: u64) {
            self.live.retain(|(i, _)| *i != id);
        }

        fn live_bytes(&self) -> usize {
            self.live.len()
        }
    }

    fn req(id: u64, max_new: usize) -> TokenRequest {
        TokenRequest {
            id,
            prompt: vec![1, 2],
            max_new_tokens: max_new,
            arrival_ms: 0.0,
            deadline_ms: None,
            class: Default::default(),
        }
    }

    fn run_rounds(inj: &mut FaultInjector<Inner>, rounds: usize) -> Vec<Vec<StepEvent>> {
        let mut rng = Rng::new(0);
        (0..rounds)
            .map(|r| inj.step_round(&mut rng, r as f64).unwrap())
            .collect()
    }

    #[test]
    fn noop_plan_passes_events_through_unchanged() {
        let mut inj = FaultInjector::new(Inner { live: Vec::new() }, FaultPlan::default(), 0);
        assert!(inj.plan.is_noop());
        inj.admit(&req(1, 3)).unwrap();
        let rounds = run_rounds(&mut inj, 3);
        assert!(rounds
            .iter()
            .flatten()
            .all(|ev| ev.fault.is_none() && ev.tokens == vec![9]));
        assert_eq!(inj.take_stall_ms(), 0.0);
    }

    #[test]
    fn injection_is_deterministic_per_request_attempt_round() {
        let plan = FaultPlan::default().seeded(11).with_step_errors(0.4).with_nan(0.2);
        let trace = |worker: usize| {
            let mut inj = FaultInjector::new(Inner { live: Vec::new() }, plan.clone(), worker);
            for id in 0..6 {
                inj.admit(&req(id, 4)).unwrap();
            }
            run_rounds(&mut inj, 4)
                .into_iter()
                .flatten()
                .map(|ev| (ev.id, ev.fault))
                .collect::<Vec<_>>()
        };
        // same plan → identical faults, regardless of which worker hosts
        // the request (the draw is keyed on request, not worker)
        assert_eq!(trace(0), trace(0));
        assert_eq!(trace(0), trace(3));
        // and a busy plan actually injects something at these rates
        assert!(trace(0).iter().any(|(_, f)| f.is_some()));
    }

    #[test]
    fn retried_attempt_draws_fresh_faults() {
        let plan = FaultPlan::default().seeded(5).with_step_errors(0.9999);
        let mut inj = FaultInjector::new(Inner { live: Vec::new() }, plan, 0);
        inj.admit(&req(7, 2)).unwrap();
        let first = run_rounds(&mut inj, 1).pop().unwrap().pop().unwrap();
        assert!(first.fault.is_some(), "0.9999 rate faults round 0");
        // the scheduler retires, announces the new attempt, and re-admits;
        // the second attempt's round 0 uses a different draw than the
        // first attempt's round 0
        inj.retire(7);
        inj.note_attempt(7, 2);
        inj.admit(&req(7, 2)).unwrap();
        let mut rng = Rng::new(0);
        let second = inj.step_round(&mut rng, 1.0).unwrap().pop().unwrap();
        // both may fault at this rate — the property is that the draws
        // differ, which we can only observe through the attempt label
        if let Some(StepFault::Error(msg)) = &second.fault {
            assert!(msg.contains("attempt 2"), "fresh attempt label: {msg}");
        }
    }

    #[test]
    fn scheduled_crash_fires_at_virtual_time_once() {
        let plan = FaultPlan::default().with_crash(2, 50.0);
        let mut inj = FaultInjector::new(Inner { live: Vec::new() }, plan, 2);
        inj.admit(&req(1, 10)).unwrap();
        let mut rng = Rng::new(0);
        assert!(inj.step_round(&mut rng, 49.9).is_ok(), "before the crash point");
        let err = inj.step_round(&mut rng, 50.0).unwrap_err();
        let crash = err.downcast_ref::<WorkerCrash>().expect("typed crash error");
        assert_eq!(crash.worker, 2);
        // other workers never see this crash point
        let plan2 = FaultPlan::default().with_crash(2, 50.0);
        let mut other = FaultInjector::new(Inner { live: Vec::new() }, plan2, 0);
        other.admit(&req(1, 2)).unwrap();
        assert!(other.step_round(&mut rng, 99.0).is_ok());
    }

    #[test]
    fn stalls_accumulate_and_drain() {
        let plan = FaultPlan::default().with_stalls(1.0, 7.5);
        let mut inj = FaultInjector::new(Inner { live: Vec::new() }, plan, 0);
        inj.admit(&req(1, 3)).unwrap();
        let mut rng = Rng::new(0);
        inj.step_round(&mut rng, 0.0).unwrap();
        assert_eq!(inj.take_stall_ms(), 7.5, "rate 1.0 stalls every round");
        assert_eq!(inj.take_stall_ms(), 0.0, "drained once per round");
    }

    #[test]
    fn validate_rejects_malformed_plans() {
        assert!(FaultPlan::default().validate(1).is_ok());
        assert!(FaultPlan::default().with_step_errors(1.5).validate(1).is_err());
        assert!(FaultPlan::default().with_nan(-0.1).validate(1).is_err());
        assert!(FaultPlan::default().with_stalls(0.5, -1.0).validate(1).is_err());
        assert!(FaultPlan::default().with_crash(2, 10.0).validate(2).is_err());
        assert!(FaultPlan::default().with_crash(1, 10.0).validate(2).is_ok());
        assert!(FaultPlan::default().with_crash(0, -5.0).validate(1).is_err());
    }
}
