//! Batch assembly: collect queued requests into fixed-size batches under a
//! wait-deadline — the standard serving trade-off (batch efficiency vs
//! queueing latency).

use crate::data::TokenRequest;
use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct BatcherCfg {
    pub max_batch: usize,
    /// assemble a partial batch once the oldest request has waited this long
    pub max_wait_ms: f64,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 8, max_wait_ms: 4.0 }
    }
}

#[derive(Clone, Debug)]
pub struct Batch {
    pub requests: Vec<TokenRequest>,
    /// virtual time at which the batch was closed
    pub formed_at_ms: f64,
}

pub struct Batcher {
    pub cfg: BatcherCfg,
    queue: VecDeque<TokenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Self {
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: TokenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Try to form a batch at virtual time `now_ms`. A batch forms when
    /// either max_batch requests are queued or the oldest has exceeded the
    /// wait deadline.
    pub fn try_form(&mut self, now_ms: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now_ms - self.queue.front().unwrap().arrival_ms;
        if self.queue.len() >= self.cfg.max_batch || oldest_wait >= self.cfg.max_wait_ms {
            let n = self.queue.len().min(self.cfg.max_batch);
            let requests: Vec<TokenRequest> = self.queue.drain(..n).collect();
            return Some(Batch { requests, formed_at_ms: now_ms });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival_ms: f64) -> TokenRequest {
        TokenRequest { id, prompt: vec![1, 2, 3], max_new_tokens: 8, arrival_ms }
    }

    #[test]
    fn forms_full_batch_immediately() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_ms: 100.0 });
        b.push(req(0, 0.0));
        b.push(req(1, 0.1));
        let batch = b.try_form(0.2).expect("full batch");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn waits_for_deadline_on_partial() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 8, max_wait_ms: 5.0 });
        b.push(req(0, 0.0));
        assert!(b.try_form(2.0).is_none(), "should wait");
        let batch = b.try_form(6.0).expect("deadline reached");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn drains_in_arrival_order() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_ms: 0.0 });
        for i in 0..5 {
            b.push(req(i, i as f64));
        }
        let b1 = b.try_form(10.0).unwrap();
        assert_eq!(b1.requests[0].id, 0);
        assert_eq!(b1.requests[1].id, 1);
        let b2 = b.try_form(10.0).unwrap();
        assert_eq!(b2.requests[0].id, 2);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn empty_queue_yields_nothing() {
        let mut b = Batcher::new(BatcherCfg::default());
        assert!(b.try_form(1e9).is_none());
    }
}
