//! Serving engine — the deployment layer the paper targets (vLLM/SGLang
//! analogue). One sharded continuous-batching scheduler (request state
//! machine + per-worker KV-memory admission control between decode
//! rounds, work-stealing from a shared FIFO queue) drives every serve
//! path; sequential, static batching, and single-worker serving are
//! degenerate configurations. TTFT / latency / throughput metrics share
//! one virtual-clock time model across worker counts.
//!
//! The pool is fault-tolerant: per-request step faults are contained
//! (bounded retry with virtual-time backoff), worker crashes re-admit
//! the lost live set to survivors, deadlines cancel overdue requests,
//! and every submitted request ends in exactly one terminal
//! [`RequestOutcome`]. `server/faults.rs` provides the deterministic
//! [`FaultInjector`] chaos harness behind `ServeCfg::fault`.

pub mod classes;
pub mod engine;
pub mod faults;
pub mod paged_exec;
pub mod scheduler;

pub use classes::{prune_multimodal_prompt, ClassPolicy, ClassSlo, RequestClass};
pub use engine::{
    ClassStats, CompletedRequest, OutcomeCounts, RequestOutcome, ServeReport, ServingEngine,
};
pub use faults::{CrashPoint, FaultInjector, FaultPlan, WorkerCrash};
pub use paged_exec::{PagedGreedyExecutor, PagedModel, PagedSession, PagedSpecExecutor};
pub use scheduler::{
    AdmissionPolicy, GreedyExecutor, PjrtBatchExecutor, ReqState, Scheduler, ServeCfg,
    SpecExecutor, StepEvent, StepExecutor, StepFault, WorkerPool,
};
