//! Serving engine — the deployment layer the paper targets (vLLM/SGLang
//! analogue). One sharded continuous-batching scheduler (request state
//! machine + per-worker KV-memory admission control between decode
//! rounds, work-stealing from a shared FIFO queue) drives every serve
//! path; sequential, static batching, and single-worker serving are
//! degenerate configurations. TTFT / latency / throughput metrics share
//! one virtual-clock time model across worker counts.

pub mod engine;
pub mod scheduler;

pub use engine::{CompletedRequest, ServeReport, ServingEngine};
pub use scheduler::{
    AdmissionPolicy, GreedyExecutor, PjrtBatchExecutor, ReqState, Scheduler, ServeCfg,
    SpecExecutor, StepEvent, StepExecutor, WorkerPool,
};
