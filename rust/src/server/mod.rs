//! Serving engine — the deployment layer the paper targets (vLLM/SGLang
//! analogue): request queue, batch assembly, decode loop over the PJRT
//! executables, TTFT / latency / throughput metrics.

pub mod batcher;
pub mod engine;

pub use batcher::{Batch, Batcher, BatcherCfg};
pub use engine::{ServeReport, ServingEngine};
