//! Serving engine — the deployment layer the paper targets (vLLM/SGLang
//! analogue). One continuous-batching scheduler (request state machine +
//! KV-memory admission control between decode rounds) drives every serve
//! path; sequential and static batching are degenerate configurations.
//! TTFT / latency / throughput metrics share one virtual-clock time model.

pub mod engine;
pub mod scheduler;

pub use engine::{CompletedRequest, ServeReport, ServingEngine};
pub use scheduler::{
    AdmissionPolicy, GreedyExecutor, PjrtBatchExecutor, ReqState, Scheduler, ServeCfg,
    SpecExecutor, StepEvent, StepExecutor,
};
