//! Serving engine — the deployment layer the paper targets (vLLM/SGLang
//! analogue): request queue, batch assembly, KV-cached decode loop (one
//! session per in-flight request; PJRT executables fall back to replay
//! sessions), TTFT / latency / throughput metrics.

pub mod batcher;
pub mod engine;

pub use batcher::{Batch, Batcher, BatcherCfg};
pub use engine::{ServeReport, ServingEngine};
