//! Paged-KV step executors: block-granular serving over a shared
//! [`BlockPool`] (vLLM-style PagedAttention, arxiv 2309.06180).
//!
//! Where [`GreedyExecutor`] / [`SpecExecutor`] back each request with a
//! contiguous [`KvCache`] sized to its projected peak, the executors here
//! draw fixed `block_tokens` pages from one per-worker pool on demand:
//! admission needs only the *prompt's* pages (the scheduler reads
//! [`StepExecutor::free_capacity_bytes`] instead of reserving projected
//! peaks), decode grabs one page at a time, and identical prompt prefixes
//! attach to the same sealed pages copy-on-write, so a shared system
//! prompt is resident once per worker instead of once per request.
//!
//! Block exhaustion mid-round is handled inside `step_round`: the
//! executor preempts the live request with the least progress (its pages
//! free immediately, the scheduler requeues it through the retry FIFO on
//! a [`StepFault::Preempted`] event) and retries the blocked slot; when
//! no victim remains the slot finishes on the pool's overcommit valve
//! rather than deadlocking. Outputs stay bit-identical to the contiguous
//! executors for every worker count: preemption restarts a request from
//! scratch exactly like the existing fault-retry path, and the paged
//! attention kernels read the same rows in the same order.
//!
//! [`GreedyExecutor`]: super::scheduler::GreedyExecutor
//! [`SpecExecutor`]: super::scheduler::SpecExecutor
//! [`KvCache`]: crate::models::KvCache

use crate::data::TokenRequest;
use crate::models::{is_pool_exhausted, BlockPool, PagedKvCache, Sampler, Transformer};
use crate::spec_decode::{spec_verify_step, DecodeSession, LogitsModel, SessionModel};
use crate::util::Rng;
use anyhow::Result;
use std::sync::{Arc, Mutex};

use super::classes::ClassPolicy;
use super::scheduler::{StepEvent, StepExecutor, StepFault};

/// A transformer plus the block pool its paged sessions draw from —
/// the [`SessionModel`] whose sessions are [`PagedSession`]s.
pub struct PagedModel<'a> {
    model: &'a Transformer,
    pool: Arc<Mutex<BlockPool>>,
}

impl<'a> PagedModel<'a> {
    /// Pair `model` with an unbounded pool (`budget_bytes` = 0) or one
    /// capped at `budget_bytes` of pages.
    pub fn new(model: &'a Transformer, block_tokens: usize, budget_bytes: usize) -> Self {
        let pool = if budget_bytes == 0 {
            model.new_block_pool(block_tokens)
        } else {
            model.new_block_pool_bounded(block_tokens, budget_bytes)
        };
        PagedModel { model, pool }
    }

    pub fn pool(&self) -> &Arc<Mutex<BlockPool>> {
        &self.pool
    }

    pub fn transformer(&self) -> &'a Transformer {
        self.model
    }
}

impl LogitsModel for PagedModel<'_> {
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        self.model.seq_logits(tokens)
    }

    fn max_t(&self) -> usize {
        self.model.cfg.max_t
    }

    fn kv_bytes_per_token(&self) -> usize {
        self.model.cfg.kv_bytes_per_token()
    }
}

impl<'a> SessionModel for PagedModel<'a> {
    type Session = PagedSession;

    fn new_session(&self) -> PagedSession {
        PagedSession { cache: self.model.new_paged_cache(&self.pool) }
    }
    // new_session_bounded: the default (ignore the hint) is right here —
    // paged sessions hold exactly the pages they use, never a peak-sized
    // reservation, so there is nothing to bound per session.
}

/// Block-table decode session: the paged twin of `KvSession`. The first
/// multi-token extend attaches any sealed pages matching the prompt's
/// prefix (copy-on-write sharing) and seals its own full pages for later
/// arrivals; rollback returns whole pages to the pool immediately.
pub struct PagedSession {
    cache: PagedKvCache,
}

impl PagedSession {
    /// Let appends grow the pool past its cap (the no-victim-left escape
    /// hatch of the preemption policy).
    pub fn set_overcommit(&mut self, on: bool) {
        self.cache.set_overcommit(on);
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }
}

impl<'a> DecodeSession<PagedModel<'a>> for PagedSession {
    fn extend(&mut self, model: &PagedModel<'a>, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        match tokens.len() {
            0 => Ok(Vec::new()),
            1 => Ok(vec![model.model.decode_step_paged(&mut self.cache, tokens[0])?]),
            _ => {
                let first = self.cache.is_empty();
                if first {
                    self.cache.attach_prefix(tokens);
                }
                let rows = model.model.prefill_paged(&mut self.cache, tokens)?;
                if first {
                    self.cache.seal_prefix(tokens);
                }
                Ok((0..rows.rows()).map(|i| rows.row(i).to_vec()).collect())
            }
        }
    }

    fn len(&self) -> usize {
        self.cache.len()
    }

    fn rollback(&mut self, keep: usize) {
        self.cache.truncate(keep);
    }
    // kv_bytes stays 0: residency is page-granular and pool-owned, so the
    // executors report it via `live_bytes` = pool.allocated_bytes()
    // (shared pages counted once, not once per session).
}

// ─────────────────────────────────────────────────────────────────────
// Victim selection, shared by both paged executors
// ─────────────────────────────────────────────────────────────────────

/// Index of the preemption victim among `(id, priority, generated,
/// preempted)` candidates: lowest class priority first (SLO-aware — a
/// Batch request is evicted before an Interactive one; without a class
/// policy every priority is 0 and the tie-break below decides alone),
/// then lowest progress (least work lost), youngest (highest index) on
/// full ties — skipping the blocked slot itself, already-preempted
/// slots, and any slot with a terminal (finished/faulted) event this
/// round, whose retirement the scheduler has already been promised.
fn pick_victim(
    slots: &[(u64, u8, usize, bool)],
    self_id: u64,
    events: &[StepEvent],
) -> Option<usize> {
    let mut best: Option<(usize, u8, usize)> = None;
    for (i, &(id, priority, generated, preempted)) in slots.iter().enumerate() {
        if id == self_id || preempted {
            continue;
        }
        if events.iter().any(|e| e.id == id && (e.finished || e.fault.is_some())) {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, bp, bg)) => (priority, generated) <= (bp, bg),
        };
        if better {
            best = Some((i, priority, generated));
        }
    }
    best.map(|(i, _, _)| i)
}

// ─────────────────────────────────────────────────────────────────────
// PagedGreedyExecutor
// ─────────────────────────────────────────────────────────────────────

struct PagedGreedySlot {
    id: u64,
    prompt: Vec<u8>,
    sess: PagedSession,
    /// tokens still to generate; 0 at admission finishes empty
    remaining: usize,
    /// tokens committed so far — the preemption progress metric
    generated: usize,
    last: Option<Vec<f32>>,
    /// a Preempted event for this slot is already in flight; it takes no
    /// further rounds and its retirement is imminent
    preempted: bool,
    /// class priority (0 without a class policy) — the leading victim key
    priority: u8,
}

/// Greedy decoding over paged sessions — output bit-identical to
/// [`GreedyExecutor`](super::scheduler::GreedyExecutor) per request, with
/// page-granular admission and exhaustion-driven preemption.
pub struct PagedGreedyExecutor<'a> {
    model: PagedModel<'a>,
    sampler: Sampler,
    slots: Vec<PagedGreedySlot>,
    /// class policy: preemption victims ordered by (priority, progress)
    classes: Option<ClassPolicy>,
}

impl<'a> PagedGreedyExecutor<'a> {
    pub fn new(model: &'a Transformer, block_tokens: usize, budget_bytes: usize) -> Self {
        PagedGreedyExecutor {
            model: PagedModel::new(model, block_tokens, budget_bytes),
            sampler: Sampler::Greedy,
            slots: Vec::new(),
            classes: None,
        }
    }

    /// Enable SLO-aware victim selection (no-op when `None`).
    pub fn with_class_policy(mut self, classes: Option<ClassPolicy>) -> Self {
        self.classes = classes;
        self
    }

    pub fn pool(&self) -> &Arc<Mutex<BlockPool>> {
        self.model.pool()
    }

    /// One slot's decode round, restartable after pool exhaustion: every
    /// state commit happens only after the allocation it depends on
    /// succeeded, so a retry recomputes the identical token. `Err` is
    /// raised *only* for pool exhaustion; model failures come back as
    /// fault events with the same messages as the contiguous executor.
    fn slot_step(
        model: &PagedModel<'a>,
        sampler: &Sampler,
        slot: &mut PagedGreedySlot,
        rng: &mut Rng,
    ) -> Result<StepEvent> {
        if slot.remaining == 0 {
            return Ok(StepEvent {
                id: slot.id,
                tokens: Vec::new(),
                steps: 0,
                proposed: 0,
                accepted: 0,
                finished: true,
                fault: None,
            });
        }
        if slot.last.is_none() {
            match slot.sess.extend(model, &slot.prompt) {
                Ok(mut rows) => slot.last = rows.pop(),
                Err(e) if is_pool_exhausted(&e) => return Err(e),
                Err(e) => {
                    return Ok(StepEvent::faulted(
                        slot.id,
                        StepFault::Error(format!(
                            "request {}: prompt prefill failed: {e:#}",
                            slot.id
                        )),
                    ))
                }
            }
        }
        let next = match slot.last.as_ref() {
            Some(row) if row.iter().all(|x| x.is_finite()) => sampler.sample(row, rng),
            Some(_) => return Ok(StepEvent::faulted(slot.id, StepFault::NanLogits)),
            None => {
                return Ok(StepEvent::faulted(
                    slot.id,
                    StepFault::Error(format!(
                        "request {}: prefill produced no logits row",
                        slot.id
                    )),
                ))
            }
        };
        // like the contiguous executor, the final token is never fed back
        let finished = slot.remaining == 1;
        if finished {
            slot.last = None;
        } else {
            match slot.sess.extend(model, &[next]) {
                Ok(mut rows) => match rows.pop() {
                    Some(row) => slot.last = Some(row),
                    None => {
                        return Ok(StepEvent::faulted(
                            slot.id,
                            StepFault::Error(format!(
                                "request {}: decode step produced no logits row",
                                slot.id
                            )),
                        ))
                    }
                },
                Err(e) if is_pool_exhausted(&e) => return Err(e),
                Err(e) => {
                    return Ok(StepEvent::faulted(
                        slot.id,
                        StepFault::Error(format!(
                            "request {}: decode step failed: {e:#}",
                            slot.id
                        )),
                    ))
                }
            }
        }
        slot.remaining -= 1;
        slot.generated += 1;
        Ok(StepEvent {
            id: slot.id,
            tokens: vec![next],
            steps: 1,
            proposed: 0,
            accepted: 0,
            finished,
            fault: None,
        })
    }
}

impl StepExecutor for PagedGreedyExecutor<'_> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        // page-rounded projected peak: reporting + the unbudgeted case
        let peak_t = req
            .prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.model.max_t());
        let pool = self.model.pool.lock().unwrap();
        peak_t.div_ceil(pool.block_tokens()) * pool.block_bytes()
    }

    fn admission_bytes(&self, req: &TokenRequest) -> usize {
        // free-block admission: a request needs only its prompt's pages
        // to start; decode growth is claimed one page at a time
        let pool = self.model.pool.lock().unwrap();
        req.prompt.len().div_ceil(pool.block_tokens()) * pool.block_bytes()
    }

    fn free_capacity_bytes(&self) -> Option<usize> {
        let pool = self.model.pool.lock().unwrap();
        // pages that admitted-but-not-yet-prefilled slots are still owed
        let pending: usize = self
            .slots
            .iter()
            .filter(|s| !s.preempted && s.last.is_none() && s.remaining > 0)
            .map(|s| s.prompt.len().div_ceil(pool.block_tokens()))
            .sum();
        Some(
            pool.free_blocks()
                .saturating_sub(pending)
                .saturating_mul(pool.block_bytes()),
        )
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.model.max_t().saturating_sub(req.prompt.len()))
        };
        self.slots.push(PagedGreedySlot {
            id: req.id,
            prompt: req.prompt.clone(),
            sess: self.model.new_session(),
            remaining: budget,
            generated: 0,
            last: None,
            preempted: false,
            priority: self
                .classes
                .as_ref()
                .map_or(0, |p| p.priority_of(&req.class)),
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
        let mut events: Vec<StepEvent> = Vec::with_capacity(self.slots.len());
        for si in 0..self.slots.len() {
            if self.slots[si].preempted {
                continue;
            }
            loop {
                match Self::slot_step(&self.model, &self.sampler, &mut self.slots[si], rng) {
                    Ok(ev) => {
                        events.push(ev);
                        break;
                    }
                    // pool exhausted: preempt the lowest-progress live
                    // slot (pages freed now, scheduler requeues it) and
                    // retry; no victim left → overcommit rather than
                    // deadlock
                    Err(_) => {
                        let meta: Vec<(u64, u8, usize, bool)> = self
                            .slots
                            .iter()
                            .map(|s| (s.id, s.priority, s.generated, s.preempted))
                            .collect();
                        match pick_victim(&meta, self.slots[si].id, &events) {
                            Some(vi) => {
                                let fresh = self.model.new_session();
                                let v = &mut self.slots[vi];
                                v.preempted = true;
                                v.sess = fresh; // old cache drops → pages free
                                v.last = None;
                                events.push(StepEvent::faulted(v.id, StepFault::Preempted));
                            }
                            None => self.slots[si].sess.set_overcommit(true),
                        }
                    }
                }
            }
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        // honest page-granular residency: shared pages count once
        self.model.pool.lock().unwrap().allocated_bytes()
    }
}

// ─────────────────────────────────────────────────────────────────────
// PagedSpecExecutor
// ─────────────────────────────────────────────────────────────────────

struct PagedSpecSlot {
    id: u64,
    seq: Vec<u8>,
    budget: usize,
    generated: usize,
    dsess: PagedSession,
    tsess: PagedSession,
    /// at least one verify step has committed (its prompt pages are held)
    started: bool,
    preempted: bool,
    /// class priority (0 without a class policy) — the leading victim key
    priority: u8,
}

/// Speculative draft+target decoding over paged sessions — output
/// bit-identical to [`SpecExecutor`](super::scheduler::SpecExecutor) per
/// request. Draft and target keep *separate* pools (their K/V rows have
/// different shapes and values, so cross-model sharing is meaningless);
/// the worker's byte budget splits between them in proportion to each
/// model's per-token KV cost.
pub struct PagedSpecExecutor<'a> {
    draft: PagedModel<'a>,
    target: PagedModel<'a>,
    gamma: usize,
    sampler: Sampler,
    slots: Vec<PagedSpecSlot>,
    /// class policy: preemption victims ordered by (priority, progress)
    classes: Option<ClassPolicy>,
}

impl<'a> PagedSpecExecutor<'a> {
    pub fn new(
        draft: &'a Transformer,
        target: &'a Transformer,
        gamma: usize,
        block_tokens: usize,
        budget_bytes: usize,
    ) -> Self {
        let (d_share, t_share) = if budget_bytes == 0 {
            (0, 0)
        } else {
            let d_bpt = draft.cfg.kv_bytes_per_token().max(1);
            let t_bpt = target.cfg.kv_bytes_per_token().max(1);
            let d_share = budget_bytes * d_bpt / (d_bpt + t_bpt);
            (d_share.max(1), budget_bytes.saturating_sub(d_share).max(1))
        };
        PagedSpecExecutor {
            draft: PagedModel::new(draft, block_tokens, d_share),
            target: PagedModel::new(target, block_tokens, t_share),
            gamma,
            sampler: Sampler::Greedy,
            slots: Vec::new(),
            classes: None,
        }
    }

    /// Enable SLO-aware victim selection (no-op when `None`).
    pub fn with_class_policy(mut self, classes: Option<ClassPolicy>) -> Self {
        self.classes = classes;
        self
    }

    fn limit(&self) -> usize {
        self.target.max_t().min(self.draft.max_t())
    }

    fn combined_block_bytes(&self) -> usize {
        self.draft.pool.lock().unwrap().block_bytes() + self.target.pool.lock().unwrap().block_bytes()
    }

    /// One verify step for one slot, restartable after pool exhaustion:
    /// `spec_verify_step` mutates `seq` only after its last fallible
    /// extend, and on exhaustion both sessions roll back to the committed
    /// prefix (pages freed), so a retry recomputes identical tokens.
    #[allow(clippy::too_many_arguments)]
    fn slot_step(
        draft: &PagedModel<'a>,
        target: &PagedModel<'a>,
        gamma: usize,
        limit: usize,
        sampler: &Sampler,
        slot: &mut PagedSpecSlot,
        rng: &mut Rng,
    ) -> Result<StepEvent> {
        let room = limit
            .saturating_sub(slot.seq.len())
            .min(gamma)
            .min(slot.budget.saturating_sub(slot.generated));
        if room == 0 {
            return Ok(StepEvent {
                id: slot.id,
                tokens: Vec::new(),
                steps: 0,
                proposed: 0,
                accepted: 0,
                finished: true,
                fault: None,
            });
        }
        let step = spec_verify_step(
            draft,
            target,
            &mut slot.dsess,
            &mut slot.tsess,
            &mut slot.seq,
            room,
            slot.budget - slot.generated,
            limit,
            sampler,
            rng,
        );
        let (tokens, proposed, accepted) = match step {
            Ok(v) => v,
            Err(e) if is_pool_exhausted(&e) => {
                // partially-extended sessions would desync the next
                // catch-up: rewind both to the committed prefix (whole
                // pages return to the pools) before the retry
                let keep = slot.seq.len().saturating_sub(1);
                slot.dsess.rollback(keep);
                slot.tsess.rollback(keep);
                return Err(e);
            }
            Err(e) => {
                return Ok(StepEvent::faulted(
                    slot.id,
                    StepFault::Error(format!(
                        "request {}: speculative verify step failed: {e:#}",
                        slot.id
                    )),
                ))
            }
        };
        slot.generated += tokens.len();
        slot.started = true;
        let finished = slot.generated >= slot.budget || slot.seq.len() >= limit;
        Ok(StepEvent {
            id: slot.id,
            tokens,
            steps: 1,
            proposed,
            accepted,
            finished,
            fault: None,
        })
    }
}

impl StepExecutor for PagedSpecExecutor<'_> {
    fn projected_bytes(&self, req: &TokenRequest) -> usize {
        let peak_t = req
            .prompt
            .len()
            .saturating_add(req.max_new_tokens)
            .min(self.limit());
        let bt = self.target.pool.lock().unwrap().block_tokens();
        peak_t.div_ceil(bt) * self.combined_block_bytes()
    }

    fn admission_bytes(&self, req: &TokenRequest) -> usize {
        let bt = self.target.pool.lock().unwrap().block_tokens();
        req.prompt.len().div_ceil(bt) * self.combined_block_bytes()
    }

    fn free_capacity_bytes(&self) -> Option<usize> {
        // a slot needs matching pages in *both* pools, so capacity is the
        // scarcer pool's free pages, priced at the combined page cost
        let bt = self.target.pool.lock().unwrap().block_tokens();
        let pending: usize = self
            .slots
            .iter()
            .filter(|s| !s.preempted && !s.started)
            .map(|s| s.seq.len().div_ceil(bt))
            .sum();
        let free = self
            .draft
            .pool
            .lock().unwrap()
            .free_blocks()
            .min(self.target.pool.lock().unwrap().free_blocks());
        Some(
            free.saturating_sub(pending)
                .saturating_mul(self.combined_block_bytes()),
        )
    }

    fn admit(&mut self, req: &TokenRequest) -> Result<()> {
        let budget = if req.prompt.is_empty() {
            0
        } else {
            req.max_new_tokens
                .min(self.limit().saturating_sub(req.prompt.len()))
        };
        self.slots.push(PagedSpecSlot {
            id: req.id,
            seq: req.prompt.clone(),
            budget,
            generated: 0,
            dsess: self.draft.new_session(),
            tsess: self.target.new_session(),
            started: false,
            preempted: false,
            priority: self
                .classes
                .as_ref()
                .map_or(0, |p| p.priority_of(&req.class)),
        });
        Ok(())
    }

    fn step_round(&mut self, rng: &mut Rng, _now_ms: f64) -> Result<Vec<StepEvent>> {
        let gamma = self.gamma;
        let limit = self.limit();
        let mut events: Vec<StepEvent> = Vec::with_capacity(self.slots.len());
        for si in 0..self.slots.len() {
            if self.slots[si].preempted {
                continue;
            }
            loop {
                match Self::slot_step(
                    &self.draft,
                    &self.target,
                    gamma,
                    limit,
                    &self.sampler,
                    &mut self.slots[si],
                    rng,
                ) {
                    Ok(ev) => {
                        events.push(ev);
                        break;
                    }
                    Err(_) => {
                        let meta: Vec<(u64, u8, usize, bool)> = self
                            .slots
                            .iter()
                            .map(|s| (s.id, s.priority, s.generated, s.preempted))
                            .collect();
                        match pick_victim(&meta, self.slots[si].id, &events) {
                            Some(vi) => {
                                let fresh_d = self.draft.new_session();
                                let fresh_t = self.target.new_session();
                                let v = &mut self.slots[vi];
                                v.preempted = true;
                                v.dsess = fresh_d;
                                v.tsess = fresh_t;
                                events.push(StepEvent::faulted(v.id, StepFault::Preempted));
                            }
                            None => {
                                let s = &mut self.slots[si];
                                s.dsess.set_overcommit(true);
                                s.tsess.set_overcommit(true);
                            }
                        }
                    }
                }
            }
        }
        Ok(events)
    }

    fn retire(&mut self, id: u64) {
        self.slots.retain(|s| s.id != id);
    }

    fn live_bytes(&self) -> usize {
        self.draft.pool.lock().unwrap().allocated_bytes()
            + self.target.pool.lock().unwrap().allocated_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::engine::RequestOutcome;
    use crate::server::scheduler::{GreedyExecutor, Scheduler, ServeCfg, SpecExecutor};
    use crate::util::fixtures::{fixture_draft, fixture_target};
    use crate::util::testing::assert_outputs_match;

    fn reqs(n: usize, max_new: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![10 + i as u8, 20, 30, 40, 50],
                max_new_tokens: max_new,
                arrival_ms: i as f64,
                deadline_ms: None,
                class: Default::default(),
            })
            .collect()
    }

    #[test]
    fn paged_greedy_matches_contiguous_unbudgeted() {
        let model = fixture_target(3);
        let flat = Scheduler::run(
            reqs(5, 8),
            GreedyExecutor::new(&model),
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        let paged = Scheduler::run(
            reqs(5, 8),
            PagedGreedyExecutor::new(&model, 4, 0),
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        assert_outputs_match(&flat, &paged, "paged greedy vs contiguous");
    }

    #[test]
    fn paged_spec_matches_contiguous_unbudgeted() {
        let draft = fixture_draft(3);
        let target = fixture_target(3);
        let flat = Scheduler::run(
            reqs(4, 10),
            SpecExecutor::new(&draft, &target, 3),
            &ServeCfg::continuous(2),
            0,
        )
        .unwrap();
        let paged = Scheduler::run(
            reqs(4, 10),
            PagedSpecExecutor::new(&draft, &target, 3, 4, 0),
            &ServeCfg::continuous(2),
            0,
        )
        .unwrap();
        assert_outputs_match(&flat, &paged, "paged spec vs contiguous");
    }

    #[test]
    fn preemption_under_tight_pool_still_completes_every_request() {
        let model = fixture_target(3);
        // room for ~3 pages of 4 tokens: several 5-token prompts decoding
        // 12 tokens each must collide and preempt
        let block_bytes = model.cfg.n_layers * 2 * 4 * model.cfg.d_model * 4;
        let budget = 3 * block_bytes;
        let cfg = ServeCfg::continuous(4).with_budget(budget).with_retries(8);
        let report = Scheduler::run(
            reqs(4, 12),
            PagedGreedyExecutor::new(&model, 4, budget),
            &cfg,
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 4);
        for c in &report.completed {
            assert_eq!(
                c.outcome,
                RequestOutcome::Completed,
                "request {} under preemption: {:?}",
                c.id,
                c.outcome
            );
        }
        // ...and the outputs still match an untight contiguous run
        let flat = Scheduler::run(
            reqs(4, 12),
            GreedyExecutor::new(&model),
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        assert_outputs_match(&flat, &report, "preempted paged vs contiguous");
    }

    #[test]
    fn shared_prompts_share_pages_in_one_round() {
        let model = fixture_target(3);
        let mut requests = reqs(4, 2);
        for r in &mut requests {
            r.prompt = vec![9; 8]; // identical 8-token prompt, 2 pages at bt=4
            r.arrival_ms = 0.0;
        }
        let mut exec = PagedGreedyExecutor::new(&model, 4, 0);
        for r in &requests {
            exec.admit(r).unwrap();
        }
        let mut rng = Rng::new(0);
        exec.step_round(&mut rng, 0.0).unwrap();
        let pool = exec.pool().lock().unwrap();
        // 4 sessions × (2 prompt pages + 1 decode page), but the 2 prompt
        // pages are shared: 2 + 4 × 1 pages resident, not 12
        assert_eq!(pool.in_use_blocks(), 6, "prompt pages must be shared");
    }

    #[test]
    fn free_capacity_accounts_admitted_but_unprefilled_prompts() {
        let model = fixture_target(3);
        let block_bytes = model.cfg.n_layers * 2 * 4 * model.cfg.d_model * 4;
        let mut exec = PagedGreedyExecutor::new(&model, 4, 10 * block_bytes);
        assert_eq!(exec.free_capacity_bytes(), Some(10 * block_bytes));
        // a 5-token prompt owes 2 pages before its first round runs
        exec.admit(&reqs(1, 4)[0]).unwrap();
        assert_eq!(exec.free_capacity_bytes(), Some(8 * block_bytes));
        assert_eq!(exec.admission_bytes(&reqs(1, 4)[0]), 2 * block_bytes);
    }
}
