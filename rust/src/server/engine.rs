//! Serving engine: drains a request stream through the batcher and decodes
//! with either vanilla batched decoding (the b8 PJRT executable) or
//! per-request speculative decoding (draft + target b1 executables) —
//! reporting TTFT / latency / throughput like the paper's deployment
//! benchmarks.
//!
//! Time model: request *arrivals* are virtual (from the workload trace);
//! compute occupies real wall-clock measured around the PJRT calls. The
//! engine advances a virtual clock max(arrival, ready) + measured compute,
//! which is the standard discrete-event treatment for single-worker
//! serving simulators.

use crate::data::TokenRequest;
use crate::spec_decode::{DecodeSession, SessionModel, SpecDecoder, VanillaDecoder};
use crate::tensor::ops::argmax;
use crate::util::{Rng, Summary};
use anyhow::Result;

use super::batcher::{Batcher, BatcherCfg};

#[derive(Clone, Debug)]
pub struct CompletedRequest {
    pub id: u64,
    pub output: Vec<u8>,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub generated: usize,
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: Vec<CompletedRequest>,
    pub wall_s: f64,
    pub total_tokens: usize,
    pub mean_al: f64,
}

impl ServeReport {
    pub fn tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_s
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.completed.iter().map(|c| c.ttft_ms).collect::<Vec<_>>())
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.completed.iter().map(|c| c.total_ms).collect::<Vec<_>>())
    }
}

pub struct ServingEngine;

impl ServingEngine {
    /// Serve a trace of requests with per-request decoding (b1 models).
    /// Each generation call holds its own KV session, so decoding costs
    /// one cached step per token. `draft` = None -> vanilla decoding.
    pub fn serve<D: SessionModel, T: SessionModel>(
        requests: Vec<TokenRequest>,
        target: &T,
        draft: Option<(&D, usize)>,
        batcher_cfg: BatcherCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        let mut rng = Rng::new(seed);
        let mut batcher = Batcher::new(batcher_cfg);
        let mut completed = Vec::new();
        let t0 = std::time::Instant::now();
        let mut clock_ms = 0.0f64;
        let mut al_num = 0.0f64;
        let mut al_den = 0.0f64;
        let mut total_tokens = 0usize;

        let mut pending = requests.into_iter().peekable();
        loop {
            // admit arrivals up to the current clock (or the next arrival
            // if the queue is empty — the worker sleeps until then)
            while let Some(r) = pending.peek() {
                if r.arrival_ms <= clock_ms || batcher.pending() == 0 {
                    clock_ms = clock_ms.max(pending.peek().unwrap().arrival_ms);
                    batcher.push(pending.next().unwrap());
                } else {
                    break;
                }
            }
            let Some(batch) = batcher.try_form(clock_ms) else {
                if pending.peek().is_none() && batcher.pending() == 0 {
                    break;
                }
                // force the deadline forward
                clock_ms += 1.0;
                continue;
            };

            for req in batch.requests {
                let gen_t0 = std::time::Instant::now();
                let (out, stats) = match draft {
                    Some((d, gamma)) => {
                        SpecDecoder::new(d, target, gamma).generate(
                            &req.prompt,
                            req.max_new_tokens,
                            &mut rng,
                        )?
                    }
                    None => VanillaDecoder::new(target).generate(
                        &req.prompt,
                        req.max_new_tokens,
                        &mut rng,
                    )?,
                };
                let gen_ms = gen_t0.elapsed().as_secs_f64() * 1e3;
                // TTFT: queueing delay + one verify/decode step
                let first_step_ms = gen_ms / stats.steps.max(1) as f64;
                let queue_ms = (clock_ms - req.arrival_ms).max(0.0);
                clock_ms += gen_ms;
                al_num += stats.generated as f64;
                al_den += stats.steps as f64;
                total_tokens += stats.generated;
                completed.push(CompletedRequest {
                    id: req.id,
                    output: out[req.prompt.len()..].to_vec(),
                    ttft_ms: queue_ms + first_step_ms,
                    total_ms: queue_ms + gen_ms,
                    generated: stats.generated,
                });
            }
        }
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            total_tokens,
            mean_al: if al_den == 0.0 { 0.0 } else { al_num / al_den },
        })
    }

    /// Static batched greedy decoding on any session model: every request
    /// in the chunk holds its own KV-cache session and the whole batch
    /// advances one decode step per round — the pure-Rust analogue of
    /// [`ServingEngine::serve_batched_pjrt`], one cached step per token
    /// instead of one full forward per token.
    pub fn serve_batched<T>(
        requests: Vec<TokenRequest>,
        target: &T,
        max_batch: usize,
    ) -> Result<ServeReport>
    where
        T: SessionModel,
        T::Session: DecodeSession<T>,
    {
        let b = max_batch.max(1);
        let t0 = std::time::Instant::now();
        let mut completed = Vec::new();
        let mut total_tokens = 0usize;
        for chunk in requests.chunks(b) {
            let chunk_t0 = std::time::Instant::now();
            let mut seqs: Vec<Vec<u8>> = chunk.iter().map(|r| r.prompt.clone()).collect();
            let mut first_token_ms = vec![0.0f64; chunk.len()];
            // one session per in-flight request; prefill covers the prompt.
            // `last[ri]` holds the next-token logits while the request is
            // live, None once it has finished (or can never start).
            let mut sessions = Vec::with_capacity(chunk.len());
            let mut last: Vec<Option<Vec<f32>>> = Vec::with_capacity(chunk.len());
            for req in chunk {
                let mut sess = target.new_session();
                let row = if req.prompt.is_empty()
                    || req.prompt.len() >= target.max_t()
                    || req.max_new_tokens == 0
                {
                    None
                } else {
                    sess.extend(target, &req.prompt)?.pop()
                };
                sessions.push(sess);
                last.push(row);
            }
            let max_new = chunk.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
            for step in 0..max_new {
                for ri in 0..chunk.len() {
                    let next = match &last[ri] {
                        Some(row) => argmax(row) as u8,
                        None => continue,
                    };
                    seqs[ri].push(next);
                    total_tokens += 1;
                    if step == 0 {
                        first_token_ms[ri] = chunk_t0.elapsed().as_secs_f64() * 1e3;
                    }
                    let live = seqs[ri].len() - chunk[ri].prompt.len() < chunk[ri].max_new_tokens
                        && seqs[ri].len() < target.max_t();
                    last[ri] = if live {
                        sessions[ri].extend(target, &[next])?.pop()
                    } else {
                        None
                    };
                }
            }
            let chunk_ms = chunk_t0.elapsed().as_secs_f64() * 1e3;
            for (ri, req) in chunk.iter().enumerate() {
                completed.push(CompletedRequest {
                    id: req.id,
                    output: seqs[ri][req.prompt.len()..].to_vec(),
                    ttft_ms: first_token_ms[ri],
                    total_ms: chunk_ms,
                    generated: seqs[ri].len() - req.prompt.len(),
                });
            }
        }
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            total_tokens,
            mean_al: 1.0,
        })
    }

    /// Batched vanilla decoding on a b8 executable: all requests in the
    /// batch advance one token per joint forward (static batching).
    pub fn serve_batched_pjrt(
        requests: Vec<TokenRequest>,
        exe: &crate::runtime::ModelExecutable,
    ) -> Result<ServeReport> {
        let b = exe.batch;
        let t0 = std::time::Instant::now();
        let mut completed = Vec::new();
        let mut total_tokens = 0usize;
        for chunk in requests.chunks(b) {
            let mut seqs: Vec<Vec<u8>> = chunk.iter().map(|r| r.prompt.clone()).collect();
            let max_new = chunk.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
            let chunk_t0 = std::time::Instant::now();
            let mut first_token_ms = vec![0.0f64; chunk.len()];
            for step in 0..max_new {
                if seqs.iter().all(|s| s.len() >= exe.seq_t) {
                    break;
                }
                // pack the batch (pad short rows, reuse last row for gaps)
                let mut tokens = vec![0i32; b * exe.seq_t];
                for (ri, seq) in seqs.iter().enumerate() {
                    for (i, &t) in seq.iter().enumerate().take(exe.seq_t) {
                        tokens[ri * exe.seq_t + i] = t as i32;
                    }
                }
                let logits = exe.run(&tokens)?;
                for (ri, seq) in seqs.iter_mut().enumerate() {
                    if ri >= chunk.len()
                        || seq.len() >= exe.seq_t
                        || seq.len() - chunk[ri].prompt.len() >= chunk[ri].max_new_tokens
                    {
                        continue;
                    }
                    let pos = seq.len() - 1;
                    let off = ri * exe.seq_t * exe.vocab + pos * exe.vocab;
                    let next = argmax(&logits[off..off + exe.vocab]) as u8;
                    seq.push(next);
                    total_tokens += 1;
                    if step == 0 {
                        first_token_ms[ri] = chunk_t0.elapsed().as_secs_f64() * 1e3;
                    }
                }
            }
            let chunk_ms = chunk_t0.elapsed().as_secs_f64() * 1e3;
            for (ri, req) in chunk.iter().enumerate() {
                completed.push(CompletedRequest {
                    id: req.id,
                    output: seqs[ri][req.prompt.len()..].to_vec(),
                    ttft_ms: first_token_ms[ri],
                    total_ms: chunk_ms,
                    generated: seqs[ri].len() - req.prompt.len(),
                });
            }
        }
        Ok(ServeReport {
            completed,
            wall_s: t0.elapsed().as_secs_f64(),
            total_tokens,
            mean_al: 1.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec_decode::engine::tests_support::ToyModel;

    fn reqs(n: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: 10,
                arrival_ms: i as f64 * 2.0,
            })
            .collect()
    }

    #[test]
    fn vanilla_serving_completes_all() {
        let target = ToyModel::new(3);
        let report = ServingEngine::serve::<ToyModel, _>(
            reqs(6),
            &target,
            None,
            BatcherCfg::default(),
            0,
        )
        .unwrap();
        assert_eq!(report.completed.len(), 6);
        assert!(report.completed.iter().all(|c| c.generated == 10));
        assert!(report.tps() > 0.0);
        assert_eq!(report.mean_al, 1.0);
    }

    #[test]
    fn speculative_serving_same_outputs_higher_al() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(3);
        let v = ServingEngine::serve::<ToyModel, _>(
            reqs(4),
            &target,
            None,
            BatcherCfg::default(),
            0,
        )
        .unwrap();
        let s = ServingEngine::serve(
            reqs(4),
            &target,
            Some((&draft, 3)),
            BatcherCfg::default(),
            0,
        )
        .unwrap();
        for (a, b) in v.completed.iter().zip(&s.completed) {
            assert_eq!(a.output, b.output, "spec decode must preserve outputs");
        }
        assert!(s.mean_al > 2.0, "AL {}", s.mean_al);
    }

    #[test]
    fn batched_serving_matches_sequential_outputs() {
        let target = ToyModel::new(3);
        let sequential = ServingEngine::serve::<ToyModel, _>(
            reqs(7),
            &target,
            None,
            BatcherCfg::default(),
            0,
        )
        .unwrap();
        let batched = ServingEngine::serve_batched(reqs(7), &target, 4).unwrap();
        assert_eq!(batched.completed.len(), 7);
        assert_eq!(batched.total_tokens, sequential.total_tokens);
        let mut by_id: Vec<_> = batched.completed.clone();
        by_id.sort_by_key(|c| c.id);
        for (a, b) in sequential.completed.iter().zip(&by_id) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "batched decode changed request {}", a.id);
        }
    }

    #[test]
    fn ttft_includes_queueing() {
        let target = ToyModel::new(1);
        let report = ServingEngine::serve::<ToyModel, _>(
            reqs(8),
            &target,
            None,
            BatcherCfg { max_batch: 8, max_wait_ms: 50.0 },
            0,
        )
        .unwrap();
        let ttft = report.ttft_summary();
        assert!(ttft.max >= ttft.min);
    }
}
