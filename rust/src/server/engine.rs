//! Serving entry points — thin policy wrappers over the one
//! [`WorkerPool`] loop (see `server/scheduler.rs`). Sequential serving,
//! static batching, the PJRT batched path, and single-worker continuous
//! batching are degenerate configurations of the same sharded
//! work-stealing scheduler, so TTFT and total latency mean the same
//! thing on every path and every worker count: per-request, on the
//! unified virtual timeline, measured from arrival.

use crate::data::TokenRequest;
use crate::spec_decode::SessionModel;
use crate::util::Summary;
use anyhow::Result;

use super::classes::{ClassPolicy, RequestClass};
use super::paged_exec::{PagedGreedyExecutor, PagedSpecExecutor};
use super::scheduler::{
    GreedyExecutor, PjrtBatchExecutor, Scheduler, ServeCfg, SpecExecutor, WorkerPool,
};

/// Terminal outcome of one submitted request. Every request the pool
/// accepts ends in exactly one of these (the exactly-once accounting
/// property, enforced by the scheduler and chaos-tested in
/// `tests/test_fault_props.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestOutcome {
    /// decoded to completion; `output` is the full generation
    Completed,
    /// a step fault (or worker crash) consumed every retry attempt
    Failed {
        /// the final attempt's error, carrying request id + worker index
        error: String,
    },
    /// cancelled past its deadline on the virtual clock; `output` keeps
    /// whatever was decoded before cancellation
    DeadlineExceeded,
    /// never ran to a verdict: every worker was dead when its turn came
    Shed,
}

impl RequestOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::Failed { .. } => "failed",
            RequestOutcome::DeadlineExceeded => "deadline_exceeded",
            RequestOutcome::Shed => "shed",
        }
    }
}

/// Per-outcome tallies over one report (see [`ServeReport::outcome_counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    pub completed: usize,
    pub failed: usize,
    pub deadline_exceeded: usize,
    pub shed: usize,
}

#[derive(Clone, Debug)]
pub struct CompletedRequest {
    pub id: u64,
    pub output: Vec<u8>,
    /// first-token time measured from *arrival* (queueing included)
    pub ttft_ms: f64,
    /// completion time measured from *arrival*
    pub total_ms: f64,
    pub generated: usize,
    /// how this request ended (always `Completed` on fault-free runs)
    pub outcome: RequestOutcome,
    /// execution attempts consumed (1 on fault-free runs; 0 for requests
    /// cancelled or shed before their first admission)
    pub attempts: usize,
    /// workload class the request carried (drives the per-class rows in
    /// [`ServeReport::class_breakdown`])
    pub class: RequestClass,
}

impl CompletedRequest {
    pub fn is_completed(&self) -> bool {
        self.outcome == RequestOutcome::Completed
    }
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    /// every submitted request with its terminal outcome, ordered by id
    pub completed: Vec<CompletedRequest>,
    pub wall_s: f64,
    /// end of the last decode round on the virtual timeline (max worker
    /// clock): the schedule's makespan. With N workers the pool executes
    /// rounds one at a time but models the workers as parallel replicas,
    /// so this — not `wall_s` — is the time the sharded schedule takes.
    pub makespan_ms: f64,
    pub total_tokens: usize,
    /// tokens committed per target step, from actual step counts (1.0 for
    /// greedy decoding; > 1 when speculation accepts proposals)
    pub mean_al: f64,
    /// speculative tokens proposed across all requests (0 when greedy)
    pub proposed: usize,
    /// speculative tokens accepted across all requests
    pub accepted: usize,
    /// max resident KV bytes observed across decode rounds, summed over
    /// all workers
    pub peak_kv_bytes: usize,
    /// per-worker max resident KV bytes (length = worker count) — each
    /// entry stays within that worker's `ServeCfg::per_worker_budgets`
    /// share (property-tested in `tests/test_sharded_props.rs`)
    pub worker_peak_kv_bytes: Vec<usize>,
    /// workers lost during the run as `(worker index, crash message)`;
    /// empty on fault-free runs
    pub crashed_workers: Vec<(usize, String)>,
    /// max requests decoding concurrently (summed over workers) observed
    /// across admissions and decode rounds
    pub peak_in_flight: usize,
    /// mean live requests per decode round (summed over workers) — the
    /// batch-occupancy number paged admission is graded on in
    /// `bench_continuous`
    pub mean_in_flight: f64,
    /// prompt tokens dropped by admission-time multimodal pruning (0
    /// without a class policy): the KV bytes the pool never charged
    pub pruned_prompt_tokens: usize,
    /// prompt prefills routed through the sparse-attention path
    /// (LongContext class under a class policy)
    pub sparse_prefills: usize,
    /// request ids in admission order (re-admissions repeat the id).
    /// Deterministic on the virtual-clock twin; under `threads: true` it
    /// records the actual interleaving
    pub admitted_order: Vec<u64>,
}

/// Per-class slice of a [`ServeReport`]: outcome tallies, latency
/// summaries over completed requests, and SLO attainment against a
/// [`ClassPolicy`].
#[derive(Clone, Debug)]
pub struct ClassStats {
    /// [`RequestClass::name`] this row aggregates
    pub name: &'static str,
    pub counts: OutcomeCounts,
    /// TTFT over completed requests of this class
    pub ttft: Summary,
    /// total latency over completed requests of this class
    pub latency: Summary,
    /// completed requests whose TTFT met the class `ttft_slo_ms`
    pub ttft_attained: usize,
    /// completed requests whose total latency met the class `latency_slo_ms`
    pub latency_attained: usize,
}

impl ClassStats {
    /// Requests this row covers (every terminal outcome).
    pub fn total(&self) -> usize {
        self.counts.completed
            + self.counts.failed
            + self.counts.deadline_exceeded
            + self.counts.shed
    }

    /// Fraction of completed requests meeting the TTFT SLO (1.0 when the
    /// class completed nothing — vacuous attainment).
    pub fn ttft_attainment(&self) -> f64 {
        if self.counts.completed == 0 {
            1.0
        } else {
            self.ttft_attained as f64 / self.counts.completed as f64
        }
    }

    /// Fraction of completed requests meeting the latency SLO.
    pub fn latency_attainment(&self) -> f64 {
        if self.counts.completed == 0 {
            1.0
        } else {
            self.latency_attained as f64 / self.counts.completed as f64
        }
    }
}

impl ServeReport {
    pub fn tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_s
        }
    }

    /// Tokens per second on the virtual timeline (total tokens over the
    /// schedule makespan) — the throughput the worker pool models, and the
    /// number that scales with `ServeCfg::workers` (`bench_sharded`
    /// tracks it; `tps()` measures the simulation's real wall time, which
    /// executes workers' rounds one at a time).
    pub fn virtual_tps(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / (self.makespan_ms / 1e3)
        }
    }

    /// Worker count that produced this report.
    pub fn workers(&self) -> usize {
        self.worker_peak_kv_bytes.len().max(1)
    }

    /// Fraction of speculative proposals the target accepted (0.0 when
    /// nothing was proposed — greedy serving).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Requests that decoded to completion — the number a fault-tolerant
    /// pool is graded on (`bench_faults` gates on it).
    pub fn goodput(&self) -> usize {
        self.completed.iter().filter(|c| c.is_completed()).count()
    }

    /// Per-outcome tallies across every submitted request.
    pub fn outcome_counts(&self) -> OutcomeCounts {
        let mut counts = OutcomeCounts::default();
        for c in &self.completed {
            match c.outcome {
                RequestOutcome::Completed => counts.completed += 1,
                RequestOutcome::Failed { .. } => counts.failed += 1,
                RequestOutcome::DeadlineExceeded => counts.deadline_exceeded += 1,
                RequestOutcome::Shed => counts.shed += 1,
            }
        }
        counts
    }

    /// Requests that consumed more than one execution attempt.
    pub fn retried(&self) -> usize {
        self.completed.iter().filter(|c| c.attempts > 1).count()
    }

    /// TTFT over requests that completed (failed/cancelled requests would
    /// skew the latency picture with eviction times).
    pub fn ttft_summary(&self) -> Summary {
        Summary::of(
            &self
                .completed
                .iter()
                .filter(|c| c.is_completed())
                .map(|c| c.ttft_ms)
                .collect::<Vec<_>>(),
        )
    }

    /// Total latency over requests that completed.
    pub fn latency_summary(&self) -> Summary {
        Summary::of(
            &self
                .completed
                .iter()
                .filter(|c| c.is_completed())
                .map(|c| c.total_ms)
                .collect::<Vec<_>>(),
        )
    }

    /// Per-class outcome tallies, latency summaries, and SLO attainment
    /// under `policy`, one row per [`RequestClass::NAMES`] entry in that
    /// order (classes with no traffic report zero counts).
    pub fn class_breakdown(&self, policy: &ClassPolicy) -> Vec<ClassStats> {
        RequestClass::NAMES
            .iter()
            .map(|&name| {
                let slo = policy.slo_of_name(name);
                let mut counts = OutcomeCounts::default();
                let mut ttfts = Vec::new();
                let mut lats = Vec::new();
                let (mut ttft_ok, mut lat_ok) = (0usize, 0usize);
                for c in self.completed.iter().filter(|c| c.class.name() == name) {
                    match c.outcome {
                        RequestOutcome::Completed => counts.completed += 1,
                        RequestOutcome::Failed { .. } => counts.failed += 1,
                        RequestOutcome::DeadlineExceeded => {
                            counts.deadline_exceeded += 1
                        }
                        RequestOutcome::Shed => counts.shed += 1,
                    }
                    if c.is_completed() {
                        ttfts.push(c.ttft_ms);
                        lats.push(c.total_ms);
                        if c.ttft_ms <= slo.ttft_slo_ms {
                            ttft_ok += 1;
                        }
                        if c.total_ms <= slo.latency_slo_ms {
                            lat_ok += 1;
                        }
                    }
                }
                ClassStats {
                    name,
                    counts,
                    ttft: Summary::of(&ttfts),
                    latency: Summary::of(&lats),
                    ttft_attained: ttft_ok,
                    latency_attained: lat_ok,
                }
            })
            .collect()
    }
}

pub struct ServingEngine;

impl ServingEngine {
    /// Serve a trace one request at a time in arrival order (b1 models).
    /// `draft = None` -> vanilla decoding; `Some((draft, gamma))` ->
    /// speculative decoding. Sequential configuration of the scheduler.
    pub fn serve<D: SessionModel, T: SessionModel>(
        requests: Vec<TokenRequest>,
        target: &T,
        draft: Option<(&D, usize)>,
        seed: u64,
    ) -> Result<ServeReport> {
        Self::serve_scheduled(requests, target, draft, &ServeCfg::sequential(), seed)
    }

    /// Serve under an explicit scheduler configuration — the continuous
    /// batching / sharded entry point (admission policy, per-worker
    /// in-flight cap, KV budget, worker count). `cfg.workers > 1` staffs a
    /// [`WorkerPool`] with one executor per worker, all borrowing the same
    /// model(s); per-request outputs stay bit-identical to sequential
    /// decoding for every worker count.
    pub fn serve_scheduled<D: SessionModel, T: SessionModel>(
        requests: Vec<TokenRequest>,
        target: &T,
        draft: Option<(&D, usize)>,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        match draft {
            Some((d, gamma)) => {
                WorkerPool::run(requests, |_| SpecExecutor::new(d, target, gamma), cfg, seed)
            }
            None => WorkerPool::run(
                requests,
                |_| GreedyExecutor::new(target).with_class_policy(cfg.classes.clone()),
                cfg,
                seed,
            ),
        }
    }

    /// Serve through the paged-KV executors: block-granular admission
    /// (a request starts when its *prompt's* pages fit; decode growth
    /// claims one page at a time, preempting the lowest-progress request
    /// on pool exhaustion) with copy-on-write prefix sharing across
    /// requests on the same worker. Page size comes from
    /// `cfg.kv_block_tokens` (default 16 tokens); per-request outputs are
    /// bit-identical to [`ServingEngine::serve_scheduled`] on the
    /// contiguous executors.
    pub fn serve_paged(
        requests: Vec<TokenRequest>,
        target: &crate::models::Transformer,
        draft: Option<(&crate::models::Transformer, usize)>,
        cfg: &ServeCfg,
        seed: u64,
    ) -> Result<ServeReport> {
        let bt = cfg.kv_block_tokens.unwrap_or(16);
        let budgets = cfg.per_worker_budgets();
        match draft {
            Some((d, gamma)) => WorkerPool::run(
                requests,
                |w| {
                    PagedSpecExecutor::new(d, target, gamma, bt, budgets[w])
                        .with_class_policy(cfg.classes.clone())
                },
                cfg,
                seed,
            ),
            None => WorkerPool::run(
                requests,
                |w| {
                    PagedGreedyExecutor::new(target, bt, budgets[w])
                        .with_class_policy(cfg.classes.clone())
                },
                cfg,
                seed,
            ),
        }
    }

    /// Static batched greedy decoding on any session model: up to
    /// `max_batch` requests decode together and the whole chunk drains
    /// before the next one is admitted. Static configuration of the
    /// scheduler — kept as the baseline the continuous bench compares
    /// against.
    pub fn serve_batched<T: SessionModel>(
        requests: Vec<TokenRequest>,
        target: &T,
        max_batch: usize,
    ) -> Result<ServeReport> {
        Scheduler::run(
            requests,
            GreedyExecutor::new(target),
            &ServeCfg::static_batch(max_batch),
            0,
        )
    }

    /// Batched vanilla decoding on a b>1 executable: all live requests
    /// advance one token per joint forward. Static configuration of the
    /// scheduler over the PJRT step executor.
    pub fn serve_batched_pjrt(
        requests: Vec<TokenRequest>,
        exe: &crate::runtime::ModelExecutable,
    ) -> Result<ServeReport> {
        Scheduler::run(
            requests,
            PjrtBatchExecutor::new(exe),
            &ServeCfg::static_batch(exe.batch),
            0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec_decode::engine::tests_support::ToyModel;

    fn reqs(n: usize) -> Vec<TokenRequest> {
        (0..n)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: 10,
                arrival_ms: i as f64 * 2.0,
                deadline_ms: None,
                class: Default::default(),
            })
            .collect()
    }

    #[test]
    fn vanilla_serving_completes_all() {
        let target = ToyModel::new(3);
        let report =
            ServingEngine::serve::<ToyModel, _>(reqs(6), &target, None, 0).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert!(report.completed.iter().all(|c| c.generated == 10));
        assert!(report.tps() > 0.0);
        assert_eq!(report.mean_al, 1.0);
        assert_eq!(report.goodput(), 6);
        assert_eq!(report.retried(), 0);
        assert!(report.crashed_workers.is_empty());
        let counts = report.outcome_counts();
        assert_eq!(counts.completed, 6);
        assert_eq!(counts.failed + counts.deadline_exceeded + counts.shed, 0);
    }

    #[test]
    fn speculative_serving_same_outputs_higher_al() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(3);
        let v = ServingEngine::serve::<ToyModel, _>(reqs(4), &target, None, 0).unwrap();
        let s = ServingEngine::serve(reqs(4), &target, Some((&draft, 3)), 0).unwrap();
        for (a, b) in v.completed.iter().zip(&s.completed) {
            assert_eq!(a.output, b.output, "spec decode must preserve outputs");
        }
        assert!(s.mean_al > 2.0, "AL {}", s.mean_al);
        assert!(s.acceptance_rate() > 0.9, "{}", s.acceptance_rate());
        assert_eq!(v.proposed, 0, "greedy serving proposes nothing");
    }

    #[test]
    fn batched_serving_matches_sequential_outputs() {
        let target = ToyModel::new(3);
        let sequential =
            ServingEngine::serve::<ToyModel, _>(reqs(7), &target, None, 0).unwrap();
        let batched = ServingEngine::serve_batched(reqs(7), &target, 4).unwrap();
        assert_eq!(batched.completed.len(), 7);
        assert_eq!(batched.total_tokens, sequential.total_tokens);
        for (a, b) in sequential.completed.iter().zip(&batched.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "batched decode changed request {}", a.id);
        }
    }

    #[test]
    fn ttft_includes_queueing_on_the_unified_clock() {
        let target = ToyModel::new(1);
        let report =
            ServingEngine::serve::<ToyModel, _>(reqs(8), &target, None, 0).unwrap();
        let ttft = report.ttft_summary();
        assert!(ttft.max >= ttft.min);
        for c in &report.completed {
            assert!(c.ttft_ms >= 0.0, "ttft measured from arrival");
            assert!(c.ttft_ms <= c.total_ms + 1e-9);
        }
    }

    #[test]
    fn paged_serving_matches_contiguous_outputs() {
        use crate::models::Transformer;
        let target = crate::util::fixtures::fixture_target(3);
        let cfg = ServeCfg::continuous(4).with_block_tokens(4);
        let flat = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs(5),
            &target,
            None,
            &cfg,
            0,
        )
        .unwrap();
        let paged = ServingEngine::serve_paged(reqs(5), &target, None, &cfg, 0).unwrap();
        crate::util::testing::assert_outputs_match(
            &flat,
            &paged,
            "serve_paged vs contiguous serve_scheduled",
        );
        assert!(paged.peak_in_flight >= 1);
        assert!(paged.mean_in_flight > 0.0);
    }

    #[test]
    fn continuous_serving_matches_sequential_outputs() {
        let target = ToyModel::new(3);
        let sequential =
            ServingEngine::serve::<ToyModel, _>(reqs(7), &target, None, 0).unwrap();
        let continuous = ServingEngine::serve_scheduled::<ToyModel, _>(
            reqs(7),
            &target,
            None,
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        for (a, b) in sequential.completed.iter().zip(&continuous.completed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output, "continuous changed request {}", a.id);
        }
    }
}
