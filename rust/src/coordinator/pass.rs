//! The composable compression-pass API — the paper's unified pipeline
//! (Fig. 6) as a first-class abstraction.
//!
//! A [`CompressionPass`] is one named stage (GPTQ, SmoothQuant migration,
//! token pruning, an eval checkpoint, ...) executed over a shared
//! [`PassContext`]: the mutating model, the calibration / evaluation
//! datasets, cached calibration activations (invalidated whenever a pass
//! mutates the model), a seeded RNG, and the accumulated per-stage
//! reports. `CompressEngine::run` threads the context through the
//! config's `pipeline:` stages and emits a structured [`PipelineReport`],
//! so compositions like smooth → GPTQ → eval are ordinary configs instead
//! of impossible special cases.
//!
//! Every pass lives in the single static registry
//! (`coordinator::registry::PassRegistry`); the engine, `SlimFactory`,
//! `angelslim list`, and config-schema validation all read from it.

use crate::config::{SlimConfig, StageCfg};
use crate::models::Transformer;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::Result;

use super::factories::{DataFactory, Datasets, ModelFactory};

/// How many calibration sequences are captured for activation statistics
/// (GPTQ / AWQ / LeptoQuant / SmoothQuant).
pub const CALIB_SEQS: usize = 8;

/// NLL evaluation window / stride shared by every pass that scores the
/// current model on the held-out stream.
pub const EVAL_WINDOW: usize = 48;
pub const EVAL_STRIDE: usize = 8;

/// The method family a pass belongs to — the paper's four compression
/// pillars plus the in-pipeline evaluation checkpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PassKind {
    Quantization,
    SpecDecode,
    SparseAttn,
    TokenPrune,
    Eval,
}

impl PassKind {
    pub fn all() -> [PassKind; 5] {
        [
            PassKind::Quantization,
            PassKind::SpecDecode,
            PassKind::SparseAttn,
            PassKind::TokenPrune,
            PassKind::Eval,
        ]
    }

    /// The `compression.method` string this family answers to.
    pub fn method(&self) -> &'static str {
        match self {
            PassKind::Quantization => "quantization",
            PassKind::SpecDecode => "spec_decode",
            PassKind::SparseAttn => "sparse_attn",
            PassKind::TokenPrune => "token_prune",
            PassKind::Eval => "eval",
        }
    }

    pub fn from_method(method: &str) -> Option<PassKind> {
        PassKind::all().into_iter().find(|k| k.method() == method)
    }

    /// The pass a bare `compression.method` desugars to when no algo is
    /// named — kept next to the registry so the default cannot drift from
    /// what is actually registered (pinned by a registry test).
    pub fn default_pass(&self) -> &'static str {
        match self {
            PassKind::Quantization => "fp8_dynamic",
            PassKind::SpecDecode => "eagle3",
            PassKind::SparseAttn => "stem",
            PassKind::TokenPrune => "idpruner",
            PassKind::Eval => "eval",
        }
    }
}

/// Per-layer calibration activations captured from the *current* model
/// weights (tagged with the model version that produced them).
#[derive(Clone, Debug)]
pub struct CalibCapture {
    pub model_version: u64,
    /// post-ln1 inputs to wq/wk/wv, one `[rows, d]` tensor per layer
    pub attn_in: Vec<Tensor>,
    /// post-ln2 inputs to w_gate/w_up, one `[rows, d]` tensor per layer
    pub mlp_in: Vec<Tensor>,
}

/// Shared state threaded through every stage of a pipeline run.
///
/// Model and datasets load lazily so passes that need neither (visual /
/// audio token pruning on synthetic scenes) stay hermetic even when the
/// configured model artifacts are absent — exactly like the pre-pipeline
/// engine behaved.
pub struct PassContext {
    pub cfg: SlimConfig,
    model: Option<Transformer>,
    datasets: Option<Datasets>,
    /// seeded from `global.seed`: the one RNG stream for passes that need
    /// randomness. No built-in pass draws from it (they stay bit-identical
    /// to the legacy engine, pinned by tests/test_pass_pipeline.rs);
    /// drawing from it in a new pass is safe — it feeds nothing else.
    pub rng: Rng,
    /// bumped by `mark_model_mutated`; invalidates the calibration cache
    pub model_version: u64,
    calib: Option<CalibCapture>,
    /// memoized held-out NLL of the current weights, keyed by version —
    /// a stage's "before" is bit-identical to its predecessor's "after",
    /// so stage boundaries don't re-run the dominant eval
    nll_cache: Option<(u64, f64)>,
    /// NLL of the model the first metric-producing stage saw — the
    /// pipeline-wide "before" an eval checkpoint reports against
    pub baseline_nll: Option<f64>,
    /// accumulated per-stage reports (what `PipelineReport` is built from)
    pub reports: Vec<StageReport>,
}

impl PassContext {
    pub fn new(cfg: SlimConfig) -> Self {
        let rng = Rng::new(cfg.global.seed ^ 0x9A55_C0DE);
        PassContext {
            cfg,
            model: None,
            datasets: None,
            rng,
            model_version: 0,
            calib: None,
            nll_cache: None,
            baseline_nll: None,
            reports: Vec::new(),
        }
    }

    /// The model under compression (loaded on first use).
    pub fn model(&mut self) -> Result<&mut Transformer> {
        if self.model.is_none() {
            self.model = Some(ModelFactory::load(&self.cfg)?);
        }
        Ok(self.model.as_mut().unwrap())
    }

    /// Calibration + evaluation datasets (loaded on first use).
    pub fn datasets(&mut self) -> Result<&Datasets> {
        if self.datasets.is_none() {
            self.datasets = Some(DataFactory::load(&self.cfg)?);
        }
        Ok(self.datasets.as_ref().unwrap())
    }

    /// Both at once (split borrow for calibrate-then-mutate passes).
    pub fn model_and_data(&mut self) -> Result<(&mut Transformer, &Datasets)> {
        self.model()?;
        self.datasets()?;
        Ok((self.model.as_mut().unwrap(), self.datasets.as_ref().unwrap()))
    }

    /// Record that the model weights changed: calibration activations
    /// captured before this point no longer describe the model, so the
    /// cached capture is freed immediately (it could never be reused —
    /// the version bump alone would keep it resident until the next
    /// capture or the end of the run).
    pub fn mark_model_mutated(&mut self) {
        self.model_version += 1;
        self.calib = None;
    }

    /// Calibration activations for the current weights, recapturing only
    /// when a pass has mutated the model since the last capture — so
    /// back-to-back calibrated passes share one capture.
    pub fn calib(&mut self) -> Result<&CalibCapture> {
        let version = self.model_version;
        if self.calib.as_ref().map(|c| c.model_version) != Some(version) {
            let (model, ds) = self.model_and_data()?;
            let (n_layers, d) = (model.cfg.n_layers, model.cfg.d_model);
            let mut attn: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
            let mut mlp: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
            for seq in ds.calib.iter().take(CALIB_SEQS) {
                let caps = model.capture_activations(seq);
                for (li, cap) in caps.iter().enumerate() {
                    attn[li].extend_from_slice(&cap.attn_in.data);
                    mlp[li].extend_from_slice(&cap.mlp_in.data);
                }
            }
            let to_tensors = |cols: Vec<Vec<f32>>| -> Vec<Tensor> {
                cols.into_iter()
                    .map(|v| {
                        let rows = v.len() / d;
                        Tensor::from_vec(&[rows, d], v)
                    })
                    .collect()
            };
            self.calib = Some(CalibCapture {
                model_version: version,
                attn_in: to_tensors(attn),
                mlp_in: to_tensors(mlp),
            });
        }
        Ok(self.calib.as_ref().unwrap())
    }

    /// Run `f` with the current calibration capture *and* mutable context
    /// access, without cloning the capture: the capture is moved out for
    /// the duration of the call and restored afterwards, so peak memory
    /// stays one capture. `f` must not call `ctx.calib()` (it would
    /// recapture into the temporarily-empty slot); mutating the model is
    /// fine — the caller bumps the version afterwards as usual.
    pub fn with_calib<R>(
        &mut self,
        f: impl FnOnce(&mut PassContext, &CalibCapture) -> Result<R>,
    ) -> Result<R> {
        self.calib()?;
        let capture = self.calib.take().expect("calib() just populated the capture");
        let out = f(self, &capture);
        self.calib = Some(capture);
        out
    }

    /// NLL of the current model on the held-out stream — the shared
    /// quality metric quant/eval stages report. Memoized per model
    /// version: deterministic evals of the same weights are bit-identical,
    /// so a stage's "before" reuses the previous stage's "after" for free.
    pub fn nll(&mut self) -> Result<f64> {
        if let Some((version, nll)) = self.nll_cache {
            if version == self.model_version {
                return Ok(nll);
            }
        }
        let version = self.model_version;
        let (model, ds) = self.model_and_data()?;
        let nll = crate::eval::corpus_nll(model, &ds.eval, EVAL_WINDOW, EVAL_STRIDE)?;
        self.nll_cache = Some((version, nll));
        Ok(nll)
    }

    /// Record the pipeline-wide baseline metric (first writer wins).
    pub fn note_baseline(&mut self, nll: f64) {
        if self.baseline_nll.is_none() {
            self.baseline_nll = Some(nll);
        }
    }

    /// Surrender the (possibly mutated) model — the bit-exactness witness
    /// for pipeline-equivalence tests. `None` if no stage ever loaded it.
    pub fn into_model(self) -> Option<Transformer> {
        self.model
    }
}

/// What a pass hands back from `apply`: the raw stage metrics, before the
/// trait's `report` hook folds in identity / wall-clock / size ratio.
#[derive(Clone, Debug, Default)]
pub struct StageOutcome {
    /// quantization/eval: NLL; sparse/prune: accuracy (audio: WER%)
    pub metric_before: f64,
    pub metric_after: f64,
    /// effective bits per weight (quantization) or kept density/ratio
    pub compression: f64,
    pub notes: Vec<String>,
    /// peak resident bytes during calibration (low-memory mode)
    pub peak_calib_bytes: usize,
}

/// One finished stage of a pipeline run.
#[derive(Clone, Debug, PartialEq)]
pub struct StageReport {
    /// registry name of the pass ("gptq", "smooth", "eval", ...)
    pub pass: String,
    /// method family ("quantization", "token_prune", ...)
    pub kind: String,
    pub metric_before: f64,
    pub metric_after: f64,
    /// effective bits per weight (quantization) or kept density/ratio
    pub compression: f64,
    /// stored-size multiplier this stage contributes (bits/32 for
    /// quantization, kept fraction for prune/sparse, 1.0 otherwise)
    pub size_ratio: f64,
    pub wall_ms: f64,
    pub peak_calib_bytes: usize,
    pub notes: Vec<String>,
}

impl StageReport {
    /// Report-number equality ignoring wall-clock (the only
    /// non-deterministic field) — what pipeline-equivalence tests compare.
    pub fn same_numbers(&self, other: &StageReport) -> bool {
        self.pass == other.pass
            && self.kind == other.kind
            && self.metric_before.to_bits() == other.metric_before.to_bits()
            && self.metric_after.to_bits() == other.metric_after.to_bits()
            && self.compression.to_bits() == other.compression.to_bits()
            && self.size_ratio.to_bits() == other.size_ratio.to_bits()
            && self.peak_calib_bytes == other.peak_calib_bytes
            && self.notes == other.notes
    }

    fn json_fragment(&self) -> String {
        let notes = self
            .notes
            .iter()
            .map(|n| format!("\"{}\"", json_escape(n)))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"pass\":\"{}\",\"kind\":\"{}\",\"metric_before\":{},\"metric_after\":{},\
             \"compression\":{},\"size_ratio\":{},\"wall_ms\":{},\"peak_calib_bytes\":{},\
             \"notes\":[{}]}}",
            json_escape(&self.pass),
            json_escape(&self.kind),
            json_num(self.metric_before),
            json_num(self.metric_after),
            json_num(self.compression),
            json_num(self.size_ratio),
            json_num(self.wall_ms),
            self.peak_calib_bytes,
            notes
        )
    }
}

/// The structured result of a pipeline run — one entry per stage, in
/// execution order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineReport {
    pub stages: Vec<StageReport>,
}

impl PipelineReport {
    pub fn final_stage(&self) -> &StageReport {
        self.stages.last().expect("a validated pipeline has >= 1 stage")
    }

    /// The pipeline's combined stored-size multiplier vs the fp32 model.
    /// Weight quantizers *replace* the stored weight image, so only the
    /// last quantization stage's ratio counts (int8 → int4 stores int4,
    /// not int4-of-int8; gptq → smooth re-scales the weights off the int
    /// grid back to fp32). Prune/sparse ratios act on different axes
    /// (tokens / attention) and compose multiplicatively.
    pub fn overall_size_ratio(&self) -> f64 {
        let weights = self
            .stages
            .iter()
            .rev()
            .find(|s| s.kind == "quantization")
            .map(|s| s.size_ratio)
            .unwrap_or(1.0);
        let other: f64 = self
            .stages
            .iter()
            .filter(|s| s.kind != "quantization")
            .map(|s| s.size_ratio)
            .product();
        weights * other
    }

    pub fn total_wall_ms(&self) -> f64 {
        self.stages.iter().map(|s| s.wall_ms).sum()
    }

    /// One machine-readable JSON object (no prefix) following the same
    /// conventions as the benches' BENCH_JSON lines; `angelslim compress
    /// --json` prints it behind the `BENCH_JSON ` prefix so CI can gate on
    /// `python -m json.tool` parsing it.
    pub fn to_json(&self, config: &str) -> String {
        let stages = self
            .stages
            .iter()
            .map(StageReport::json_fragment)
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"bench\":\"compress\",\"config\":\"{}\",\"stages\":[{}],\
             \"overall_size_ratio\":{},\"total_wall_ms\":{}}}",
            json_escape(config),
            stages,
            json_num(self.overall_size_ratio()),
            json_num(self.total_wall_ms())
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf literals; clamp them to null so the line always
/// parses.
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// A composable compression stage. Implementations are stateless unit
/// values registered once in `PassRegistry`; all per-run inputs arrive
/// via the shared context and the stage's resolved config.
pub trait CompressionPass: Sync {
    /// Registry name — the string configs dispatch on.
    fn name(&self) -> &'static str;
    /// Method family (groups the registry for listing/validation).
    fn kind(&self) -> PassKind;
    /// One-line human description for `angelslim list`.
    fn describe(&self) -> &'static str;

    /// Cheap feasibility checks against the context (model shape
    /// constraints, missing inputs) — loud errors before any work.
    fn prepare(&self, _ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        Ok(())
    }

    /// Gather calibration statistics into the shared context (shared and
    /// reused across consecutive stages until the model mutates).
    fn calibrate(&self, _ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        Ok(())
    }

    /// Run the stage: mutate the model / score the method, returning the
    /// stage metrics.
    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome>;

    /// Fold an outcome into the structured per-stage report.
    fn report(&self, outcome: StageOutcome, wall_ms: f64) -> StageReport {
        let size_ratio = match self.kind() {
            PassKind::Quantization => outcome.compression / 32.0,
            PassKind::SparseAttn | PassKind::TokenPrune => outcome.compression,
            PassKind::SpecDecode | PassKind::Eval => 1.0,
        };
        StageReport {
            pass: self.name().into(),
            kind: self.kind().method().into(),
            metric_before: outcome.metric_before,
            metric_after: outcome.metric_after,
            compression: outcome.compression,
            size_ratio,
            wall_ms,
            peak_calib_bytes: outcome.peak_calib_bytes,
            notes: outcome.notes,
        }
    }
}

/// Write the per-stage checkpoint marker (the save step of the paper's
/// prepare → calibrate → compress → save → eval flow).
pub(crate) fn save_marker(cfg: &SlimConfig, algo: &str, notes: &mut Vec<String>) -> Result<()> {
    let dir = &cfg.global.save_path;
    std::fs::create_dir_all(dir)?;
    let marker = format!("{dir}/compressed_{algo}.txt");
    std::fs::write(&marker, format!("{cfg:#?}"))?;
    notes.push(format!("checkpoint note saved to {marker}"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Json;

    fn stage(pass: &str, kind: &str) -> StageReport {
        StageReport {
            pass: pass.into(),
            kind: kind.into(),
            metric_before: 0.25,
            metric_after: 0.5,
            compression: 5.0,
            size_ratio: 5.0 / 32.0,
            wall_ms: 12.5,
            peak_calib_bytes: 64,
            notes: vec!["a \"quoted\" note".into()],
        }
    }

    #[test]
    fn pipeline_json_parses_with_own_parser() {
        let report = PipelineReport {
            stages: vec![stage("gptq", "quantization"), stage("eval", "eval")],
        };
        let line = report.to_json("configs/x.yaml");
        let v = Json::parse(&line).expect("report JSON must parse");
        assert_eq!(v.get("bench").unwrap().as_str(), Some("compress"));
        let stages = v.get("stages").unwrap();
        assert_eq!(stages.idx(0).unwrap().get("pass").unwrap().as_str(), Some("gptq"));
        let note = stages.idx(1).unwrap().get("notes").unwrap().idx(0).unwrap();
        assert_eq!(note.as_str(), Some("a \"quoted\" note"));
    }

    #[test]
    fn non_finite_metrics_emit_null_not_nan() {
        let mut s = stage("eval", "eval");
        s.metric_before = f64::NAN;
        let line = PipelineReport { stages: vec![s] }.to_json("c");
        assert!(Json::parse(&line).is_ok(), "NaN must not break the JSON line: {line}");
        assert!(line.contains("null"));
    }

    #[test]
    fn same_numbers_ignores_wall_clock_only() {
        let a = stage("gptq", "quantization");
        let mut b = a.clone();
        b.wall_ms = 9999.0;
        assert!(a.same_numbers(&b));
        b.metric_after += 1e-12;
        assert!(!a.same_numbers(&b));
    }

    #[test]
    fn kind_method_roundtrip_and_defaults() {
        for k in PassKind::all() {
            assert_eq!(PassKind::from_method(k.method()), Some(k));
        }
        assert_eq!(PassKind::from_method("teleport"), None);
    }

    #[test]
    fn overall_size_ratio_last_quantizer_wins() {
        let quant = |pass: &str, bits: f64| StageReport {
            compression: bits,
            size_ratio: bits / 32.0,
            ..stage(pass, "quantization")
        };
        // successive weight quantizers replace the image — no double count
        let r = PipelineReport { stages: vec![quant("int8", 8.0), quant("int4", 5.0)] };
        assert!((r.overall_size_ratio() - 5.0 / 32.0).abs() < 1e-12);
        // prune composes with the (last) weight format
        let mut prune = stage("idpruner", "token_prune");
        prune.size_ratio = 0.25;
        let r = PipelineReport { stages: vec![prune, quant("int4", 5.0)] };
        assert!((r.overall_size_ratio() - 0.25 * 5.0 / 32.0).abs() < 1e-12);
        // no quantizer at all → only the prune axis
        let mut prune = stage("idpruner", "token_prune");
        prune.size_ratio = 0.25;
        let r = PipelineReport { stages: vec![prune] };
        assert!((r.overall_size_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn context_rng_is_seeded_and_deterministic() {
        let cfg = SlimConfig::from_str(
            "global:\n  seed: 9\nmodel:\n  name: tiny-fixture\n\
             compression:\n  method: quantization\n",
        )
        .unwrap();
        let mut a = PassContext::new(cfg.clone());
        let mut b = PassContext::new(cfg);
        // the pass-facing RNG stream is a pure function of global.seed
        let draw = |ctx: &mut PassContext| (0..8).map(|_| ctx.rng.next_u64()).collect::<Vec<_>>();
        assert_eq!(draw(&mut a), draw(&mut b));
    }
}
