//! The three factories of the paper's Module Init stage (Fig. 6):
//! ModelFactory (registered base models), DataFactory (dataset loaders),
//! SlimFactory (compression strategy dispatch).

use crate::config::SlimConfig;
use crate::data;
use crate::models::{Transformer, WeightStore};
use anyhow::{bail, Context, Result};

#[cfg(test)]
use super::registry::PassRegistry;

/// ModelFactory: registry keys -> loaded models.
pub struct ModelFactory;

impl ModelFactory {
    pub fn registered() -> &'static [&'static str] {
        &[
            "tiny-target",
            "tiny-draft",
            "tiny-small",
            "tiny-fixture",
            "tiny-fixture-draft",
            "packed-artifact",
        ]
    }

    pub fn load(cfg: &SlimConfig) -> Result<Transformer> {
        // hermetic fixture models need no artifacts/ on disk — the whole
        // pipeline runs in-memory (seeded by global.seed)
        match cfg.model.name.as_str() {
            "tiny-fixture" => {
                return Ok(crate::util::fixtures::fixture_target(cfg.global.seed))
            }
            "tiny-fixture-draft" => {
                return Ok(crate::util::fixtures::fixture_draft(cfg.global.seed))
            }
            // serve directly from a compress job's exported packed artifact
            // (`export-packed` stage output in model.artifacts_dir)
            "packed-artifact" => {
                return crate::models::packed_store::load_packed(&cfg.model.artifacts_dir)
                    .context("loading packed artifact")
            }
            _ => {}
        }
        let ws = WeightStore::load(&cfg.model.artifacts_dir)
            .context("loading weight store")?;
        let key = match cfg.model.name.as_str() {
            "tiny-target" => "target",
            "tiny-draft" => "draft",
            other => bail!(
                "unknown model `{other}` (registered: {:?})",
                Self::registered()
            ),
        };
        Transformer::from_store(&ws, key)
    }
}

/// DataFactory: dataset kind -> calibration / evaluation token sets.
pub struct DataFactory;

pub struct Datasets {
    /// calibration sequences (token windows)
    pub calib: Vec<Vec<u8>>,
    /// held-out evaluation stream
    pub eval: Vec<u8>,
}

impl DataFactory {
    pub fn load(cfg: &SlimConfig) -> Result<Datasets> {
        let fixture_spec = crate::util::fixtures::FixtureSpec::default();
        let eval = match cfg.dataset.kind.as_str() {
            "synthetic" => data::markov_corpus(32_768, cfg.dataset.seed ^ 0xE7A1),
            "fixture" => crate::util::fixtures::fixture_corpus(
                &fixture_spec,
                16_384,
                cfg.dataset.seed ^ 0xE7A1,
            ),
            "artifact" => data::load_corpus(&format!(
                "{}/eval_corpus.bin",
                cfg.model.artifacts_dir
            ))?,
            other => bail!("unknown dataset kind `{other}`"),
        };
        let train = match cfg.dataset.kind.as_str() {
            "artifact" => data::load_corpus(&format!(
                "{}/train_corpus.bin",
                cfg.model.artifacts_dir
            ))?,
            "fixture" => {
                crate::util::fixtures::fixture_corpus(&fixture_spec, 32_768, cfg.dataset.seed)
            }
            _ => data::markov_corpus(65_536, cfg.dataset.seed),
        };
        let mut calib = Vec::with_capacity(cfg.dataset.num_samples);
        let stride = (train.len() - cfg.dataset.seq_len - 1) / cfg.dataset.num_samples.max(1);
        for i in 0..cfg.dataset.num_samples {
            let s = i * stride.max(1);
            calib.push(train[s..s + cfg.dataset.seq_len].to_vec());
        }
        Ok(Datasets { calib, eval })
    }
}

/// ServeFactory: config -> serving-scheduler setup (models + ServeCfg).
pub struct ServeFactory;

impl ServeFactory {
    /// The scheduler configuration for this job (the `serve:` section of
    /// the YAML, already parsed and validated).
    pub fn serve_cfg(cfg: &SlimConfig) -> crate::server::ServeCfg {
        cfg.serve.clone()
    }

    /// Target model, plus the aligned draft when the job's compression
    /// method is `spec_decode` (speculative serving needs both).
    pub fn load_models(cfg: &SlimConfig) -> Result<(Transformer, Option<Transformer>)> {
        let target = ModelFactory::load(cfg)?;
        if cfg.compression.method != "spec_decode" {
            return Ok((target, None));
        }
        let draft_name = match cfg.model.name.as_str() {
            "tiny-fixture" => "tiny-fixture-draft",
            "tiny-target" => "tiny-draft",
            other => bail!("no registered draft model for target `{other}`"),
        };
        let mut draft_cfg = cfg.clone();
        draft_cfg.model.name = draft_name.into();
        let draft = ModelFactory::load(&draft_cfg)?;
        Ok((target, Some(draft)))
    }
}

/// SlimFactory: the compression strategy surface of the Module Init stage.
/// Both the listing and the validation render directly from the single
/// static `PassRegistry`, so they cannot drift from what the engine
/// actually dispatches.
pub struct SlimFactory;

impl SlimFactory {
    /// Method families and their registered passes, straight from the
    /// `PassRegistry` (the same table `angelslim list` prints and the
    /// engine dispatches on).
    pub fn registered() -> Vec<(&'static str, Vec<&'static str>)> {
        super::registry::PassRegistry::by_method()
    }

    /// Validate a job config against the registry: every pipeline stage
    /// must name a registered pass with in-range parameters. (Configs
    /// built by `SlimConfig::from_str`/`from_file` are already validated;
    /// this re-checks hand-constructed ones.)
    pub fn validate(cfg: &SlimConfig) -> Result<()> {
        cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SlimConfig;

    fn cfg(method: &str, algo: &str) -> SlimConfig {
        SlimConfig::from_str(&format!(
            "model:\n  name: tiny-target\ncompression:\n  method: {method}\n  {method}:\n    algo: {algo}\n"
        ))
        .unwrap()
    }

    #[test]
    fn slim_factory_validates_known_algos() {
        assert!(SlimFactory::validate(&cfg("quantization", "gptq")).is_ok());
        assert!(SlimFactory::validate(&cfg("sparse_attn", "stem")).is_ok());
        assert!(SlimFactory::validate(&cfg("token_prune", "samp")).is_ok());
        // unknown algos are rejected at parse time by the same registry
        let src = "model:\n  name: m\ncompression:\n  method: quantization\n  \
                   quantization:\n    algo: wizardry\n";
        assert!(SlimConfig::from_str(src).is_err());
        // ...and a hand-mutated config is re-rejected by validate()
        let mut c = cfg("quantization", "gptq");
        c.pipeline[0].pass = "wizardry".into();
        assert!(SlimFactory::validate(&c).is_err());
    }

    #[test]
    fn registered_renders_from_the_pass_registry() {
        let listed = SlimFactory::registered();
        // every listed algo resolves in the registry under its method...
        for (method, algos) in &listed {
            for algo in algos {
                let pass = PassRegistry::find(algo)
                    .unwrap_or_else(|| panic!("listed algo {algo} not in registry"));
                assert_eq!(pass.kind().method(), *method);
            }
        }
        // ...and the listing covers the whole registry (no drift possible)
        let total: usize = listed.iter().map(|(_, a)| a.len()).sum();
        assert_eq!(total, PassRegistry::all().len());
    }

    #[test]
    fn data_factory_synthetic() {
        let c = cfg("quantization", "int8");
        let ds = DataFactory::load(&c).unwrap();
        assert_eq!(ds.calib.len(), c.dataset.num_samples);
        assert!(ds.calib.iter().all(|s| s.len() == c.dataset.seq_len));
        assert!(!ds.eval.is_empty());
    }

    #[test]
    fn model_factory_rejects_unknown() {
        let mut c = cfg("quantization", "int8");
        c.model.name = "gpt-4".into();
        assert!(ModelFactory::load(&c).is_err());
    }

    #[test]
    fn serve_factory_loads_fixture_pair() {
        let mut c = cfg("spec_decode", "eagle3");
        c.model.name = "tiny-fixture".into();
        let (target, draft) = ServeFactory::load_models(&c).unwrap();
        assert_eq!(target.cfg.n_layers, 2);
        let draft = draft.expect("spec_decode jobs serve with a draft");
        assert_eq!(draft.cfg.n_layers, 1);
        // non-spec jobs serve without a draft
        let mut q = cfg("quantization", "int8");
        q.model.name = "tiny-fixture".into();
        let (_, none) = ServeFactory::load_models(&q).unwrap();
        assert!(none.is_none());
        assert_eq!(ServeFactory::serve_cfg(&q), q.serve);
    }

    #[test]
    fn packed_artifact_factory_serves_exported_model() {
        use crate::models::packed_store;
        use crate::quant::packing::PackFormat;
        use crate::util::Selector;

        let mut m = crate::util::fixtures::fixture_target(11);
        m.pack_weights(&Selector::all(), PackFormat::Int4, 16).unwrap();
        let dir = std::env::temp_dir().join("angelslim_factory_packed_artifact");
        let _ = std::fs::remove_dir_all(&dir);
        let dir = dir.to_string_lossy().into_owned();
        packed_store::save_packed(&m, &dir).unwrap();

        let mut c = cfg("quantization", "int8");
        c.model.name = "packed-artifact".into();
        c.model.artifacts_dir = dir.clone();
        let loaded = ModelFactory::load(&c).unwrap();
        let toks = [2u8, 7, 12];
        assert_eq!(loaded.greedy_next(&toks), m.greedy_next(&toks));
        let _ = std::fs::remove_dir_all(&dir);

        // a missing artifact dir fails loudly, pointing at export-packed
        let err = ModelFactory::load(&c).unwrap_err();
        assert!(format!("{err:#}").contains("export-packed"), "{err:#}");
    }

    /// The `packed-artifact` serve factory surfaces artifact corruption
    /// as a structured error (never a panic): truncation and bit flips in
    /// `packed_weights.bin` both fail the stored checksum.
    #[test]
    fn packed_artifact_factory_rejects_corrupt_artifacts() {
        use crate::models::packed_store::{self, WEIGHTS_FILE};
        use crate::quant::packing::PackFormat;
        use crate::util::Selector;

        let mut m = crate::util::fixtures::fixture_target(13);
        m.pack_weights(&Selector::all(), PackFormat::TwoBit, 0).unwrap();
        let dir = std::env::temp_dir().join("angelslim_factory_packed_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let dir = dir.to_string_lossy().into_owned();
        packed_store::save_packed(&m, &dir).unwrap();

        let mut c = cfg("quantization", "int8");
        c.model.name = "packed-artifact".into();
        c.model.artifacts_dir = dir.clone();
        assert!(ModelFactory::load(&c).is_ok(), "pristine artifact serves");

        let bin = format!("{dir}/{WEIGHTS_FILE}");
        let orig = std::fs::read(&bin).unwrap();

        std::fs::write(&bin, &orig[..orig.len() - 5]).unwrap();
        let err = format!("{:#}", ModelFactory::load(&c).unwrap_err());
        assert!(err.contains("corrupt"), "truncated: {err}");

        let mut flipped = orig.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        std::fs::write(&bin, &flipped).unwrap();
        let err = format!("{:#}", ModelFactory::load(&c).unwrap_err());
        assert!(err.contains("corrupt"), "bit flip: {err}");

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fixture_factories_are_hermetic() {
        // no artifacts/ on disk needed for the fixture model + corpus
        let mut c = cfg("quantization", "int8");
        c.model.name = "tiny-fixture".into();
        c.dataset.kind = "fixture".into();
        let m = ModelFactory::load(&c).unwrap();
        assert_eq!(m.cfg.vocab, 256);
        let ds = DataFactory::load(&c).unwrap();
        assert_eq!(ds.calib.len(), c.dataset.num_samples);
        assert!(ds.eval.iter().all(|&t| (t as usize) < m.cfg.d_model));
        let d = ModelFactory::load(&{
            let mut c2 = c.clone();
            c2.model.name = "tiny-fixture-draft".into();
            c2
        })
        .unwrap();
        assert_eq!(d.cfg.n_layers, 1);
    }
}
