//! CompressEngine: the generic pipeline-stage loop.
//!
//! `run` resolves each configured stage against the static `PassRegistry`
//! and drives the pass lifecycle (prepare → calibrate → apply → report)
//! over one shared [`PassContext`], threading the mutated model from stage
//! to stage and accumulating a structured per-stage [`PipelineReport`].
//! There is no per-algorithm dispatch here: adding a pass to the registry
//! is all it takes to make it runnable, listable, and validatable.

use crate::config::SlimConfig;
use anyhow::{Context, Result};

use super::factories::SlimFactory;
use super::pass::{PassContext, PipelineReport};
use super::registry::PassRegistry;

pub struct CompressEngine {
    pub cfg: SlimConfig,
}

impl CompressEngine {
    pub fn new(cfg: SlimConfig) -> Result<Self> {
        SlimFactory::validate(&cfg)?;
        Ok(CompressEngine { cfg })
    }

    pub fn from_file(path: &str) -> Result<Self> {
        Self::new(SlimConfig::from_file(path)?)
    }

    /// Run the configured pipeline and return the per-stage report.
    pub fn run(&self) -> Result<PipelineReport> {
        self.run_with_context().map(|(report, _)| report)
    }

    /// Run the pipeline, also returning the final context (mutated model,
    /// calibration cache, baseline metric) — the hook equivalence tests
    /// and downstream tooling use to inspect the produced model.
    pub fn run_with_context(&self) -> Result<(PipelineReport, PassContext)> {
        let mut ctx = PassContext::new(self.cfg.clone());
        for (i, spec) in self.cfg.pipeline.iter().enumerate() {
            let pass = PassRegistry::find(&spec.pass).with_context(|| {
                format!(
                    "pipeline stage {i}: unknown pass `{}` (registered: {:?})",
                    spec.pass,
                    PassRegistry::names()
                )
            })?;
            let stage_err = |what: &str| format!("stage {i} (`{}`): {what}", spec.pass);
            pass.prepare(&mut ctx, spec).with_context(|| stage_err("prepare"))?;
            let t0 = std::time::Instant::now();
            pass.calibrate(&mut ctx, spec).with_context(|| stage_err("calibrate"))?;
            let outcome = pass.apply(&mut ctx, spec).with_context(|| stage_err("apply"))?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            ctx.reports.push(pass.report(outcome, wall_ms));
        }
        let report = PipelineReport { stages: ctx.reports.clone() };
        Ok((report, ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pass::StageReport;

    /// Hermetic engine over the in-memory fixture model + its rule corpus:
    /// no artifacts/ required, so these run on a clean checkout.
    fn engine(method: &str, algo: &str, extra: &str) -> CompressEngine {
        let src = format!(
            "global:\n  save_path: target/test-output/engine\nmodel:\n  name: tiny-fixture\n\
             compression:\n  method: {method}\n  {method}:\n    algo: {algo}\n{extra}\
             dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n"
        );
        CompressEngine::new(SlimConfig::from_str(&src).unwrap()).unwrap()
    }

    /// One-stage runs: the single stage of the desugared legacy config.
    fn run_one(method: &str, algo: &str, extra: &str) -> StageReport {
        let r = engine(method, algo, extra).run().unwrap();
        assert_eq!(r.stages.len(), 1, "legacy config desugars to one stage");
        r.stages.into_iter().next().unwrap()
    }

    #[test]
    fn int8_job_near_lossless() {
        let r = run_one("quantization", "int8", "");
        assert!(r.metric_after < r.metric_before + 0.05, "{r:?}");
        assert_eq!(r.kind, "quantization");
        assert!((r.size_ratio - 0.25).abs() < 1e-12, "8/32 bits: {r:?}");
        assert!(r.wall_ms >= 0.0);
    }

    #[test]
    fn ternary_ptq_job_degrades_vs_int4() {
        // the paper-shaped PTQ ladder: sub-2-bit PTQ visibly collapses
        // while int4 stays close to the fp32 reference
        let int4 = run_one("quantization", "int4", "");
        let tern = run_one("quantization", "ternary", "");
        assert!(
            tern.metric_after > int4.metric_after + 0.2,
            "{tern:?} vs {int4:?}"
        );
        assert!(int4.metric_after < int4.metric_before + 0.6, "{int4:?}");
    }

    #[test]
    fn low_memory_budget_bounds_peak() {
        let full = run_one("quantization", "gptq", "    low_memory_budget_layers: 0\n");
        let lo = run_one("quantization", "gptq", "    low_memory_budget_layers: 1\n");
        assert!(lo.peak_calib_bytes < full.peak_calib_bytes, "{lo:?} vs {full:?}");
        // accuracy unaffected by streaming
        assert!((lo.metric_after - full.metric_after).abs() < 1e-6);
    }

    #[test]
    fn sparse_attn_job_runs() {
        let r = run_one("sparse_attn", "stem", "    ratio: 0.3\n");
        assert!(r.compression < 0.95, "{r:?}");
        assert!(r.metric_after >= 0.0);
        // one scored note per long-context task family, incl. the needle task
        assert_eq!(r.notes.len(), crate::data::LongCtxTaskKind::all().len(), "{r:?}");
        assert!(r.notes.iter().any(|n| n.starts_with("SYN:")), "{r:?}");
    }

    #[test]
    fn token_prune_job_runs() {
        let r = run_one("token_prune", "idpruner", "    ratio: 0.25\n");
        assert!(r.metric_after > 0.3, "{r:?}");
        assert_eq!(r.kind, "token_prune");
    }

    #[test]
    fn spec_decode_stage_refuses_compress_loop() {
        let err = engine("spec_decode", "eagle3", "").run().unwrap_err();
        assert!(format!("{err:#}").contains("serving engine"), "{err:#}");
    }

    #[test]
    fn multi_stage_pipeline_threads_the_model_through() {
        let src = "global:\n  save_path: target/test-output/engine\n\
                   model:\n  name: tiny-fixture\n\
                   pipeline:\n  - smooth\n  - int4\n  - eval\n\
                   dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n";
        let engine = CompressEngine::new(SlimConfig::from_str(src).unwrap()).unwrap();
        let (report, ctx) = engine.run_with_context().unwrap();
        assert_eq!(report.stages.len(), 3);
        let [smooth, int4, eval] = &report.stages[..] else { unreachable!() };
        // smooth is function-preserving: NLL moves only by float rounding
        assert!((smooth.metric_after - smooth.metric_before).abs() < 0.05, "{smooth:?}");
        assert!((smooth.size_ratio - 1.0).abs() < 1e-12);
        // int4 sees the *smoothed* model: its before == the pipeline state
        // (two deterministic evals of the same weights — exactly equal)
        assert_eq!(int4.metric_before.to_bits(), smooth.metric_after.to_bits(), "{int4:?}");
        // the eval checkpoint reports final-vs-baseline
        assert_eq!(eval.kind, "eval");
        assert_eq!(eval.metric_before.to_bits(), ctx.baseline_nll.unwrap().to_bits());
        assert_eq!(eval.metric_after.to_bits(), int4.metric_after.to_bits(), "{eval:?}");
        assert!((report.overall_size_ratio() - 5.0 / 32.0).abs() < 1e-12);
        // the context surrenders the quantized model
        assert!(ctx.into_model().is_some());
    }

    #[test]
    fn eval_only_pipeline_scores_the_pristine_model() {
        let src = "global:\n  save_path: target/test-output/engine\n\
                   model:\n  name: tiny-fixture\n\
                   pipeline:\n  - eval\n\
                   dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n";
        let r = CompressEngine::new(SlimConfig::from_str(src).unwrap())
            .unwrap()
            .run()
            .unwrap();
        let s = &r.stages[0];
        assert!(s.metric_after < 1.0, "fixture encodes its rule: {s:?}");
        assert_eq!(s.metric_before.to_bits(), s.metric_after.to_bits());
    }
}
