//! CompressEngine: prepare → calibrate → compress → save → eval.

use crate::config::SlimConfig;
use crate::eval;
use crate::models::Transformer;
use crate::quant::{
    self, awq::Awq, gptq::Gptq, leptoquant::LeptoQuant, AffineQuantizer, Granularity,
    Seq2Quantizer, TernaryQuantizer,
};
use crate::sparse_attn::SparseAlgo;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

use super::factories::{DataFactory, Datasets, ModelFactory, SlimFactory};

#[derive(Clone, Debug, Default)]
pub struct CompressReport {
    pub method: String,
    pub algo: String,
    /// quantization: NLL before/after; sparse/prune: accuracy dense/sparse
    pub metric_before: f64,
    pub metric_after: f64,
    /// effective bits per weight (quantization) or kept density
    pub compression: f64,
    pub notes: Vec<String>,
    /// peak resident bytes during calibration (low-memory mode)
    pub peak_calib_bytes: usize,
}

pub struct CompressEngine {
    pub cfg: SlimConfig,
}

impl CompressEngine {
    pub fn new(cfg: SlimConfig) -> Result<Self> {
        SlimFactory::validate(&cfg)?;
        Ok(CompressEngine { cfg })
    }

    pub fn from_file(path: &str) -> Result<Self> {
        Self::new(SlimConfig::from_file(path)?)
    }

    pub fn run(&self) -> Result<CompressReport> {
        match self.cfg.compression.method.as_str() {
            "quantization" => self.run_quantization(),
            "sparse_attn" => self.run_sparse_attn(),
            "token_prune" => self.run_token_prune(),
            "spec_decode" => bail!(
                "spec_decode jobs run through the serving engine — use \
                 `angelslim serve` or examples/serve_spec_decode"
            ),
            other => bail!("unknown method {other}"),
        }
    }

    // ------------------------------------------------------------------
    // quantization jobs
    // ------------------------------------------------------------------

    fn run_quantization(&self) -> Result<CompressReport> {
        let mut model = ModelFactory::load(&self.cfg)?;
        let ds = DataFactory::load(&self.cfg)?;
        let algo = self.cfg.compression.algo.as_str();

        let before = eval::corpus_nll(&model, &ds.eval, 48, 8)?;
        let mut notes = Vec::new();
        let mut peak = 0usize;

        let bits: f64 = match algo {
            "int8" => {
                model.apply_quantizer(&AffineQuantizer::int8_per_channel());
                8.0
            }
            "int4" => {
                model.apply_quantizer(&AffineQuantizer::int4_group32());
                5.0
            }
            "seq2" => {
                model.apply_quantizer(&Seq2Quantizer::tuned(32));
                3.0
            }
            "ternary" => {
                model.apply_quantizer(&TernaryQuantizer::default());
                1.67
            }
            "fp8_dynamic" | "w4a8" => {
                // weight-side QDQ (activation QDQ is a runtime concern)
                if algo == "w4a8" {
                    model.apply_quantizer(&AffineQuantizer::new(
                        4,
                        Granularity::Group(self.cfg.compression.group_size.max(32)),
                    ));
                    4.25
                } else {
                    model.apply_quantizer(&quant::Fp8WeightQuantizer);
                    8.0
                }
            }
            "gptq" | "awq" | "fp8_lepto" | "leptoquant" => {
                peak = self.calibrated_quantization(&mut model, &ds, algo, &mut notes)?;
                match algo {
                    "gptq" | "awq" => 5.0,
                    _ => 8.0,
                }
            }
            other => bail!("unhandled quant algo {other}"),
        };

        let after = eval::corpus_nll(&model, &ds.eval, 48, 8)?;
        self.save_note(&mut notes)?;
        Ok(CompressReport {
            method: "quantization".into(),
            algo: algo.into(),
            metric_before: before,
            metric_after: after,
            compression: bits,
            notes,
            peak_calib_bytes: peak,
        })
    }

    /// GPTQ / AWQ / LeptoQuant need calibration activations; layers are
    /// streamed under the low-memory ledger when a budget is configured.
    fn calibrated_quantization(
        &self,
        model: &mut Transformer,
        ds: &Datasets,
        algo: &str,
        notes: &mut Vec<String>,
    ) -> Result<usize> {
        // capture per-layer activations over the calibration set
        let mut attn_in: Vec<Vec<f32>> = vec![Vec::new(); model.cfg.n_layers];
        let mut mlp_in: Vec<Vec<f32>> = vec![Vec::new(); model.cfg.n_layers];
        for seq in ds.calib.iter().take(8) {
            let caps = model.capture_activations(seq);
            for (li, cap) in caps.iter().enumerate() {
                attn_in[li].extend_from_slice(&cap.attn_in.data);
                mlp_in[li].extend_from_slice(&cap.mlp_in.data);
            }
        }
        let d = model.cfg.d_model;

        // low-memory ledger: one entry per layer, sized by parameter bytes
        let layer_bytes: Vec<usize> = model
            .layers
            .iter()
            .map(|l| {
                4 * (l.wq.numel()
                    + l.wk.numel()
                    + l.wv.numel()
                    + l.wo.numel()
                    + l.w_gate.numel()
                    + l.w_up.numel()
                    + l.w_down.numel())
            })
            .collect();
        let mut ledger = quant::calib::LowMemoryLedger::new(
            layer_bytes,
            self.cfg.compression.low_memory_budget_layers,
        );

        for li in 0..model.cfg.n_layers {
            ledger.touch(li);
            let rows_a = attn_in[li].len() / d;
            let xa = Tensor::from_vec(&[rows_a, d], attn_in[li].clone());
            let rows_m = mlp_in[li].len() / d;
            let xm = Tensor::from_vec(&[rows_m, d], mlp_in[li].clone());
            match algo {
                "gptq" => {
                    let g = Gptq::default();
                    let wq = g.quantize(&model.layers[li].wq.clone(), &xa);
                    model.set_layer_weight(li, "wq", wq);
                    let wg = g.quantize(&model.layers[li].w_gate.clone(), &xm);
                    model.set_layer_weight(li, "w_gate", wg);
                    let wu = g.quantize(&model.layers[li].w_up.clone(), &xm);
                    model.set_layer_weight(li, "w_up", wu);
                }
                "awq" => {
                    let a = Awq::default();
                    let r = a.quantize(&model.layers[li].w_gate.clone(), &xm);
                    notes.push(format!("layer{li} w_gate awq alpha={}", r.best_alpha));
                    model.set_layer_weight(li, "w_gate", r.weights);
                    let r = a.quantize(&model.layers[li].w_up.clone(), &xm);
                    model.set_layer_weight(li, "w_up", r.weights);
                }
                "fp8_lepto" | "leptoquant" => {
                    let lq = LeptoQuant {
                        alpha_grid: self.cfg.compression.alpha_grid.clone(),
                        ..Default::default()
                    };
                    let res = lq.search(&xm, &model.layers[li].w_gate.clone());
                    notes.push(format!(
                        "layer{li} lepto alpha={} mse {:.3e} -> {:.3e}",
                        res.best_alpha, res.mse_traditional, res.mse_best
                    ));
                    // deploy: weight QDQ at fp8 (activation scale is a
                    // runtime parameter recorded in the notes)
                    for which in ["w_gate", "w_up"] {
                        let mut w = match which {
                            "w_gate" => model.layers[li].w_gate.clone(),
                            _ => model.layers[li].w_up.clone(),
                        };
                        quant::fp8::qdq_slice_scaled(&mut w.data, quant::Fp8Format::E4M3);
                        model.set_layer_weight(li, which, w);
                    }
                }
                _ => unreachable!(),
            }
        }
        notes.push(format!(
            "calibration peak {} / total {} bytes (budget {} layers), {} swaps",
            ledger.peak_bytes,
            ledger.total_bytes(),
            self.cfg.compression.low_memory_budget_layers,
            ledger.swaps
        ));
        Ok(ledger.peak_bytes)
    }

    // ------------------------------------------------------------------
    // sparse attention + token pruning jobs
    // ------------------------------------------------------------------

    fn run_sparse_attn(&self) -> Result<CompressReport> {
        let model = ModelFactory::load(&self.cfg)?;
        let algo = match self.cfg.compression.algo.as_str() {
            "dense" => SparseAlgo::Dense,
            "a_shape" => SparseAlgo::AShape,
            "tri_shape" => SparseAlgo::TriShape,
            "dilated" => SparseAlgo::Dilated,
            "strided" => SparseAlgo::Strided,
            "minference" => SparseAlgo::MInference,
            "xattention" => SparseAlgo::XAttention,
            "flexprefill" => SparseAlgo::FlexPrefill,
            "stem" => SparseAlgo::Stem,
            other => bail!("unknown sparse algo {other}"),
        };
        let seq = self.cfg.dataset.seq_len.min(model.cfg.max_t - 8);
        let dense = eval::eval_sparse_accuracy(&model, SparseAlgo::Dense, seq, 4, 8, 1.0);
        let row = eval::eval_sparse_accuracy(
            &model,
            algo,
            seq,
            4,
            8, // finer blocks keep short configs meaningfully sparse
            self.cfg.compression.ratio,
        );
        Ok(CompressReport {
            method: "sparse_attn".into(),
            algo: self.cfg.compression.algo.clone(),
            metric_before: dense.avg,
            metric_after: row.avg,
            compression: row.mean_density,
            notes: row
                .per_task
                .iter()
                .map(|(k, a)| format!("{}: {:.3}", k.name(), a))
                .collect(),
            peak_calib_bytes: 0,
        })
    }

    fn run_token_prune(&self) -> Result<CompressReport> {
        use crate::token_prune::visual;
        let algo = self.cfg.compression.algo.as_str();
        let gen = crate::data::VisionSceneGen::new(96, 24, 6, self.cfg.global.seed);
        let pruner: Box<dyn crate::token_prune::Pruner> = match algo {
            "idpruner" => Box::new(visual::IdPruner::default()),
            "fastv" => Box::new(visual::FastV),
            "divprune" => Box::new(visual::DivPrune),
            "visionzip" => Box::new(visual::VisionZip),
            "dart" => Box::new(visual::Dart),
            "vispruner" => Box::new(visual::VisPruner),
            "scope" => Box::new(visual::Scope),
            "visionselector" => Box::new(visual::VisionSelector),
            "hiprune" => Box::new(visual::HiPrune),
            // audio algos run through the ASR evaluator instead
            "samp" | "atome" | "fastadasp" | "cdpruner" => {
                return self.run_audio_prune(algo);
            }
            other => bail!("unknown pruner {other}"),
        };
        let n = 40;
        let base = eval::vqa::baseline_accuracy(&gen, n);
        let acc = eval::eval_pruner_accuracy(&gen, pruner.as_ref(), self.cfg.compression.ratio, n);
        Ok(CompressReport {
            method: "token_prune".into(),
            algo: algo.into(),
            metric_before: base,
            metric_after: acc,
            compression: self.cfg.compression.ratio,
            notes: vec![],
            peak_calib_bytes: 0,
        })
    }

    fn run_audio_prune(&self, algo: &str) -> Result<CompressReport> {
        use crate::token_prune::audio;
        let gen = crate::data::AudioSceneGen::new(24, 24, 0.1, self.cfg.global.seed);
        let reducer: Box<dyn crate::token_prune::Reducer> = match algo {
            "samp" => Box::new(audio::Samp::default()),
            "atome" => Box::new(audio::AToMe),
            "fastadasp" => Box::new(audio::FastAdaSp),
            "cdpruner" => Box::new(audio::CdPruner),
            other => bail!("unknown audio reducer {other}"),
        };
        let base = eval::asr::baseline_wer(&gen, 15, 150);
        let w = eval::eval_wer(&gen, reducer.as_ref(), self.cfg.compression.ratio, 15, 150);
        Ok(CompressReport {
            method: "token_prune(audio)".into(),
            algo: algo.into(),
            metric_before: base,
            metric_after: w,
            compression: self.cfg.compression.ratio,
            notes: vec!["metric is WER% (lower is better)".into()],
            peak_calib_bytes: 0,
        })
    }

    fn save_note(&self, notes: &mut Vec<String>) -> Result<()> {
        let dir = &self.cfg.global.save_path;
        std::fs::create_dir_all(dir)?;
        let marker = format!("{dir}/compressed_{}.txt", self.cfg.compression.algo);
        std::fs::write(&marker, format!("{:#?}", self.cfg))?;
        notes.push(format!("checkpoint note saved to {marker}"));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hermetic engine over the in-memory fixture model + its rule corpus:
    /// no artifacts/ required, so these run on a clean checkout.
    fn engine(method: &str, algo: &str, extra: &str) -> CompressEngine {
        let src = format!(
            "global:\n  save_path: target/test-output/engine\nmodel:\n  name: tiny-fixture\n\
             compression:\n  method: {method}\n  {method}:\n    algo: {algo}\n{extra}\
             dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n"
        );
        CompressEngine::new(SlimConfig::from_str(&src).unwrap()).unwrap()
    }

    #[test]
    fn int8_job_near_lossless() {
        let r = engine("quantization", "int8", "").run().unwrap();
        assert!(r.metric_after < r.metric_before + 0.05, "{r:?}");
    }

    #[test]
    fn ternary_ptq_job_degrades_vs_int4() {
        // the paper-shaped PTQ ladder: sub-2-bit PTQ visibly collapses
        // while int4 stays close to the fp32 reference
        let int4 = engine("quantization", "int4", "").run().unwrap();
        let tern = engine("quantization", "ternary", "").run().unwrap();
        assert!(
            tern.metric_after > int4.metric_after + 0.2,
            "{tern:?} vs {int4:?}"
        );
        assert!(int4.metric_after < int4.metric_before + 0.6, "{int4:?}");
    }

    #[test]
    fn low_memory_budget_bounds_peak() {
        let full = engine("quantization", "gptq", "    low_memory_budget_layers: 0\n")
            .run()
            .unwrap();
        let lo = engine("quantization", "gptq", "    low_memory_budget_layers: 1\n")
            .run()
            .unwrap();
        assert!(lo.peak_calib_bytes < full.peak_calib_bytes, "{lo:?} vs {full:?}");
        // accuracy unaffected by streaming
        assert!((lo.metric_after - full.metric_after).abs() < 1e-6);
    }

    #[test]
    fn sparse_attn_job_runs() {
        let r = engine("sparse_attn", "stem", "    ratio: 0.3\n").run().unwrap();
        assert!(r.compression < 0.95, "{r:?}");
        assert!(r.metric_after >= 0.0);
        // one scored note per long-context task family, incl. the needle task
        assert_eq!(r.notes.len(), crate::data::LongCtxTaskKind::all().len(), "{r:?}");
        assert!(r.notes.iter().any(|n| n.starts_with("SYN:")), "{r:?}");
    }

    #[test]
    fn token_prune_job_runs() {
        let r = engine("token_prune", "idpruner", "    ratio: 0.25\n").run().unwrap();
        assert!(r.metric_after > 0.3, "{r:?}");
    }
}
