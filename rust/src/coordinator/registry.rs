//! The single static `PassRegistry` — every compression algorithm wrapped
//! as a [`CompressionPass`] and registered exactly once.
//!
//! This is the only place algorithm names are bound to dispatch targets:
//! `CompressEngine` resolves pipeline stages here, `SlimFactory`
//! (`registered`/`validate`), `angelslim list`, and config-schema
//! validation all render from this table, so the CLI listing can never
//! drift from what the engine actually runs.

use crate::config::{CompressionCfg, StageCfg};
use crate::eval;
use crate::models::packed_store;
use crate::quant::packing::PackFormat;
use crate::quant::{
    self, awq::Awq, gptq::Gptq, leptoquant::LeptoQuant, smooth::SmoothQuant, AffineQuantizer,
    Granularity, Seq2Quantizer, Sherry, Tequila, TernaryQuantizer, WeightQuantizer,
};
use crate::sparse_attn::SparseAlgo;
use crate::tensor::Tensor;
use crate::token_prune::{audio, visual, Pruner, Reducer};
use crate::util::Selector;
use anyhow::{bail, Context, Result};

use super::pass::{save_marker, CompressionPass, PassContext, PassKind, StageOutcome};

/// The static pass registry. All lookups are by registry name (the string
/// configs dispatch on).
pub struct PassRegistry;

impl PassRegistry {
    pub fn all() -> &'static [&'static (dyn CompressionPass + Sync)] {
        REGISTRY
    }

    pub fn find(name: &str) -> Option<&'static (dyn CompressionPass + Sync)> {
        REGISTRY.iter().copied().find(|p| p.name() == name)
    }

    pub fn names() -> Vec<&'static str> {
        REGISTRY.iter().map(|p| p.name()).collect()
    }

    pub fn names_for(kind: PassKind) -> Vec<&'static str> {
        REGISTRY
            .iter()
            .filter(|p| p.kind() == kind)
            .map(|p| p.name())
            .collect()
    }

    /// Registry grouped by method family — what `SlimFactory::registered`
    /// and `angelslim list` render.
    pub fn by_method() -> Vec<(&'static str, Vec<&'static str>)> {
        PassKind::all()
            .into_iter()
            .map(|k| (k.method(), Self::names_for(k)))
            .collect()
    }
}

// ---------------------------------------------------------------------
// quantization passes
// ---------------------------------------------------------------------

/// Calibration-free weight QDQ (round-to-nearest family): the quantizer is
/// built from the stage params and applied to every linear.
struct RtnQuantPass {
    name: &'static str,
    describe: &'static str,
    /// stored-size override in bits/weight for formats whose packed
    /// storage differs from `WeightQuantizer::bits()` (ternary's 3-in-5
    /// codec); `None` derives bits from the constructed quantizer, so
    /// per-stage overrides (w4a8 `group_size`) stay in lockstep with the
    /// reported compression
    stored_bits: Option<f64>,
    /// every quantized matrix dimension must divide this (Sherry's 4-lane
    /// blocks); checked loudly in `prepare`
    k_multiple: usize,
    /// pass consumes `group_size` (w4a8): `prepare` then requires the
    /// group to evenly tile every quantized row (k ∈ {d_model, d_ff}),
    /// turning a would-be kernel assert into a loud config error
    group_wired: bool,
    /// caveat recorded in the stage report notes (empty = none)
    caveat: &'static str,
    make: fn(&CompressionCfg) -> Box<dyn WeightQuantizer>,
}

fn mk_fp8(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(quant::Fp8WeightQuantizer)
}
fn mk_int8(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(AffineQuantizer::int8_per_channel())
}
fn mk_int4(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(AffineQuantizer::int4_group32())
}
fn mk_w4a8(p: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    // weight-side QDQ (activation QDQ is a runtime concern); the group is
    // honored verbatim — `prepare` has already rejected non-tiling values
    Box::new(AffineQuantizer::new(4, Granularity::Group(p.group_size)))
}
fn mk_seq2(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(Seq2Quantizer::tuned(32))
}
fn mk_ternary(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(TernaryQuantizer::default())
}
fn mk_tequila(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(Tequila::default())
}
fn mk_sherry(_: &CompressionCfg) -> Box<dyn WeightQuantizer> {
    Box::new(Sherry)
}

impl CompressionPass for RtnQuantPass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        self.describe
    }

    fn prepare(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<()> {
        if self.k_multiple > 1 {
            let cfg = ctx.model()?.cfg;
            if cfg.d_model % self.k_multiple != 0 || cfg.d_ff % self.k_multiple != 0 {
                bail!(
                    "pass `{}` needs weight dims divisible by {} (model has d_model={} d_ff={})",
                    self.name,
                    self.k_multiple,
                    cfg.d_model,
                    cfg.d_ff
                );
            }
        }
        if self.group_wired {
            let cfg = ctx.model()?.cfg;
            let g = spec.params.group_size;
            if g == 0 || cfg.d_model % g != 0 || cfg.d_ff % g != 0 {
                bail!(
                    "pass `{}`: group_size {g} must be a nonzero divisor of both \
                     d_model {} and d_ff {}",
                    self.name,
                    cfg.d_model,
                    cfg.d_ff
                );
            }
        }
        Ok(())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let q = (self.make)(&spec.params);
        let bits = self.stored_bits.unwrap_or_else(|| q.bits());
        ctx.model()?.apply_quantizer(q.as_ref());
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        let mut notes = Vec::new();
        if !self.caveat.is_empty() {
            notes.push(self.caveat.to_string());
        }
        save_marker(&ctx.cfg, self.name, &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            compression: bits,
            notes,
            peak_calib_bytes: 0,
        })
    }
}

/// Run the per-layer calibrated write-back loop shared by GPTQ / AWQ /
/// LeptoQuant: streams layers under the low-memory ledger and hands each
/// layer's captured activations to the algorithm closure.
fn with_calibrated_layers(
    ctx: &mut PassContext,
    spec: &StageCfg,
    notes: &mut Vec<String>,
    f: &mut dyn FnMut(usize, &Tensor, &Tensor, &mut crate::models::Transformer, &mut Vec<String>),
) -> Result<usize> {
    let budget = spec.params.low_memory_budget_layers;
    // borrow the capture in place (no clone — peak memory stays one
    // calibration set, which is what the low-memory ledger accounts for)
    ctx.with_calib(|ctx, capture| {
        let model = ctx.model()?;

        // low-memory ledger: one entry per layer, sized by parameter bytes
        let layer_bytes: Vec<usize> = model
            .layers
            .iter()
            .map(|l| {
                4 * (l.wq.numel()
                    + l.wk.numel()
                    + l.wv.numel()
                    + l.wo.numel()
                    + l.w_gate.numel()
                    + l.w_up.numel()
                    + l.w_down.numel())
            })
            .collect();
        let mut ledger = quant::calib::LowMemoryLedger::new(layer_bytes, budget);

        for li in 0..model.cfg.n_layers {
            ledger.touch(li);
            f(li, &capture.attn_in[li], &capture.mlp_in[li], model, notes);
        }
        notes.push(format!(
            "calibration peak {} / total {} bytes (budget {} layers), {} swaps",
            ledger.peak_bytes,
            ledger.total_bytes(),
            budget,
            ledger.swaps
        ));
        Ok(ledger.peak_bytes)
    })
}

/// Calibrated group-wise quantizers consume `group_size` per-stage; the
/// group must evenly tile every quantized row (all have k = d_model), so
/// a non-divisor is a loud `prepare` error instead of a silent ignore.
fn ensure_group_divides_d_model(ctx: &mut PassContext, spec: &StageCfg, pass: &str) -> Result<()> {
    let d = ctx.model()?.cfg.d_model;
    let g = spec.params.group_size;
    if g == 0 || d % g != 0 {
        bail!("pass `{pass}`: group_size {g} must be a nonzero divisor of d_model {d}");
    }
    Ok(())
}

struct GptqPass;

impl CompressionPass for GptqPass {
    fn name(&self) -> &'static str {
        "gptq"
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        "layer-wise Hessian-aware reconstruction (calibrated int4; group_size wired)"
    }

    fn prepare(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<()> {
        ensure_group_divides_d_model(ctx, spec, self.name())
    }

    fn calibrate(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        ctx.calib().map(|_| ())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let mut notes = Vec::new();
        let g = Gptq { group: spec.params.group_size, ..Default::default() };
        let peak = with_calibrated_layers(ctx, spec, &mut notes, &mut |li, xa, xm, model, _| {
            let wq = g.quantize(model.layers[li].wq.f32(), xa);
            model.set_layer_weight(li, "wq", wq);
            let wg = g.quantize(model.layers[li].w_gate.f32(), xm);
            model.set_layer_weight(li, "w_gate", wg);
            let wu = g.quantize(model.layers[li].w_up.f32(), xm);
            model.set_layer_weight(li, "w_up", wu);
        })?;
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        save_marker(&ctx.cfg, self.name(), &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            // int4 weights + one f32 scale per group (tracks group_size)
            compression: 4.0 + 32.0 / spec.params.group_size as f64,
            notes,
            peak_calib_bytes: peak,
        })
    }
}

struct AwqPass;

impl CompressionPass for AwqPass {
    fn name(&self) -> &'static str {
        "awq"
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        "activation-aware weight scaling (calibrated int4; group_size wired)"
    }

    fn prepare(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<()> {
        ensure_group_divides_d_model(ctx, spec, self.name())
    }

    fn calibrate(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        ctx.calib().map(|_| ())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let mut notes = Vec::new();
        let a = Awq { group: spec.params.group_size, ..Default::default() };
        let peak = with_calibrated_layers(
            ctx,
            spec,
            &mut notes,
            &mut |li, _xa, xm, model, notes| {
                let r = a.quantize(model.layers[li].w_gate.f32(), xm);
                notes.push(format!("layer{li} w_gate awq alpha={}", r.best_alpha));
                model.set_layer_weight(li, "w_gate", r.weights);
                let r = a.quantize(model.layers[li].w_up.f32(), xm);
                model.set_layer_weight(li, "w_up", r.weights);
            },
        )?;
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        save_marker(&ctx.cfg, self.name(), &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            // int4 weights + one f32 scale per group (tracks group_size)
            compression: 4.0 + 32.0 / spec.params.group_size as f64,
            notes,
            peak_calib_bytes: peak,
        })
    }
}

/// LeptoQuant outlier-isolation FP8 — registered under both the paper's
/// `fp8_lepto` deployment name and the plain `leptoquant` alias.
struct LeptoPass {
    name: &'static str,
}

impl CompressionPass for LeptoPass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        "LeptoQuant outlier-isolation alpha search + fp8 weight QDQ"
    }

    fn calibrate(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        ctx.calib().map(|_| ())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let mut notes = Vec::new();
        let alpha_grid = spec.params.alpha_grid.clone();
        let peak = with_calibrated_layers(
            ctx,
            spec,
            &mut notes,
            &mut |li, _xa, xm, model, notes| {
                let lq = LeptoQuant { alpha_grid: alpha_grid.clone(), ..Default::default() };
                let res = lq.search(xm, model.layers[li].w_gate.f32());
                notes.push(format!(
                    "layer{li} lepto alpha={} mse {:.3e} -> {:.3e}",
                    res.best_alpha, res.mse_traditional, res.mse_best
                ));
                // deploy: weight QDQ at fp8 (activation scale is a runtime
                // parameter recorded in the notes)
                for which in ["w_gate", "w_up"] {
                    let mut w = match which {
                        "w_gate" => model.layers[li].w_gate.f32().clone(),
                        _ => model.layers[li].w_up.f32().clone(),
                    };
                    quant::fp8::qdq_slice_scaled(&mut w.data, quant::Fp8Format::E4M3);
                    model.set_layer_weight(li, which, w);
                }
            },
        )?;
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        save_marker(&ctx.cfg, self.name, &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            compression: 8.0,
            notes,
            peak_calib_bytes: peak,
        })
    }
}

/// SmoothQuant-style outlier migration folded into the RMSNorm gains —
/// function-preserving (up to float rounding), so it composes in front of
/// any weight quantizer (the paper's smooth → GPTQ recipe).
struct SmoothPass;

impl SmoothPass {
    /// Fold migration scales: gain_c /= s_c, and column c of every
    /// consumer weight *= s_c. The normed-input × weight products are
    /// mathematically unchanged.
    fn fold(gain: &mut [f32], ws: &mut [&mut Tensor], s: &[f32]) -> f32 {
        for (g, sc) in gain.iter_mut().zip(s) {
            *g /= sc;
        }
        for w in ws.iter_mut() {
            for r in 0..w.rows() {
                let row = w.row_mut(r);
                for (c, sc) in s.iter().enumerate() {
                    row[c] *= sc;
                }
            }
        }
        s.iter().fold(0.0f32, |m, &v| m.max(v))
    }
}

impl CompressionPass for SmoothPass {
    fn name(&self) -> &'static str {
        "smooth"
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        "SmoothQuant activation-outlier migration into RMSNorm gains (lossless)"
    }

    fn calibrate(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<()> {
        ctx.calib().map(|_| ())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let alpha = spec.params.smooth_alpha as f32;
        let sq = SmoothQuant { alpha };
        let mut notes = Vec::new();
        ctx.with_calib(|ctx, capture| {
            let model = ctx.model()?;
            for li in 0..model.cfg.n_layers {
                let l = &mut model.layers[li];
                let s_attn = sq
                    .shared_scales(&capture.attn_in[li], &[l.wq.f32(), l.wk.f32(), l.wv.f32()]);
                let attn_max = Self::fold(
                    &mut l.ln1,
                    &mut [l.wq.f32_mut(), l.wk.f32_mut(), l.wv.f32_mut()],
                    &s_attn,
                );
                let s_mlp =
                    sq.shared_scales(&capture.mlp_in[li], &[l.w_gate.f32(), l.w_up.f32()]);
                let mlp_max = Self::fold(
                    &mut l.ln2,
                    &mut [l.w_gate.f32_mut(), l.w_up.f32_mut()],
                    &s_mlp,
                );
                notes.push(format!(
                    "layer{li} smooth alpha={alpha} s_max attn={attn_max:.3} mlp={mlp_max:.3}"
                ));
            }
            Ok(())
        })?;
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        save_marker(&ctx.cfg, self.name(), &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            compression: 32.0, // migration only — no storage change
            notes,
            peak_calib_bytes: 0,
        })
    }
}

/// The quantized execution bridge: quantize + pack selected layers into a
/// `PackedLinear` storage format, so the decode hot path runs the packed
/// LUT GEMV kernels instead of dequantized f32. Layer selection is the
/// DynamicDiT-style include/exclude pattern API (substrings or regexes,
/// auto-detected); repeated `pack` stages with disjoint selectors give
/// per-layer mixed precision.
struct PackPass;

impl PackPass {
    fn resolve(spec: &StageCfg) -> Result<(PackFormat, Selector)> {
        let p = &spec.params;
        let fmt = PackFormat::parse(&p.format).with_context(|| {
            format!("pass `pack`: unknown format `{}`", p.format)
        })?;
        if !matches!(
            fmt,
            PackFormat::Int4 | PackFormat::TwoBit | PackFormat::Ternary167 | PackFormat::Sherry125
        ) {
            bail!(
                "pass `pack`: format `{}` has no packed execution kernel \
                 (use int4, 2bit, ternary167, or sherry125)",
                p.format
            );
        }
        let sel = Selector::new(&p.include, &p.exclude)
            .context("pass `pack`: bad include/exclude pattern")?;
        Ok((fmt, sel))
    }
}

impl CompressionPass for PackPass {
    fn name(&self) -> &'static str {
        "pack"
    }
    fn kind(&self) -> PassKind {
        PassKind::Quantization
    }
    fn describe(&self) -> &'static str {
        "quantize + pack selected layers for packed-kernel serving (format/include/exclude wired)"
    }

    fn prepare(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<()> {
        let (fmt, _) = Self::resolve(spec)?;
        let cfg = ctx.model()?.cfg;
        match fmt {
            PackFormat::Int4 => {
                let g = spec.params.group_size;
                if g == 0 || g % 2 != 0 || cfg.d_model % g != 0 || cfg.d_ff % g != 0 {
                    bail!(
                        "pass `pack`: int4 group_size {g} must be even and divide both \
                         d_model {} and d_ff {}",
                        cfg.d_model,
                        cfg.d_ff
                    );
                }
            }
            PackFormat::TwoBit | PackFormat::Sherry125 => {
                if cfg.d_model % 4 != 0 || cfg.d_ff % 4 != 0 {
                    bail!(
                        "pass `pack`: format `{}` needs weight dims divisible by 4 \
                         (model has d_model={} d_ff={})",
                        fmt.name(),
                        cfg.d_model,
                        cfg.d_ff
                    );
                }
            }
            _ => {}
        }
        Ok(())
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let before = ctx.nll()?;
        ctx.note_baseline(before);
        let (fmt, sel) = Self::resolve(spec)?;
        let (packed, total, bits) = {
            let model = ctx.model()?;
            let packed = model.pack_weights(&sel, fmt, spec.params.group_size)?;
            if packed == 0 {
                bail!("pass `pack`: include/exclude selected no weights");
            }
            // effective stored bits over ALL linears (unselected layers
            // stay f32 and are charged honestly)
            let bits =
                model.stored_weight_bytes() as f64 * 8.0 / model.linear_params() as f64;
            (packed, model.named_weights().len(), bits)
        };
        ctx.mark_model_mutated();
        let after = ctx.nll()?;
        let mut notes =
            vec![format!("packed {packed}/{total} linear weights as {}", fmt.name())];
        save_marker(&ctx.cfg, self.name(), &mut notes)?;
        Ok(StageOutcome {
            metric_before: before,
            metric_after: after,
            compression: bits,
            notes,
            peak_calib_bytes: 0,
        })
    }
}

/// Pipeline-level artifact export: serialize the current (possibly packed)
/// model under `global.save_path` so `angelslim serve` can load exactly
/// what `angelslim compress` produced. Registered under the eval family —
/// exporting never changes the stored-size accounting of the pipeline.
struct ExportPackedPass;

impl CompressionPass for ExportPackedPass {
    fn name(&self) -> &'static str {
        "export-packed"
    }
    fn kind(&self) -> PassKind {
        PassKind::Eval
    }
    fn describe(&self) -> &'static str {
        "serialize the packed model as a serve-loadable artifact under save_path"
    }

    fn apply(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<StageOutcome> {
        let nll = ctx.nll()?;
        ctx.note_baseline(nll);
        let dir = ctx.cfg.global.save_path.clone();
        let model = ctx.model()?;
        let bytes = packed_store::save_packed(model, &dir)?;
        let stored = model.stored_weight_bytes();
        Ok(StageOutcome {
            metric_before: ctx.baseline_nll.unwrap_or(nll),
            metric_after: nll,
            compression: 1.0,
            notes: vec![format!(
                "packed artifact: {bytes} bytes to {dir} ({stored} linear-weight bytes)"
            )],
            peak_calib_bytes: 0,
        })
    }
}

// ---------------------------------------------------------------------
// speculative-decoding passes (serving-path; compress pipelines reject)
// ---------------------------------------------------------------------

struct SpecDecodePass {
    name: &'static str,
    describe: &'static str,
}

impl CompressionPass for SpecDecodePass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::SpecDecode
    }
    fn describe(&self) -> &'static str {
        self.describe
    }

    fn apply(&self, _ctx: &mut PassContext, _spec: &StageCfg) -> Result<StageOutcome> {
        bail!(
            "spec_decode jobs run through the serving engine — use \
             `angelslim serve` or examples/serve_spec_decode"
        )
    }
}

// ---------------------------------------------------------------------
// sparse-attention passes
// ---------------------------------------------------------------------

struct SparseAttnPass {
    name: &'static str,
    describe: &'static str,
    algo: SparseAlgo,
}

impl CompressionPass for SparseAttnPass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::SparseAttn
    }
    fn describe(&self) -> &'static str {
        self.describe
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let seq_cap = ctx.cfg.dataset.seq_len;
        let ratio = spec.params.ratio;
        let model = ctx.model()?;
        let seq = seq_cap.min(model.cfg.max_t - 8);
        let dense = eval::eval_sparse_accuracy(model, SparseAlgo::Dense, seq, 4, 8, 1.0);
        // finer blocks keep short configs meaningfully sparse
        let row = eval::eval_sparse_accuracy(model, self.algo, seq, 4, 8, ratio);
        Ok(StageOutcome {
            metric_before: dense.avg,
            metric_after: row.avg,
            compression: row.mean_density,
            notes: row
                .per_task
                .iter()
                .map(|(k, a)| format!("{}: {:.3}", k.name(), a))
                .collect(),
            peak_calib_bytes: 0,
        })
    }
}

// ---------------------------------------------------------------------
// token-pruning passes (visual VQA-proxy / audio ASR-proxy)
// ---------------------------------------------------------------------

struct VisualPrunePass {
    name: &'static str,
    describe: &'static str,
    make: fn() -> Box<dyn Pruner>,
}

fn mk_idpruner() -> Box<dyn Pruner> {
    Box::new(visual::IdPruner::default())
}
fn mk_fastv() -> Box<dyn Pruner> {
    Box::new(visual::FastV)
}
fn mk_divprune() -> Box<dyn Pruner> {
    Box::new(visual::DivPrune)
}
fn mk_visionzip() -> Box<dyn Pruner> {
    Box::new(visual::VisionZip)
}
fn mk_dart() -> Box<dyn Pruner> {
    Box::new(visual::Dart)
}
fn mk_vispruner() -> Box<dyn Pruner> {
    Box::new(visual::VisPruner)
}
fn mk_scope() -> Box<dyn Pruner> {
    Box::new(visual::Scope)
}
fn mk_visionselector() -> Box<dyn Pruner> {
    Box::new(visual::VisionSelector)
}
fn mk_hiprune() -> Box<dyn Pruner> {
    Box::new(visual::HiPrune)
}

impl CompressionPass for VisualPrunePass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::TokenPrune
    }
    fn describe(&self) -> &'static str {
        self.describe
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let gen = crate::data::VisionSceneGen::new(96, 24, 6, ctx.cfg.global.seed);
        let pruner = (self.make)();
        let n = 40;
        let base = eval::vqa::baseline_accuracy(&gen, n);
        let acc = eval::eval_pruner_accuracy(&gen, pruner.as_ref(), spec.params.ratio, n);
        Ok(StageOutcome {
            metric_before: base,
            metric_after: acc,
            compression: spec.params.ratio,
            notes: vec![],
            peak_calib_bytes: 0,
        })
    }
}

struct AudioPrunePass {
    name: &'static str,
    describe: &'static str,
    make: fn() -> Box<dyn Reducer>,
}

fn mk_samp() -> Box<dyn Reducer> {
    Box::new(audio::Samp::default())
}
fn mk_atome() -> Box<dyn Reducer> {
    Box::new(audio::AToMe)
}
fn mk_fastadasp() -> Box<dyn Reducer> {
    Box::new(audio::FastAdaSp)
}
fn mk_cdpruner() -> Box<dyn Reducer> {
    Box::new(audio::CdPruner)
}

impl CompressionPass for AudioPrunePass {
    fn name(&self) -> &'static str {
        self.name
    }
    fn kind(&self) -> PassKind {
        PassKind::TokenPrune
    }
    fn describe(&self) -> &'static str {
        self.describe
    }

    fn apply(&self, ctx: &mut PassContext, spec: &StageCfg) -> Result<StageOutcome> {
        let gen = crate::data::AudioSceneGen::new(24, 24, 0.1, ctx.cfg.global.seed);
        let reducer = (self.make)();
        let base = eval::asr::baseline_wer(&gen, 15, 150);
        let w = eval::eval_wer(&gen, reducer.as_ref(), spec.params.ratio, 15, 150);
        Ok(StageOutcome {
            metric_before: base,
            metric_after: w,
            compression: spec.params.ratio,
            notes: vec!["metric is WER% (lower is better)".into()],
            peak_calib_bytes: 0,
        })
    }
}

// ---------------------------------------------------------------------
// evaluation checkpoint
// ---------------------------------------------------------------------

/// In-pipeline evaluation checkpoint: scores the *current* model on the
/// held-out stream and reports it against the pipeline-wide baseline (the
/// model the first metric-producing stage saw).
struct EvalPass;

impl CompressionPass for EvalPass {
    fn name(&self) -> &'static str {
        "eval"
    }
    fn kind(&self) -> PassKind {
        PassKind::Eval
    }
    fn describe(&self) -> &'static str {
        "perplexity checkpoint on the held-out stream (vs pipeline baseline)"
    }

    fn apply(&self, ctx: &mut PassContext, _spec: &StageCfg) -> Result<StageOutcome> {
        let nll = ctx.nll()?;
        ctx.note_baseline(nll);
        let before = ctx.baseline_nll.unwrap_or(nll);
        Ok(StageOutcome {
            metric_before: before,
            metric_after: nll,
            compression: 1.0,
            notes: vec![format!(
                "ppl {:.4} (pipeline baseline ppl {:.4})",
                nll.exp(),
                before.exp()
            )],
            peak_calib_bytes: 0,
        })
    }
}

// ---------------------------------------------------------------------
// the registry itself
// ---------------------------------------------------------------------

static REGISTRY: &[&(dyn CompressionPass + Sync)] = &[
    // quantization (PTQ + QAT-derived QDQ deployments)
    &RtnQuantPass {
        name: "fp8_dynamic",
        describe: "fp8 E4M3 weight QDQ (near-lossless)",
        stored_bits: None,
        k_multiple: 1,
        group_wired: false,
        caveat: "",
        make: mk_fp8,
    },
    &LeptoPass { name: "fp8_lepto" },
    &LeptoPass { name: "leptoquant" },
    &RtnQuantPass {
        name: "int8",
        describe: "int8 per-channel affine QDQ",
        stored_bits: None,
        k_multiple: 1,
        group_wired: false,
        caveat: "",
        make: mk_int8,
    },
    &RtnQuantPass {
        name: "int4",
        describe: "int4 group-32 affine QDQ",
        stored_bits: None,
        k_multiple: 1,
        group_wired: false,
        caveat: "",
        make: mk_int4,
    },
    &GptqPass,
    &AwqPass,
    &SmoothPass,
    &RtnQuantPass {
        name: "seq2",
        describe: "SEQ 2-bit shifted-exponential QDQ (fixed group 32)",
        stored_bits: None,
        k_multiple: 1,
        group_wired: false,
        caveat: "",
        make: mk_seq2,
    },
    &RtnQuantPass {
        name: "tequila",
        describe: "Tequila ternary QDQ (ternary image; bias needs a deploy target)",
        stored_bits: None,
        k_multiple: 1,
        group_wired: false,
        caveat: "deadzone bias C(W) dropped: Transformer has no bias slots — \
                 apply Tequila::merge_bias in a deploy target that does",
        make: mk_tequila,
    },
    &RtnQuantPass {
        name: "sherry",
        describe: "Sherry 1.25-bit 3:4 structured-sparse ternary QDQ",
        stored_bits: None,
        k_multiple: 4,
        group_wired: false,
        caveat: "",
        make: mk_sherry,
    },
    &RtnQuantPass {
        name: "ternary",
        describe: "TWN ternary per-row QDQ",
        // packed 3-in-5 storage (packing.rs), not the 1.58-bit entropy
        stored_bits: Some(1.67),
        k_multiple: 1,
        group_wired: false,
        caveat: "",
        make: mk_ternary,
    },
    &RtnQuantPass {
        name: "w4a8",
        describe: "int4 group-wise weight QDQ (W4A8 deployment; group_size wired)",
        stored_bits: None,
        k_multiple: 1,
        group_wired: true,
        caveat: "",
        make: mk_w4a8,
    },
    &PackPass,
    // spec_decode (dispatches to the serving engine, not the compress loop)
    &SpecDecodePass { name: "eagle3", describe: "Eagle3-style aligned-draft speculative serving" },
    &SpecDecodePass { name: "vanilla", describe: "vanilla draft/target speculative serving" },
    &SpecDecodePass { name: "spec_exit", describe: "early-exit self-speculative serving" },
    // sparse_attn
    &SparseAttnPass {
        name: "dense",
        describe: "dense baseline (no sparsity)",
        algo: SparseAlgo::Dense,
    },
    &SparseAttnPass {
        name: "a_shape",
        describe: "A-shape static sink+local mask",
        algo: SparseAlgo::AShape,
    },
    &SparseAttnPass {
        name: "tri_shape",
        describe: "Tri-shape static mask",
        algo: SparseAlgo::TriShape,
    },
    &SparseAttnPass {
        name: "dilated",
        describe: "dilated strided static mask",
        algo: SparseAlgo::Dilated,
    },
    &SparseAttnPass { name: "strided", describe: "strided static mask", algo: SparseAlgo::Strided },
    &SparseAttnPass {
        name: "minference",
        describe: "MInference dynamic block estimation",
        algo: SparseAlgo::MInference,
    },
    &SparseAttnPass {
        name: "xattention",
        describe: "XAttention antidiagonal scoring",
        algo: SparseAlgo::XAttention,
    },
    &SparseAttnPass {
        name: "flexprefill",
        describe: "FlexPrefill adaptive per-head budget",
        algo: SparseAlgo::FlexPrefill,
    },
    &SparseAttnPass {
        name: "stem",
        describe: "Stem query-group block selection",
        algo: SparseAlgo::Stem,
    },
    // token_prune — visual (VQA-proxy)
    &VisualPrunePass {
        name: "idpruner",
        describe: "IDPruner identity-aware visual pruning",
        make: mk_idpruner,
    },
    &VisualPrunePass {
        name: "fastv",
        describe: "FastV attention-rank visual pruning",
        make: mk_fastv,
    },
    &VisualPrunePass {
        name: "divprune",
        describe: "DivPrune diversity-max visual pruning",
        make: mk_divprune,
    },
    &VisualPrunePass {
        name: "visionzip",
        describe: "VisionZip dominant-token selection",
        make: mk_visionzip,
    },
    &VisualPrunePass { name: "dart", describe: "DART duplication-aware reduction", make: mk_dart },
    &VisualPrunePass {
        name: "vispruner",
        describe: "VisPruner importance+diversity pruning",
        make: mk_vispruner,
    },
    &VisualPrunePass { name: "scope", describe: "SCOPE set-cover visual pruning", make: mk_scope },
    &VisualPrunePass {
        name: "visionselector",
        describe: "VisionSelector learned scoring proxy",
        make: mk_visionselector,
    },
    &VisualPrunePass {
        name: "hiprune",
        describe: "HiPrune hierarchical visual pruning",
        make: mk_hiprune,
    },
    // token_prune — audio (ASR-proxy, WER metric)
    &AudioPrunePass {
        name: "samp",
        describe: "Samp salience-aware audio merge (WER)",
        make: mk_samp,
    },
    &AudioPrunePass {
        name: "atome",
        describe: "A-ToMe adjacent token merging (WER)",
        make: mk_atome,
    },
    &AudioPrunePass {
        name: "fastadasp",
        describe: "FastAdaSp adaptive audio pruning (WER)",
        make: mk_fastadasp,
    },
    &AudioPrunePass {
        name: "cdpruner",
        describe: "CDPruner conditional-diversity pruning (WER)",
        make: mk_cdpruner,
    },
    // eval checkpoint + artifact export
    &EvalPass,
    &ExportPackedPass,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique() {
        let names = PassRegistry::names();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate registry name: {names:?}");
    }

    #[test]
    fn every_kind_has_a_registered_default() {
        for kind in PassKind::all() {
            let def = kind.default_pass();
            let pass = PassRegistry::find(def)
                .unwrap_or_else(|| panic!("default pass `{def}` for {kind:?} not registered"));
            assert_eq!(pass.kind(), kind, "default `{def}` registered under the wrong kind");
        }
    }

    #[test]
    fn find_resolves_each_registered_name_to_itself() {
        for p in PassRegistry::all() {
            let found = PassRegistry::find(p.name()).expect("registered name must resolve");
            assert_eq!(found.name(), p.name());
            assert!(!p.describe().is_empty(), "{} needs a description", p.name());
        }
        assert!(PassRegistry::find("wizardry").is_none());
    }

    #[test]
    fn by_method_groups_cover_the_whole_registry() {
        let grouped = PassRegistry::by_method();
        let total: usize = grouped.iter().map(|(_, names)| names.len()).sum();
        assert_eq!(total, PassRegistry::all().len());
        let quant = &grouped.iter().find(|(m, _)| *m == "quantization").unwrap().1;
        for expected in ["fp8_dynamic", "gptq", "awq", "smooth", "tequila", "sherry"] {
            assert!(quant.contains(&expected), "missing quant pass {expected}");
        }
    }
}
