//! The Compress Engine — the paper's Fig. 6 pipeline: YAML config →
//! Module Init (ModelFactory / DataFactory / SlimFactory) → composable
//! pass pipeline (prepare → calibrate → apply → report per stage) →
//! deployable artifacts + structured per-stage reports.

pub mod engine;
pub mod factories;
pub mod pass;
pub mod registry;

pub use engine::CompressEngine;
pub use factories::{DataFactory, ModelFactory, ServeFactory, SlimFactory};
pub use pass::{
    CalibCapture, CompressionPass, PassContext, PassKind, PipelineReport, StageOutcome,
    StageReport,
};
pub use registry::PassRegistry;
