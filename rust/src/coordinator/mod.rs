//! The Compress Engine — the paper's Fig. 6 pipeline: YAML config →
//! Module Init (ModelFactory / DataFactory / SlimFactory) → Compress Engine
//! (prepare → calibrate → compress → save → eval) → deployable artifacts.

pub mod engine;
pub mod factories;

pub use engine::{CompressEngine, CompressReport};
pub use factories::{DataFactory, ModelFactory, ServeFactory, SlimFactory};
