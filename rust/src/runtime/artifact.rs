//! Artifact registry: names -> compiled executables, compiled lazily and
//! cached. The "one compiled executable per model variant" policy of the
//! runtime (DESIGN.md §2).
//!
//! This registry covers the *compiled HLO* artifact family
//! (`<name>.hlo.txt`). The quantized-serving path has a second, weight-
//! level artifact family with its own contract: `crate::models::
//! packed_store` writes `packed_meta.json` + `packed_weights.bin` from an
//! `export-packed` pipeline stage, and the `packed-artifact` model
//! factory serves them bit-exactly without an HLO build. The two families
//! are deliberately disjoint on disk, so one artifacts dir can hold both.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

use super::executor::{ModelExecutable, PjrtRuntime};

/// Known model artifact variants (paper's quantization modes + sizes).
pub const MODEL_VARIANTS: &[&str] = &[
    "model_target_fp32_b1",
    "model_target_int4_b1",
    "model_target_seq2_b1",
    "model_target_seq2qat_b1",
    "model_target_ternary_b1",
    "model_target_fp8_b1",
    "model_target_fp32_b8",
    "model_draft_fp32_b1",
    "model_draft_fp32_b8",
    "model_small_fp32_b1",
];

pub struct ArtifactRegistry {
    pub rt: PjrtRuntime,
    pub dir: String,
    pub seq_t: usize,
    pub vocab: usize,
    cache: BTreeMap<String, std::sync::Arc<ModelExecutable>>,
}

impl ArtifactRegistry {
    pub fn open(dir: &str) -> Result<Self> {
        let rt = PjrtRuntime::cpu()?;
        Ok(ArtifactRegistry {
            rt,
            dir: dir.to_string(),
            seq_t: 64,
            vocab: 256,
            cache: BTreeMap::new(),
        })
    }

    fn batch_of(name: &str) -> usize {
        if name.ends_with("_b8") {
            8
        } else {
            1
        }
    }

    /// Get (compiling + caching on first use) a model executable by name.
    pub fn model(&mut self, name: &str) -> Result<std::sync::Arc<ModelExecutable>> {
        if let Some(exe) = self.cache.get(name) {
            return Ok(exe.clone());
        }
        let path = format!("{}/{}.hlo.txt", self.dir, name);
        anyhow::ensure!(
            std::path::Path::new(&path).exists(),
            "artifact {path} missing — run `make artifacts`"
        );
        let exe = ModelExecutable::new(
            &self.rt,
            &path,
            name,
            Self::batch_of(name),
            self.seq_t,
            self.vocab,
        )
        .with_context(|| format!("loading {name}"))?;
        let rc = std::sync::Arc::new(exe);
        self.cache.insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    pub fn available(&self) -> Vec<&'static str> {
        MODEL_VARIANTS
            .iter()
            .copied()
            .filter(|n| std::path::Path::new(&format!("{}/{}.hlo.txt", self.dir, n)).exists())
            .collect()
    }
}
