//! API-compatible stub for the PJRT executor, compiled when the `pjrt`
//! cargo feature is off (the default — the vendored `xla` crate is not
//! available in the hermetic build).
//!
//! Every constructor fails with an explicit error, so nothing downstream
//! can silently "succeed" without a real runtime: `ArtifactRegistry::open`
//! reports the missing feature, `angelslim serve` / `eval-quant` exit with
//! a clear message, and artifact-gated tests are `#[ignore]`d rather than
//! skipped. The struct/method surface mirrors executor.rs exactly so the
//! serving engine, spec decoder, benches, and examples type-check
//! identically under both configurations.

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` feature (requires the vendored `xla` crate; see Cargo.toml)";

/// Stub of the shared CPU PJRT client.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }
}

/// Stub of a compiled LM forward: tokens i32[B, T] -> logits f32[B, T, V].
pub struct ModelExecutable {
    pub batch: usize,
    pub seq_t: usize,
    pub vocab: usize,
    pub name: String,
}

impl ModelExecutable {
    pub fn new(
        _rt: &PjrtRuntime,
        _path: &str,
        _name: &str,
        _batch: usize,
        _seq_t: usize,
        _vocab: usize,
    ) -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run_padded(&self, _tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn next_logits(&self, _tokens: &[u8]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stub of the compiled sparse-attention kernel artifact.
pub struct AttnExecutable {
    pub t: usize,
    pub h: usize,
    pub d: usize,
    pub nb: usize,
}

impl AttnExecutable {
    pub fn new(
        _rt: &PjrtRuntime,
        _path: &str,
        _t: usize,
        _h: usize,
        _d: usize,
        _nb: usize,
    ) -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run(&self, _q: &[f32], _k: &[f32], _v: &[f32], _mask: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stub of a compiled quantized-matmul kernel artifact.
pub struct KernelExecutable {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl KernelExecutable {
    pub fn new(_rt: &PjrtRuntime, _path: &str, _m: usize, _k: usize, _n: usize) -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run(&self, _x: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_loudly() {
        let err = PjrtRuntime::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
