//! PJRT client wrapper + compiled-executable handles.

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared CPU PJRT client.
pub struct PjrtRuntime {
    pub client: PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &str) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))
    }
}

/// A compiled LM forward: tokens i32[B, T] -> logits f32[B, T, V].
pub struct ModelExecutable {
    exe: PjRtLoadedExecutable,
    pub batch: usize,
    pub seq_t: usize,
    pub vocab: usize,
    pub name: String,
}

impl ModelExecutable {
    pub fn new(
        rt: &PjrtRuntime,
        path: &str,
        name: &str,
        batch: usize,
        seq_t: usize,
        vocab: usize,
    ) -> Result<Self> {
        Ok(ModelExecutable {
            exe: rt.load_hlo_text(path)?,
            batch,
            seq_t,
            vocab,
            name: name.to_string(),
        })
    }

    /// Run a full batch. `tokens` is row-major [batch, seq_t] (caller pads).
    /// Returns logits row-major [batch, seq_t, vocab].
    pub fn run(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch * self.seq_t,
            "expected {}x{} tokens, got {}",
            self.batch,
            self.seq_t,
            tokens.len()
        );
        let lit = Literal::vec1(tokens)
            .reshape(&[self.batch as i64, self.seq_t as i64])
            .context("reshaping tokens")?;
        let result = self.exe.execute::<Literal>(&[lit])?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().context("untupling")?;
        let v = out.to_vec::<f32>().context("reading logits")?;
        anyhow::ensure!(v.len() == self.batch * self.seq_t * self.vocab);
        Ok(v)
    }

    /// Run a single (possibly short) sequence: pads to seq_t, returns the
    /// per-position logits for the first `len` positions.
    pub fn run_padded(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.batch == 1, "run_padded needs a b1 executable");
        anyhow::ensure!(tokens.len() <= self.seq_t, "sequence too long");
        let mut padded = vec![0i32; self.seq_t];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let flat = self.run(&padded)?;
        Ok((0..tokens.len())
            .map(|p| flat[p * self.vocab..(p + 1) * self.vocab].to_vec())
            .collect())
    }

    /// Logits at the last real position of a padded single sequence.
    pub fn next_logits(&self, tokens: &[u8]) -> Result<Vec<f32>> {
        let rows = self.run_padded(tokens)?;
        Ok(rows.into_iter().last().unwrap())
    }
}

/// A compiled sparse-attention kernel artifact:
/// (q, k, v f32[T, H, D], mask f32[NB, NB]) -> f32[T, H, D].
pub struct AttnExecutable {
    exe: PjRtLoadedExecutable,
    pub t: usize,
    pub h: usize,
    pub d: usize,
    pub nb: usize,
}

impl AttnExecutable {
    pub fn new(rt: &PjrtRuntime, path: &str, t: usize, h: usize, d: usize, nb: usize) -> Result<Self> {
        Ok(AttnExecutable { exe: rt.load_hlo_text(path)?, t, h, d, nb })
    }

    pub fn run(&self, q: &[f32], k: &[f32], v: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        let dims = [self.t as i64, self.h as i64, self.d as i64];
        let ql = Literal::vec1(q).reshape(&dims)?;
        let kl = Literal::vec1(k).reshape(&dims)?;
        let vl = Literal::vec1(v).reshape(&dims)?;
        let ml = Literal::vec1(mask).reshape(&[self.nb as i64, self.nb as i64])?;
        let result = self.exe.execute::<Literal>(&[ql, kl, vl, ml])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A compiled quantized-matmul kernel artifact: x f32[M, K] -> f32[M, N].
pub struct KernelExecutable {
    exe: PjRtLoadedExecutable,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl KernelExecutable {
    pub fn new(rt: &PjrtRuntime, path: &str, m: usize, k: usize, n: usize) -> Result<Self> {
        Ok(KernelExecutable { exe: rt.load_hlo_text(path)?, m, k, n })
    }

    pub fn run(&self, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.m * self.k);
        let xl = Literal::vec1(x).reshape(&[self.m as i64, self.k as i64])?;
        let result = self.exe.execute::<Literal>(&[xl])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
