//! PJRT runtime — loads the AOT HLO-text artifacts produced by the python
//! build and executes them on the CPU PJRT client. Python is never on this
//! path: the Rust binary is self-contained once artifacts/ exists.
//!
//! Interchange is HLO *text*: xla_extension 0.5.1 rejects jax>=0.5's
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md and DESIGN.md §2).

pub mod artifact;

/// Real PJRT executor — needs the vendored `xla` crate (see Cargo.toml's
/// `pjrt` feature notes). Without the feature, an API-compatible stub is
/// compiled instead so the rest of the toolkit (serving engine, spec
/// decode, CLI) builds hermetically; stub constructors return a clear
/// runtime error rather than silently succeeding.
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::ArtifactRegistry;
pub use executor::{ModelExecutable, PjrtRuntime};
