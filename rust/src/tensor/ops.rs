//! Linear-algebra kernels for the pure-Rust model/calibration path.
//!
//! `matmul_transb` is the workhorse: activations are `[M, K]` row-major and
//! weights are stored `[N, K]` (out x in, transposed-B layout), so both
//! operands stream contiguously — the same layout the packed quantized
//! GEMV kernels in quant/packing.rs use.

use super::Tensor;

/// y = x @ w.T where x: [M, K], w: [N, K] -> [M, N].
///
/// Register-blocked over activation rows: four `x` rows share each
/// streamed `w` row via [`dot4`], so the (large) weight operand is read
/// once per block instead of once per row. Each output element still
/// accumulates in exactly [`dot`]'s order, so results are bit-identical
/// to the naive row-at-a-time kernel.
pub fn matmul_transb(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "inner-dim mismatch {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let blocks = m / 4;
    for ib in 0..blocks {
        let i = ib * 4;
        let x0 = &x.data[i * k..(i + 1) * k];
        let x1 = &x.data[(i + 1) * k..(i + 2) * k];
        let x2 = &x.data[(i + 2) * k..(i + 3) * k];
        let x3 = &x.data[(i + 3) * k..(i + 4) * k];
        for j in 0..n {
            let [y0, y1, y2, y3] = dot4(x0, x1, x2, x3, w.row(j));
            out.data[i * n + j] = y0;
            out.data[(i + 1) * n + j] = y1;
            out.data[(i + 2) * n + j] = y2;
            out.data[(i + 3) * n + j] = y3;
        }
    }
    for i in blocks * 4..m {
        let xi = x.row(i);
        let oi = out.row_mut(i);
        for j in 0..n {
            oi[j] = dot(xi, w.row(j));
        }
    }
    out
}

/// y = x @ w.T for a single activation row: [K] · [N, K] -> [N].
/// The t=1 decode-step fast path — no [1, N] Tensor round-trips.
pub fn matvec_transb(x: &[f32], w: &Tensor) -> Vec<f32> {
    let (n, k) = (w.rows(), w.cols());
    assert_eq!(x.len(), k, "inner-dim mismatch {} vs {k}", x.len());
    (0..n).map(|j| dot(x, w.row(j))).collect()
}

/// y = x @ w.T where the `[N, K]` weight rows are produced on demand by
/// `row_of(j, buf)` — the fused-dequant prefill kernel for packed weights.
/// Each row is materialized ONCE into an L1-resident scratch and shared by
/// every activation row, so a packed matrix streams its packed bytes once
/// per matmul instead of dequantizing per activation row. Accumulation
/// runs through the same [`dot4`]/[`dot`] order as [`matmul_transb`], so
/// output is bit-identical to `matmul_transb(x, dequantized_w)`.
pub fn matmul_transb_rows(
    x: &Tensor,
    n: usize,
    k: usize,
    mut row_of: impl FnMut(usize, &mut [f32]),
) -> Tensor {
    let (m, xk) = (x.rows(), x.cols());
    assert_eq!(xk, k, "inner-dim mismatch {xk} vs {k}");
    let mut out = Tensor::zeros(&[m, n]);
    let mut wrow = vec![0.0f32; k];
    let blocks = m / 4;
    for j in 0..n {
        row_of(j, &mut wrow);
        for ib in 0..blocks {
            let i = ib * 4;
            let x0 = &x.data[i * k..(i + 1) * k];
            let x1 = &x.data[(i + 1) * k..(i + 2) * k];
            let x2 = &x.data[(i + 2) * k..(i + 3) * k];
            let x3 = &x.data[(i + 3) * k..(i + 4) * k];
            let [y0, y1, y2, y3] = dot4(x0, x1, x2, x3, &wrow);
            out.data[i * n + j] = y0;
            out.data[(i + 1) * n + j] = y1;
            out.data[(i + 2) * n + j] = y2;
            out.data[(i + 3) * n + j] = y3;
        }
        for i in blocks * 4..m {
            out.data[i * n + j] = dot(x.row(i), &wrow);
        }
    }
    out
}

/// Unrolled dot product (4-wide) — the scalar hot loop of the repo.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Four dot products against a shared right-hand side. Each lane keeps
/// the same four-phase accumulators as [`dot`] (bit-identical results);
/// `b` is streamed once per block of four left-hand rows.
#[inline]
pub fn dot4(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
    let len = b.len();
    debug_assert!(a0.len() == len && a1.len() == len && a2.len() == len && a3.len() == len);
    let chunks = len / 4;
    let mut s = [[0.0f32; 4]; 4]; // s[lane][phase]
    for c in 0..chunks {
        let i = c * 4;
        for p in 0..4 {
            let bv = b[i + p];
            s[0][p] += a0[i + p] * bv;
            s[1][p] += a1[i + p] * bv;
            s[2][p] += a2[i + p] * bv;
            s[3][p] += a3[i + p] * bv;
        }
    }
    let mut out = [0.0f32; 4];
    for (lane, a) in [a0, a1, a2, a3].into_iter().enumerate() {
        let mut acc = s[lane][0] + s[lane][1] + s[lane][2] + s[lane][3];
        for i in chunks * 4..len {
            acc += a[i] * b[i];
        }
        out[lane] = acc;
    }
    out
}

/// In-place row-wise softmax over the last dim of a 2-D tensor.
pub fn softmax_rows(t: &mut Tensor) {
    let c = t.cols();
    for i in 0..t.rows() {
        let row = t.row_mut(i);
        softmax_inplace(row);
        debug_assert_eq!(row.len(), c);
    }
}

/// Numerically-stable softmax on a slice.
pub fn softmax_inplace(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        let v = 1.0 / row.len() as f32;
        row.iter_mut().for_each(|x| *x = v);
        return;
    }
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    row.iter_mut().for_each(|x| *x *= inv);
}

/// Log-softmax of a slice (returns a new Vec).
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + row.iter().map(|x| (x - m).exp()).sum::<f32>().ln();
    row.iter().map(|x| x - lse).collect()
}

/// RMSNorm: x * g / sqrt(mean(x^2) + eps), row-wise.
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Index of the max element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Element-wise a += b.
pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += b[i];
    }
}

/// a * s element-wise, in place.
pub fn scale_inplace(a: &mut [f32], s: f32) {
    a.iter_mut().for_each(|x| *x *= s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_allclose;

    #[test]
    fn matmul_small() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]] (3x2) -> x @ w.T
        let x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let y = matmul_transb(&x, &w);
        assert_eq!(y.dims(), &[2, 3]);
        assert_allclose(&y.data, &[1.0, 2.0, 3.0, 3.0, 4.0, 7.0], 1e-6, 1e-6);
    }

    /// Reference row-at-a-time kernel the blocked matmul must match bitwise.
    fn matmul_transb_naive(x: &Tensor, w: &Tensor) -> Tensor {
        let (m, n) = (x.rows(), w.rows());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                out.row_mut(i)[j] = dot(x.row(i), w.row(j));
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_bit_identical_to_naive() {
        let mut rng = crate::util::Rng::new(42);
        // m spans sub-block, exact-block, and remainder cases; k exercises
        // the 4-wide unroll remainder too
        for (m, k, n) in [(1, 7, 5), (3, 8, 4), (4, 16, 9), (6, 13, 3), (9, 32, 17)] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let w = Tensor::randn(&[n, k], 1.0, &mut rng);
            let blocked = matmul_transb(&x, &w);
            let naive = matmul_transb_naive(&x, &w);
            assert_eq!(blocked.data, naive.data, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn dot4_bit_identical_to_dot() {
        let mut rng = crate::util::Rng::new(7);
        for len in [1usize, 4, 7, 16, 33] {
            let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(len, 1.0)).collect();
            let b = rng.normal_vec(len, 1.0);
            let ys = dot4(&rows[0], &rows[1], &rows[2], &rows[3], &b);
            for (lane, row) in rows.iter().enumerate() {
                assert_eq!(ys[lane], dot(row, &b), "len={len} lane={lane}");
            }
        }
    }

    #[test]
    fn matmul_rows_bit_identical_to_matmul() {
        // the row-provider kernel (fed here by plain f32 row copies) must
        // reproduce matmul_transb bitwise — the packed-prefill anchor
        let mut rng = crate::util::Rng::new(21);
        for (m, k, n) in [(1, 8, 5), (4, 16, 9), (6, 13, 3), (9, 32, 17)] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let w = Tensor::randn(&[n, k], 1.0, &mut rng);
            let direct = matmul_transb(&x, &w);
            let via_rows = matmul_transb_rows(&x, n, k, |j, buf| {
                buf.copy_from_slice(w.row(j));
            });
            assert_eq!(direct.data, via_rows.data, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matvec_matches_matmul_row() {
        let mut rng = crate::util::Rng::new(9);
        let x = Tensor::randn(&[1, 13], 1.0, &mut rng);
        let w = Tensor::randn(&[6, 13], 1.0, &mut rng);
        let full = matmul_transb(&x, &w);
        let fast = matvec_transb(x.row(0), &w);
        assert_eq!(full.data, fast);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut row = vec![1.0f32, 2.0, 3.0, 4.0];
        softmax_inplace(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[3] > row[0]);
    }

    #[test]
    fn softmax_handles_neg_inf_row() {
        let mut row = vec![f32::NEG_INFINITY; 4];
        softmax_inplace(&mut row);
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_normalizes() {
        let row = [0.5f32, 1.5, -0.5];
        let lp = log_softmax(&row);
        let s: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let g = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &g, &mut out);
        // mean square = 12.5, rms ≈ 3.5355
        assert!((out[0] - 3.0 / 3.5355).abs() < 1e-3);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn silu_signs() {
        assert!(silu(5.0) > 4.9);
        assert!(silu(-5.0).abs() < 0.05);
        assert_eq!(silu(0.0), 0.0);
    }
}
