//! Shape bookkeeping for the dense tensor.

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    pub dims: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape { dims: dims.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1];
        }
        s
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}]", self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.rank(), 3);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert!(s.strides().is_empty());
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[2, 3]).to_string(), "[2, 3]");
    }
}
