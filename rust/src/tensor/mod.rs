//! Minimal dense f32 tensor + the linear-algebra ops the pure-Rust model
//! path needs (matmul, softmax, rmsnorm, attention primitives).
//!
//! The PJRT artifacts carry the *serving* hot path; this module exists so
//! the PTQ framework (GPTQ / AWQ / LeptoQuant) can run calibration and
//! layer-wise reconstruction over real transformer weights entirely in Rust
//! — the paper's Compress Engine does the same against torch modules.

pub mod ops;
pub mod shape;

pub use ops::*;
pub use shape::Shape;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn randn(dims: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let shape = Shape::new(dims);
        let data = rng.normal_vec(shape.numel(), std);
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.shape.dims
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.dims().len(), 2);
        self.dims()[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.dims().len(), 2);
        self.dims()[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn row_access() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(0), &[1.0, 2.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn randn_uses_std() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[100, 100], 0.1, &mut rng);
        let var = t.data.iter().map(|x| x * x).sum::<f32>() / t.numel() as f32;
        assert!((var - 0.01).abs() < 0.002, "var {var}");
    }
}
