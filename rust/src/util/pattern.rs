//! Pattern-based layer selection — the DynamicDiT-style include/exclude
//! API for per-layer compression: each pattern is either a plain
//! substring or a regex (auto-detected by the presence of regex
//! metacharacters, so one list can mix both spellings, e.g.
//! `["w_gate", r"layer\d+\.wq"]`).
//!
//! The regex dialect is deliberately small (no crates.io deps): literals,
//! `.`, `*`, `+`, `?`, `^`/`$` anchors, `[...]` classes (ranges and
//! negation), `\d`/`\w`/`\s`, escapes, and top-level alternation `|`.
//! Groups are rejected loudly rather than mis-matched silently. Matching
//! uses search semantics: an unanchored pattern matches anywhere in the
//! layer name, like `re.search`.

use anyhow::{bail, Context, Result};

/// One include/exclude pattern over layer names.
#[derive(Clone, Debug)]
pub struct Pattern {
    raw: String,
    kind: Kind,
}

#[derive(Clone, Debug)]
enum Kind {
    Substring,
    /// alternation of node sequences (`a|b|c`)
    Regex(Vec<Vec<Node>>),
}

#[derive(Clone, Debug)]
enum Node {
    Start,
    End,
    Lit(char),
    Any,
    /// inclusive ranges + negation flag
    Class(Vec<(char, char)>, bool),
    Star(Box<Node>),
    Plus(Box<Node>),
    Opt(Box<Node>),
}

impl Pattern {
    pub fn new(raw: &str) -> Result<Pattern> {
        if raw.is_empty() {
            bail!("empty layer pattern");
        }
        let kind = if raw.chars().any(|c| r"^$.*+?[]\|()".contains(c)) {
            Kind::Regex(parse_alternation(raw)?)
        } else {
            Kind::Substring
        };
        Ok(Pattern { raw: raw.to_string(), kind })
    }

    pub fn as_str(&self) -> &str {
        &self.raw
    }

    pub fn matches(&self, name: &str) -> bool {
        match &self.kind {
            Kind::Substring => name.contains(&self.raw),
            Kind::Regex(alts) => {
                let text: Vec<char> = name.chars().collect();
                alts.iter()
                    .any(|seq| (0..=text.len()).any(|i| match_here(seq, &text, i)))
            }
        }
    }
}

/// Include/exclude filter over weight names: a name is selected when it
/// matches any include pattern (an empty include list selects everything)
/// and no exclude pattern.
#[derive(Clone, Debug, Default)]
pub struct Selector {
    pub include: Vec<Pattern>,
    pub exclude: Vec<Pattern>,
}

impl Selector {
    pub fn new(include: &[String], exclude: &[String]) -> Result<Selector> {
        let compile = |ps: &[String]| -> Result<Vec<Pattern>> {
            ps.iter()
                .map(|p| Pattern::new(p).with_context(|| format!("pattern `{p}`")))
                .collect()
        };
        Ok(Selector { include: compile(include)?, exclude: compile(exclude)? })
    }

    /// The match-everything selector.
    pub fn all() -> Selector {
        Selector::default()
    }

    pub fn matches(&self, name: &str) -> bool {
        let included =
            self.include.is_empty() || self.include.iter().any(|p| p.matches(name));
        included && !self.exclude.iter().any(|p| p.matches(name))
    }
}

fn parse_alternation(pat: &str) -> Result<Vec<Vec<Node>>> {
    // no groups, so every `|` is top-level
    pat.split('|').map(|seq| parse_sequence(seq, pat)).collect()
}

fn parse_sequence(seq: &str, pat: &str) -> Result<Vec<Node>> {
    let mut out: Vec<Node> = Vec::new();
    let mut chars = seq.chars().peekable();
    while let Some(c) = chars.next() {
        let node = match c {
            '^' => Node::Start,
            '$' => Node::End,
            '.' => Node::Any,
            '(' | ')' => bail!("regex groups are not supported in layer patterns: `{pat}`"),
            '[' => parse_class(&mut chars, pat)?,
            '\\' => escape_node(
                chars.next().with_context(|| format!("dangling `\\` in `{pat}`"))?,
                pat,
            )?,
            '*' | '+' | '?' => {
                let prev = out.pop().filter(is_char_node).with_context(|| {
                    format!("quantifier `{c}` without a preceding atom in `{pat}`")
                })?;
                let b = Box::new(prev);
                match c {
                    '*' => Node::Star(b),
                    '+' => Node::Plus(b),
                    _ => Node::Opt(b),
                }
            }
            lit => Node::Lit(lit),
        };
        out.push(node);
    }
    Ok(out)
}

fn escape_node(e: char, pat: &str) -> Result<Node> {
    Ok(match e {
        'd' => Node::Class(vec![('0', '9')], false),
        'w' => Node::Class(vec![('a', 'z'), ('A', 'Z'), ('0', '9'), ('_', '_')], false),
        's' => Node::Class(vec![(' ', ' '), ('\t', '\t')], false),
        '.' | '\\' | '*' | '+' | '?' | '[' | ']' | '^' | '$' | '|' | '(' | ')' | '-' => {
            Node::Lit(e)
        }
        other => bail!("unsupported escape `\\{other}` in `{pat}`"),
    })
}

fn parse_class(
    chars: &mut std::iter::Peekable<std::str::Chars<'_>>,
    pat: &str,
) -> Result<Node> {
    let mut ranges: Vec<(char, char)> = Vec::new();
    let mut negated = false;
    if chars.peek() == Some(&'^') {
        chars.next();
        negated = true;
    }
    loop {
        let c = match chars.next() {
            None => bail!("unterminated `[...]` class in `{pat}`"),
            Some(']') => break,
            Some(c) => c,
        };
        let lo = if c == '\\' {
            let e = chars.next().with_context(|| format!("dangling `\\` in `{pat}`"))?;
            match escape_node(e, pat)? {
                Node::Lit(l) => l,
                Node::Class(mut rs, false) => {
                    // \d / \w / \s inside a class contribute their ranges
                    ranges.append(&mut rs);
                    continue;
                }
                _ => bail!("unsupported escape `\\{e}` in class in `{pat}`"),
            }
        } else {
            c
        };
        if chars.peek() == Some(&'-') {
            chars.next();
            match chars.peek() {
                Some(&']') | None => {
                    // trailing `-` is a literal
                    ranges.push((lo, lo));
                    ranges.push(('-', '-'));
                }
                Some(_) => {
                    let hi = chars.next().unwrap();
                    if hi < lo {
                        bail!("inverted range `{lo}-{hi}` in `{pat}`");
                    }
                    ranges.push((lo, hi));
                }
            }
        } else {
            ranges.push((lo, lo));
        }
    }
    Ok(Node::Class(ranges, negated))
}

fn is_char_node(n: &Node) -> bool {
    matches!(n, Node::Lit(_) | Node::Any | Node::Class(..))
}

fn char_match(n: &Node, c: char) -> bool {
    match n {
        Node::Lit(l) => *l == c,
        Node::Any => true,
        Node::Class(ranges, neg) => {
            ranges.iter().any(|&(lo, hi)| lo <= c && c <= hi) != *neg
        }
        _ => false,
    }
}

/// Backtracking matcher: does `nodes` match `text` starting at `i`?
fn match_here(nodes: &[Node], text: &[char], i: usize) -> bool {
    let Some(node) = nodes.first() else {
        return true;
    };
    let rest = &nodes[1..];
    match node {
        Node::Start => i == 0 && match_here(rest, text, i),
        Node::End => i == text.len() && match_here(rest, text, i),
        Node::Star(a) => {
            let mut j = i;
            while j < text.len() && char_match(a, text[j]) {
                j += 1;
            }
            // greedy, then back off
            loop {
                if match_here(rest, text, j) {
                    return true;
                }
                if j == i {
                    return false;
                }
                j -= 1;
            }
        }
        Node::Plus(a) => {
            if i >= text.len() || !char_match(a, text[i]) {
                return false;
            }
            let floor = i + 1;
            let mut j = floor;
            while j < text.len() && char_match(a, text[j]) {
                j += 1;
            }
            loop {
                if match_here(rest, text, j) {
                    return true;
                }
                if j == floor {
                    return false;
                }
                j -= 1;
            }
        }
        Node::Opt(a) => {
            (i < text.len() && char_match(a, text[i]) && match_here(rest, text, i + 1))
                || match_here(rest, text, i)
        }
        single => {
            i < text.len() && char_match(single, text[i]) && match_here(rest, text, i + 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pat(s: &str) -> Pattern {
        Pattern::new(s).unwrap()
    }

    #[test]
    fn substring_patterns_match_anywhere() {
        assert!(pat("wq").matches("layer3.wq"));
        assert!(pat("layer0").matches("layer0.w_gate"));
        assert!(!pat("head").matches("layer0.wq"));
    }

    #[test]
    fn regex_digits_and_anchors() {
        let p = pat(r"layer\d+\.wq");
        assert!(p.matches("layer0.wq"));
        assert!(p.matches("layer12.wq"));
        assert!(!p.matches("layer.wq"));
        let anchored = pat("^head$");
        assert!(anchored.matches("head"));
        assert!(!anchored.matches("layer0.head"));
        assert!(!anchored.matches("heads"));
    }

    #[test]
    fn regex_alternation_and_classes() {
        let p = pat("w[qk]$");
        assert!(p.matches("layer1.wq"));
        assert!(p.matches("layer1.wk"));
        assert!(!p.matches("layer1.wv"));
        let alt = pat("wq|w_gate");
        assert!(alt.matches("layer0.wq"));
        assert!(alt.matches("layer1.w_gate"));
        assert!(!alt.matches("layer1.wo"));
        let neg = pat("w[^qk]$");
        assert!(neg.matches("layer1.wv"));
        assert!(!neg.matches("layer1.wq"));
    }

    #[test]
    fn regex_star_plus_opt() {
        assert!(pat("la.*wq").matches("layer9.wq"));
        assert!(pat("^w_?gate").matches("w_gate"));
        assert!(pat("^w_?gate").matches("wgate"));
        assert!(!pat("x+").matches("layer0.wq"));
        // `*` may match zero chars
        assert!(pat("^ab*c").matches("ac"));
    }

    #[test]
    fn groups_and_bad_escapes_fail_loudly() {
        assert!(Pattern::new("(wq|wk)").is_err());
        assert!(Pattern::new(r"\y").is_err());
        assert!(Pattern::new("[abc").is_err());
        assert!(Pattern::new("*wq").is_err());
        assert!(Pattern::new("").is_err());
    }

    #[test]
    fn selector_include_exclude_semantics() {
        let all = Selector::all();
        assert!(all.matches("layer0.wq"));
        assert!(all.matches("head"));

        let s = Selector::new(
            &["wq".into(), r"layer\d+\.w_gate".into()],
            &["layer1".into()],
        )
        .unwrap();
        assert!(s.matches("layer0.wq"));
        assert!(s.matches("layer2.w_gate"));
        assert!(!s.matches("layer1.wq"), "exclude wins over include");
        assert!(!s.matches("layer0.wo"), "not included");

        // empty include = everything (minus excludes)
        let only_excl = Selector::new(&[], &["head".into()]).unwrap();
        assert!(only_excl.matches("layer0.wq"));
        assert!(!only_excl.matches("head"));
    }

    #[test]
    fn selector_rejects_bad_patterns() {
        assert!(Selector::new(&["(bad".into()], &[]).is_err());
        assert!(Selector::new(&[], &["[oops".into()]).is_err());
    }
}
