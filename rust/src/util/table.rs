//! ASCII/markdown table rendering — every bench prints its paper table
//! through this so `cargo bench` output reads like the paper's evaluation
//! section.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by the bench binaries.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row_strs(&["a", "1.00"]);
        t.row_strs(&["longer", "2"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| name   | val  |"));
        assert!(s.contains("| longer | 2    |"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.1234), "12.34%");
    }
}
