//! Deterministic xorshift128+ RNG — every synthetic workload in this repo is
//! seeded so tables and tests are reproducible run to run.

#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed so nearby seeds decorrelate
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Rng { s0, s1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of n (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let a: Vec<u64> = (0..8).map(|_| Rng::new(1).next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| Rng::new(2).next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 3);
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(8);
        let picks = r.choose(10, 5);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
        assert!(picks.iter().all(|&i| i < 10));
    }
}
