//! Wall-clock timing + a hand-rolled bench harness (criterion is not
//! available offline): warmup, fixed iteration count, median-of-N reporting.

use std::time::{Duration, Instant};

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// per-iteration wall time, seconds
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    pub fn per_sec(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Median of `samples` draws of `f()` — the shared building block for
/// wall-clock perf gates (`bench_decode_kv`'s packed≥f32 gate,
/// `bench_sharded`'s threaded-scaling gate). A single noisy draw on a
/// loaded CI machine flips a comparison; the median of a small odd count
/// doesn't. Callers wrap this in `testing::retry_timing` for bounded
/// retries on top.
pub fn median_of<F: FnMut() -> f64>(samples: usize, mut f: F) -> f64 {
    let n = samples.max(1);
    let mut xs: Vec<f64> = (0..n).map(|_| f()).collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[n / 2]
}

/// Run `f` `iters` times after `warmup` untimed runs; report median/mean/min.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median_s: median,
        mean_s: mean,
        min_s: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let r = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.median_s >= 0.0);
        assert!(r.min_s <= r.median_s);
    }

    #[test]
    fn median_of_is_order_insensitive() {
        let mut vals = [5.0, 1.0, 9.0, 3.0, 7.0].into_iter();
        let m = median_of(5, || vals.next().unwrap());
        assert_eq!(m, 5.0);
        let m1 = median_of(1, || 42.0);
        assert_eq!(m1, 42.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
