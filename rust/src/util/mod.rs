//! Shared utilities: RNG, timing, statistics, table rendering, and a tiny
//! property-testing harness.
//!
//! This environment is offline with only the `xla` crate's dependency
//! closure vendored, so the usual suspects (rand, criterion, proptest,
//! comfy-table) are hand-rolled here. See DESIGN.md §8.

pub mod fixtures;
pub mod pattern;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testing;
pub mod timer;

pub use pattern::{Pattern, Selector};
pub use rng::Rng;
pub use stats::Summary;
pub use table::Table;
pub use timer::{bench, median_of, BenchResult, Timer};
