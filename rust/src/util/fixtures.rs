//! Hermetic fixture models — a tiny deterministic in-memory transformer
//! that encodes a known next-token rule, so the *whole* paper pipeline
//! (calibrate → quantize → evaluate → speculative decode → serve) can be
//! exercised by `cargo test` on a clean checkout: no `artifacts/` on disk,
//! no PJRT, no python build.
//!
//! Construction: token `t < d_model` embeds as a noisy two-component
//! pattern (`gain` at column `t`, `gain/2` at column `(t+partner) % d`);
//! the untied head inverts that pattern shifted by `shift`, so the logits
//! at every position peak at `(t + shift) % d_model` — the same rule
//! [`fixture_corpus`] generates. Transformer blocks carry small random
//! weights: enough to exercise attention/MLP/calibration code paths, small
//! enough that the planted signal dominates. Tokens `>= d_model` (the
//! long-context marker bytes, fillers) get noise-only embeddings: the
//! model treats them as uninformative context and never predicts them.
//!
//! Why this makes quantization *measurable*: every head row mixes weight
//! magnitudes (`gain`, `gain/2`, noise) inside one quantization group, so
//! round-trip error grows as formats coarsen — fp8 keeps both signal
//! levels nearly exact, int4 nudges the half-gain component, SEQ-2bit
//! inflates the noise floor to ±0.5·scale, and ternary collapses each row
//! onto a single ±alpha level. Perplexity on the rule corpus orders
//! accordingly, which is exactly the paper-shaped ladder the hermetic
//! end-to-end test asserts.

use crate::models::transformer::Layer;
use crate::models::{Transformer, TransformerCfg};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Specification for a fixture transformer + its rule corpus.
#[derive(Clone, Debug)]
pub struct FixtureSpec {
    /// full token space; 256 so any `u8` stream embeds safely
    pub vocab: usize,
    /// model width; also the "signal vocabulary" — rule tokens are `< d_model`
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_t: usize,
    /// the planted rule: next = (t + shift) % d_model
    pub shift: u8,
    /// column offset of the secondary (half-gain) signal component
    pub partner: usize,
    /// magnitude of the planted signal weights
    pub gain: f32,
    /// std of the random perturbation on every weight
    pub noise: f32,
    pub seed: u64,
}

impl Default for FixtureSpec {
    fn default() -> Self {
        // d_model stays a multiple of 32 so group-32 quantizers apply, and
        // d_ff a multiple of 4 for Sherry's 3:4 blocks.
        FixtureSpec {
            vocab: 256,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_t: 48,
            shift: 5,
            partner: 13,
            // rmsnorm makes the residual stream scale-invariant, so `gain`
            // effectively sets the head-side logit margin: 1.3 keeps the
            // rule prediction dominant over the 224 noise-only head rows
            // while leaving room for quantization damage to register.
            gain: 1.3,
            noise: 0.05,
            seed: 0,
        }
    }
}

/// Build the fixture transformer for a spec.
pub fn fixture_transformer(spec: &FixtureSpec) -> Transformer {
    assert!(spec.d_model % spec.n_heads == 0, "d_model must split across heads");
    assert!(spec.vocab >= spec.d_model, "signal vocab cannot exceed token space");
    assert!(spec.partner % spec.d_model != 0, "partner column must differ from hot column");
    let d = spec.d_model;
    let v = spec.vocab;
    let mut rng = Rng::new(spec.seed ^ 0xF1A7_CAFE);

    // embedding: signal rows for rule tokens, noise-only rows for fillers
    let mut embed = Tensor::randn(&[v, d], spec.noise, &mut rng);
    for t in 0..d {
        let row = embed.row_mut(t);
        row[t] += spec.gain;
        row[(t + spec.partner) % d] += 0.5 * spec.gain;
    }
    let pos = Tensor::randn(&[spec.max_t, d], spec.noise * 0.5, &mut rng);

    let mut layers = Vec::with_capacity(spec.n_layers);
    for _ in 0..spec.n_layers {
        let w = spec.noise * 0.4;
        layers.push(Layer {
            ln1: vec![1.0; d],
            wq: Tensor::randn(&[d, d], w, &mut rng).into(),
            wk: Tensor::randn(&[d, d], w, &mut rng).into(),
            wv: Tensor::randn(&[d, d], w, &mut rng).into(),
            wo: Tensor::randn(&[d, d], w, &mut rng).into(),
            ln2: vec![1.0; d],
            w_gate: Tensor::randn(&[spec.d_ff, d], w, &mut rng).into(),
            w_up: Tensor::randn(&[spec.d_ff, d], w, &mut rng).into(),
            w_down: Tensor::randn(&[d, spec.d_ff], w, &mut rng).into(),
        });
    }

    // head row r (r < d) is hot at column (r - shift) mod d, so the logit
    // for token (t + shift) mod d peaks whenever the residual stream
    // carries token t's embedding pattern. Rows >= d stay low-energy noise
    // so filler tokens never win the argmax.
    let mut head = Tensor::randn(&[v, d], spec.noise * 0.5, &mut rng);
    let shift = spec.shift as usize % d;
    for r in 0..d {
        let src = (r + d - shift) % d;
        let row = head.row_mut(r);
        row[src] += spec.gain;
        row[(src + spec.partner) % d] += 0.5 * spec.gain;
    }

    Transformer {
        cfg: TransformerCfg {
            vocab: v,
            d_model: d,
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            d_ff: spec.d_ff,
            max_t: spec.max_t,
        },
        embed,
        pos,
        layers,
        ln_f: vec![1.0; d],
        head: head.into(),
    }
}

/// The default target-sized fixture (2 blocks), with weight noise varied
/// by `seed` but the rule (shift, partner) held at the default spec so all
/// fixtures agree on the corpus they model.
pub fn fixture_target(seed: u64) -> Transformer {
    fixture_transformer(&FixtureSpec { seed: seed ^ 0xF1D0_7A26, ..FixtureSpec::default() })
}

/// A smaller draft-sized fixture (1 block, noisier) encoding the SAME
/// rule, so speculative decoding against [`fixture_target`] accepts most
/// proposals — the Eagle3-style aligned-draft setting.
pub fn fixture_draft(seed: u64) -> Transformer {
    fixture_transformer(&FixtureSpec {
        n_layers: 1,
        d_ff: 32,
        noise: 0.08,
        seed: seed ^ 0xD2AF_0001,
        ..FixtureSpec::default()
    })
}

/// Deterministic rule corpus: next = (t + shift) % d_model with a 2%
/// resample rate (so the model is confident but not saturated, and
/// quantization damage shows up in perplexity rather than vanishing into
/// an already-zero NLL).
pub fn fixture_corpus(spec: &FixtureSpec, n: usize, seed: u64) -> Vec<u8> {
    let m = spec.d_model;
    let mut rng = Rng::new(seed ^ 0x0C0_87B5);
    let mut t = rng.below(m) as u8;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(t);
        t = if rng.bool(0.02) {
            rng.below(m) as u8
        } else {
            ((t as usize + spec.shift as usize) % m) as u8
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::corpus_nll;
    use crate::models::AttnOverride;

    #[test]
    fn fixture_follows_shift_rule() {
        let spec = FixtureSpec::default();
        let m = fixture_target(0);
        for t in [0u8, 3, 17, 31] {
            let want = ((t as usize + spec.shift as usize) % spec.d_model) as u8;
            assert_eq!(m.greedy_next(&[t]), want, "token {t}");
        }
        // the rule holds mid-sequence, not just at position 0
        let ctx = [1u8, 6, 11, 16];
        assert_eq!(m.greedy_next(&ctx), 21);
    }

    #[test]
    fn fixture_is_deterministic_and_seed_sensitive() {
        let a = fixture_target(9);
        let b = fixture_target(9);
        assert_eq!(a.head.f32().data, b.head.f32().data);
        assert_eq!(a.layers[0].wq.f32().data, b.layers[0].wq.f32().data);
        let c = fixture_target(10);
        assert_ne!(a.head.f32().data, c.head.f32().data);
    }

    #[test]
    fn corpus_mostly_follows_rule() {
        let spec = FixtureSpec::default();
        let c = fixture_corpus(&spec, 5_000, 1);
        assert!(c.iter().all(|&t| (t as usize) < spec.d_model));
        let follows = c
            .windows(2)
            .filter(|w| w[1] as usize == (w[0] as usize + spec.shift as usize) % spec.d_model)
            .count();
        assert!(follows > 4_500, "only {follows}/4999 transitions follow the rule");
        assert_eq!(fixture_corpus(&spec, 500, 3), fixture_corpus(&spec, 500, 3));
        assert_ne!(fixture_corpus(&spec, 500, 3), fixture_corpus(&spec, 500, 4));
    }

    #[test]
    fn fixture_nll_beats_uniform_by_far() {
        let spec = FixtureSpec::default();
        let m = fixture_target(0);
        let corpus = fixture_corpus(&spec, 4_096, 2);
        let nll = corpus_nll(&m, &corpus, 40, 4).unwrap();
        let uniform = (spec.vocab as f64).ln();
        assert!(nll < 1.0, "fixture NLL {nll} (uniform would be {uniform:.2})");
    }

    #[test]
    fn filler_tokens_embed_safely() {
        // bytes outside the signal vocab (long-context markers, filler)
        // must forward without panicking and stay finite
        let m = fixture_target(0);
        let toks = [200u8, 13, 255, 64, 201];
        let logits = m.forward(&toks, &AttnOverride::None);
        assert_eq!(logits.dims(), &[5, 256]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }
}
