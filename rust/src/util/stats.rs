//! Small statistics helpers used by evaluation suites and benches.

/// Summary statistics over a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.5),
            p90: pct(0.9),
            p99: pct(0.99),
        }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Pearson correlation of two equal-length slices.
pub fn pearson(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f32;
    let ma = mean(a);
    let mb = mean(b);
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let da = a[i] - ma;
        let db = b[i] - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt()) * (n / n)
}

/// Mean squared error between two slices.
pub fn mse(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f32>()
        / a.len() as f32
}

/// Cosine similarity.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-6);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a = [1.0f32, -2.0, 3.5];
        assert_eq!(mse(&a, &a), 0.0);
    }

    #[test]
    fn cosine_orthogonal() {
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert!((cosine(&[1.0, 1.0], &[2.0, 2.0]) - 1.0).abs() < 1e-6);
    }
}
