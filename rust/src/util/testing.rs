//! Mini property-testing harness (proptest is not available offline).
//!
//! `check(seed_count, |rng| ...)` runs a property closure against many
//! seeded RNGs and reports the first failing seed, so failures reproduce
//! deterministically: re-run with `check_one(seed, ...)`.
//!
//! Also home to the serving-trace helpers shared by the scheduler
//! property tests (`tests/test_scheduler_props.rs`,
//! `tests/test_sharded_props.rs`) and the serving benches
//! (`benches/bench_continuous.rs`, `benches/bench_sharded.rs`): building
//! heterogeneous fixture traces and asserting the cross-path equivalence
//! / exactly-once contracts in one place instead of three.

use super::pattern::Selector;
use super::rng::Rng;
use crate::data::TokenRequest;
use crate::models::Transformer;
use crate::quant::packing::PackFormat;
use crate::server::{GreedyExecutor, ServeReport, StepExecutor};

/// Run `prop` for `cases` deterministic seeds. Panics with the failing seed
/// on the first property violation (the closure should panic/assert).
pub fn check<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9));
    prop(&mut rng);
}

/// Heterogeneous-length request trace over a corpus: prompt windows of 8
/// tokens strided through the stream, alternating full/short generations
/// (so retirement actually frees slots mid-run), arrivals 0.5 ms apart.
/// The shape the scheduler property tests and serving benches share.
pub fn fixture_requests(corpus: &[u8], n: usize, max_new: usize) -> Vec<TokenRequest> {
    assert!(corpus.len() >= n * 17 + 8, "corpus too short for {n} requests");
    (0..n)
        .map(|i| TokenRequest {
            id: i as u64,
            prompt: corpus[i * 17..i * 17 + 8].to_vec(),
            max_new_tokens: if i % 2 == 0 { max_new } else { max_new / 3 + 1 },
            arrival_ms: i as f64 * 0.5,
            deadline_ms: None,
            class: Default::default(),
        })
        .collect()
}

/// Run a timing-sensitive performance check up to `attempts` times: `f`
/// returns `Ok(value)` when the expected shape holds, or `Err(detail)`
/// when a run was skewed — compute times are tens of microseconds at
/// fixture scale, so a single OS preemption can distort one run's
/// virtual clocks. Intermediate failures are logged and retried;
/// exhaustion panics with the last detail. Shared by the serving benches
/// and the sharded TTFT property test.
pub fn retry_timing<T>(attempts: usize, mut f: impl FnMut() -> Result<T, String>) -> T {
    for attempt in 1..=attempts {
        match f() {
            Ok(v) => return v,
            Err(detail) => {
                assert!(
                    attempt < attempts,
                    "performance shape failed after {attempts} attempts: {detail}"
                );
                eprintln!("attempt {attempt}: {detail} (timing noise); retrying");
            }
        }
    }
    unreachable!("retry_timing returns or panics inside the loop");
}

/// Build the packed-vs-dense twin pair the quantized-serving equivalence
/// tests compare: a fixture model with every linear weight packed as
/// `fmt`, and its [`Transformer::dequantized`] f32 twin holding exactly
/// the values the packed codes decode to. Any divergence between serving
/// the two is a packed-kernel bug, not quantization error.
pub fn packed_twins(fmt: PackFormat, group: usize, seed: u64) -> (Transformer, Transformer) {
    let mut packed = super::fixtures::fixture_target(seed);
    let n = packed
        .pack_weights(&Selector::all(), fmt, group)
        .expect("fixture dims admit every pack format");
    assert!(n > 0, "fixture has linear weights to pack");
    let dense = packed.dequantized();
    (packed, dense)
}

/// Projected peak KV bytes the scheduler reserves for one greedy request
/// on `model`, for sizing admission budgets in tests and benches —
/// delegates to `GreedyExecutor::projected_bytes` so it can never drift
/// from the real reservation formula.
pub fn projected_greedy_bytes(model: &Transformer, r: &TokenRequest) -> usize {
    GreedyExecutor::new(model).projected_bytes(r)
}

/// Assert two serve reports completed the same request set with
/// bit-identical per-request outputs (ids aligned, same token bytes,
/// same generated counts). `context` names the pair under comparison in
/// the failure message (e.g. "continuous vs sequential", "workers=4").
#[track_caller]
pub fn assert_outputs_match(a: &ServeReport, b: &ServeReport, context: &str) {
    assert_eq!(
        a.completed.len(),
        b.completed.len(),
        "{context}: completed-request counts differ"
    );
    for (x, y) in a.completed.iter().zip(&b.completed) {
        assert_eq!(x.id, y.id, "{context}: completed ids misaligned");
        assert_eq!(
            x.output, y.output,
            "{context}: request {} output changed",
            x.id
        );
        assert_eq!(
            x.generated, y.generated,
            "{context}: request {} generated count changed",
            x.id
        );
    }
}

/// Assert the universal serving contracts on a **fault-free** report:
/// each of the `n` submitted requests completed exactly once on its first
/// attempt (no duplicates, no drops, no stray outcomes), every TTFT lies
/// in `[0, total]`, and — when `budget > 0` — peak live KV bytes stayed
/// within the admission budget. Chaos runs, where non-`Completed`
/// outcomes are expected, use [`assert_terminal_outcomes`] instead.
#[track_caller]
pub fn assert_serving_contracts(r: &ServeReport, n: usize, budget: usize) {
    assert_terminal_outcomes(r, n, budget);
    assert_eq!(r.goodput(), n, "a fault-free run completes every request");
    assert!(
        r.crashed_workers.is_empty(),
        "a fault-free run crashes no worker: {:?}",
        r.crashed_workers
    );
    for c in &r.completed {
        assert_eq!(
            c.attempts, 1,
            "request {}: fault-free serving is single-attempt",
            c.id
        );
    }
}

/// Assert the exactly-once fault-tolerance contract on any report, chaos
/// runs included: every one of the `n` submitted requests holds exactly
/// one terminal outcome (ids strictly increasing — no duplicates, no
/// drops), outcome bookkeeping is self-consistent, TTFTs lie in
/// `[0, total]`, and — when `budget > 0` — pool-wide peak live KV stayed
/// within the admission budget (faulted reservations must be released,
/// so injection never excuses an overshoot).
#[track_caller]
pub fn assert_terminal_outcomes(r: &ServeReport, n: usize, budget: usize) {
    assert_eq!(
        r.completed.len(),
        n,
        "every submitted request reaches a terminal outcome"
    );
    for w in r.completed.windows(2) {
        assert!(
            w[0].id < w[1].id,
            "terminal ids must be strictly increasing (duplicate id {}?)",
            w[1].id
        );
    }
    let counts = r.outcome_counts();
    assert_eq!(
        counts.completed + counts.failed + counts.deadline_exceeded + counts.shed,
        n,
        "outcome counts must partition the request set"
    );
    assert_eq!(counts.completed, r.goodput(), "goodput counts Completed outcomes");
    for c in &r.completed {
        assert!(c.ttft_ms >= 0.0, "request {}: ttft measured from arrival", c.id);
        assert!(
            c.ttft_ms <= c.total_ms + 1e-9,
            "request {}: ttft {} after terminal time {}",
            c.id,
            c.ttft_ms,
            c.total_ms
        );
        if c.is_completed() {
            assert!(
                c.attempts >= 1,
                "request {}: a completed request ran at least once",
                c.id
            );
        }
    }
    if budget > 0 {
        assert!(
            r.peak_kv_bytes <= budget,
            "peak live KV {} exceeded budget {budget}",
            r.peak_kv_bytes
        );
    }
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(16, |rng| {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn check_reports_failing_seed() {
        check(16, |rng| {
            assert!(rng.f32() < 0.5, "coin came up heads");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6);
    }
}
