//! Mini property-testing harness (proptest is not available offline).
//!
//! `check(seed_count, |rng| ...)` runs a property closure against many
//! seeded RNGs and reports the first failing seed, so failures reproduce
//! deterministically: re-run with `check_one(seed, ...)`.

use super::rng::Rng;

/// Run `prop` for `cases` deterministic seeds. Panics with the failing seed
/// on the first property violation (the closure should panic/assert).
pub fn check<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9));
    prop(&mut rng);
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(16, |rng| {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn check_reports_failing_seed() {
        check(16, |rng| {
            assert!(rng.f32() < 0.5, "coin came up heads");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6);
    }
}
