//! VQA-proxy evaluation for visual token pruning (Table 12): the scene's
//! class is decodable from the importance-weighted pool of its tokens; a
//! pruner is scored by whether the pooled representation of its kept subset
//! still classifies correctly (nearest prototype).

use crate::data::vision::{VisionScene, VisionSceneGen};
use crate::token_prune::{PruneContext, Pruner};

fn pooled(scene: &VisionScene, kept: &[usize]) -> Vec<f32> {
    let dim = scene.features[0].len();
    let mut out = vec![0.0f32; dim];
    let mut wsum = 0.0f32;
    for &i in kept {
        let w = scene.importance[i].max(0.01);
        wsum += w;
        for j in 0..dim {
            out[j] += scene.features[i][j] * w;
        }
    }
    for o in out.iter_mut() {
        *o /= wsum.max(1e-6);
    }
    out
}

fn classify(gen: &VisionSceneGen, emb: &[f32]) -> usize {
    let mut best = 0;
    let mut best_sim = f32::NEG_INFINITY;
    for (c, p) in gen.prototypes.iter().enumerate() {
        let s = crate::util::stats::cosine(emb, p);
        if s > best_sim {
            best_sim = s;
            best = c;
        }
    }
    best
}

/// Accuracy of a pruner at a retain ratio over `n_scenes` scenes.
/// `retain_ratio` = fraction of tokens kept (Table 12: 25% / 10%).
pub fn eval_pruner_accuracy(
    gen: &VisionSceneGen,
    pruner: &dyn Pruner,
    retain_ratio: f64,
    n_scenes: usize,
) -> f64 {
    let mut correct = 0usize;
    for i in 0..n_scenes {
        let scene = gen.scene(i as u64);
        let retain = ((scene.features.len() as f64 * retain_ratio).round() as usize).max(2);
        let ctx = PruneContext {
            features: &scene.features,
            importance: &scene.importance,
            retain,
        };
        let kept = pruner.apply(&ctx);
        let pred = classify(gen, &pooled(&scene, &kept));
        if pred == scene.label {
            correct += 1;
        }
    }
    correct as f64 / n_scenes as f64
}

/// Full-token baseline accuracy (the Table 12 "Baseline" row).
pub fn baseline_accuracy(gen: &VisionSceneGen, n_scenes: usize) -> f64 {
    let mut correct = 0usize;
    for i in 0..n_scenes {
        let scene = gen.scene(i as u64);
        let all: Vec<usize> = (0..scene.features.len()).collect();
        if classify(gen, &pooled(&scene, &all)) == scene.label {
            correct += 1;
        }
    }
    correct as f64 / n_scenes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::token_prune::visual::{FastV, IdPruner};

    #[test]
    fn baseline_is_strong() {
        let gen = VisionSceneGen::new(96, 24, 6, 0);
        let acc = baseline_accuracy(&gen, 60);
        assert!(acc > 0.6, "baseline acc {acc}");
    }

    #[test]
    fn pruning_degrades_gracefully_and_idpruner_competitive() {
        let gen = VisionSceneGen::new(96, 24, 6, 1);
        let base = baseline_accuracy(&gen, 60);
        let id25 = eval_pruner_accuracy(&gen, &IdPruner::default(), 0.25, 60);
        let id10 = eval_pruner_accuracy(&gen, &IdPruner::default(), 0.10, 60);
        // pruning noise tokens can even *help* slightly (seen on real
        // benchmarks too); it must not collapse, and harsher pruning must
        // not be better than milder pruning by much
        assert!(id10 <= id25 + 0.1, "harsher pruning should not help: {id10} vs {id25}");
        assert!(id25 > base - 0.3, "25% retention shouldn't collapse: {id25} vs {base}");
    }

    #[test]
    fn idpruner_at_least_matches_fastv() {
        let gen = VisionSceneGen::new(96, 24, 6, 2);
        let id = eval_pruner_accuracy(&gen, &IdPruner::default(), 0.1, 80);
        let fv = eval_pruner_accuracy(&gen, &FastV, 0.1, 80);
        assert!(id >= fv - 0.05, "idpruner {id} vs fastv {fv}");
    }
}
