//! LongBench-proxy evaluation for sparse attention (Table 11): each
//! long-context task plants a dependency; a sparse method scores by whether
//! the model still retrieves the answer when its attention is restricted
//! to the method's block mask.

use crate::data::longctx::{build, LongCtxTaskKind};
use crate::models::{AttnOverride, Transformer};
use crate::sparse_attn::SparseAlgo;
use crate::tensor::ops::argmax;

/// Per-task-family accuracy of one sparse algorithm.
#[derive(Clone, Debug)]
pub struct SparseEvalRow {
    pub algo: SparseAlgo,
    /// per task family: 0.5·evidence-retention + 0.5·dense-output agreement
    pub per_task: Vec<(LongCtxTaskKind, f64)>,
    pub avg: f64,
    pub mean_density: f64,
}

/// Evaluate a sparse algorithm on the long-context suite.
///
/// The mask is estimated once per example from layer-0 Q/K/V (head 0) —
/// the paper's "metadata-driven" single-pattern configuration — then
/// applied to every layer of the forward pass.
///
/// Score per example (both components graded, model-and-task-grounded):
/// * **evidence retention** — fraction of the task's planted evidence
///   positions whose blocks stay visible from the final query block. On
///   real benchmarks this is exactly what separates sparse methods:
///   dropping the needle's block loses the answer.
/// * **output agreement** — whether the masked forward reproduces the
///   dense forward's prediction at the final position (sparse attention is
///   "training-free": its contract is preserving the dense model's output).
pub fn eval_sparse_accuracy(
    model: &Transformer,
    algo: SparseAlgo,
    seq_len: usize,
    samples_per_task: usize,
    block: usize,
    budget: f64,
) -> SparseEvalRow {
    let mut per_task = Vec::new();
    let mut density_sum = 0.0;
    let mut density_n = 0usize;
    for kind in LongCtxTaskKind::all() {
        let mut score = 0.0f64;
        for s in 0..samples_per_task {
            let task = build(kind, seq_len, s as u64 * 31 + 7);
            let tokens = &task.tokens[..task.tokens.len().min(model.cfg.max_t)];
            let dense_pred = {
                let l = model.forward(tokens, &AttnOverride::None);
                argmax(l.row(l.rows() - 1))
            };

            if algo == SparseAlgo::Dense {
                score += 1.0;
                continue;
            }
            // estimate the pattern from layer-0 q/k/v metadata
            let qkv = model.capture_qk(tokens);
            let (q, k, v) = &qkv[0];
            let mask = algo.mask(q, k, v, block, budget);
            density_sum += mask.density();
            density_n += 1;

            // evidence retention from the final query block
            let qb = (tokens.len() - 1) / block;
            let ev_total = task
                .evidence_positions
                .iter()
                .filter(|&&p| p < tokens.len())
                .count()
                .max(1);
            let ev_kept = task
                .evidence_positions
                .iter()
                .filter(|&&p| p < tokens.len() && mask.get(qb, p / block))
                .count();
            let retention = ev_kept as f64 / ev_total as f64;

            // dense-output agreement under the mask
            let l = model.forward(tokens, &AttnOverride::Mask(mask.to_token_mask()));
            let agree = (argmax(l.row(l.rows() - 1)) == dense_pred) as u32 as f64;

            score += 0.5 * retention + 0.5 * agree;
        }
        per_task.push((kind, score / samples_per_task as f64));
    }
    let avg = per_task.iter().map(|t| t.1).sum::<f64>() / per_task.len() as f64;
    SparseEvalRow {
        algo,
        per_task,
        avg,
        mean_density: if density_n == 0 { 1.0 } else { density_sum / density_n as f64 },
    }
}

/// Attention-mass recall: fraction of the dense attention probability mass
/// a mask retains, averaged over query positions — a model-free quality
/// metric for pattern estimators.
pub fn attention_mass_recall(
    q: &crate::tensor::Tensor,
    k: &crate::tensor::Tensor,
    mask: &crate::sparse_attn::BlockMask,
) -> f64 {
    let t = q.rows();
    let dh = q.cols();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut total_recall = 0.0f64;
    for qi in 0..t {
        let mut dense_sum = 0.0f64;
        let mut kept_sum = 0.0f64;
        let mut maxs = f32::NEG_INFINITY;
        let scores: Vec<f32> = (0..=qi)
            .map(|ki| {
                let s = crate::tensor::ops::dot(q.row(qi), k.row(ki)) * scale;
                maxs = maxs.max(s);
                s
            })
            .collect();
        for (ki, &s) in scores.iter().enumerate() {
            let p = ((s - maxs).exp()) as f64;
            dense_sum += p;
            if mask.get(qi / mask.block, ki / mask.block) {
                kept_sum += p;
            }
        }
        total_recall += kept_sum / dense_sum.max(1e-12);
    }
    total_recall / t as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse_attn::BlockMask;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn dense_mask_recall_is_one() {
        let mut rng = Rng::new(0);
        let q = Tensor::randn(&[64, 16], 0.3, &mut rng);
        let k = Tensor::randn(&[64, 16], 0.3, &mut rng);
        let m = BlockMask::dense(64, 16);
        let r = attention_mass_recall(&q, &k, &m);
        assert!((r - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stem_recall_beats_diagonal_only() {
        let mut rng = Rng::new(1);
        let q = Tensor::randn(&[128, 16], 0.3, &mut rng);
        let k = Tensor::randn(&[128, 16], 0.3, &mut rng);
        let v = Tensor::randn(&[128, 16], 0.3, &mut rng);
        let stem = crate::sparse_attn::stem(&q, &k, &v, 16, 0.4,
            &crate::sparse_attn::StemCfg::default());
        let mut diag = BlockMask::empty(128, 16);
        diag.ensure_diagonal();
        let r_stem = attention_mass_recall(&q, &k, &stem);
        let r_diag = attention_mass_recall(&q, &k, &diag);
        assert!(r_stem > r_diag, "{r_stem} vs {r_diag}");
    }
}
