//! ASR-proxy WER evaluation for audio token reduction (Table 13): decode
//! each reduced token to its nearest phoneme centroid, run-length-collapse
//! the sequence, and compute word-error-rate (edit distance) against the
//! scene's ground-truth transcript. Over-merging deletes phonemes;
//! importance-blind pruning garbles them — exactly the failure modes real
//! ASR benchmarks punish.

use crate::data::audio::AudioSceneGen;
use crate::token_prune::{PruneContext, Reducer};

/// Levenshtein distance between two sequences.
pub fn edit_distance(a: &[usize], b: &[usize]) -> usize {
    let (n, m) = (a.len(), b.len());
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        for j in 1..=m {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// WER in percent.
pub fn wer(hyp: &[usize], truth: &[usize]) -> f64 {
    100.0 * edit_distance(hyp, truth) as f64 / truth.len().max(1) as f64
}

fn decode(gen: &AudioSceneGen, feature: &[f32]) -> usize {
    let mut best = 0;
    let mut best_sim = f32::NEG_INFINITY;
    for (p, c) in gen.centroids.iter().enumerate() {
        let s = crate::util::stats::cosine(feature, c);
        if s > best_sim {
            best_sim = s;
            best = p;
        }
    }
    best
}

/// Mean WER of a reducer at a retain ratio over `n_scenes` scenes.
pub fn eval_wer(
    gen: &AudioSceneGen,
    reducer: &dyn Reducer,
    retain_ratio: f64,
    n_scenes: usize,
    frames: usize,
) -> f64 {
    let mut total = 0.0f64;
    for i in 0..n_scenes {
        let scene = gen.scene(i as u64, frames);
        let retain = ((frames as f64 * retain_ratio).round() as usize).max(2);
        let ctx = PruneContext {
            features: &scene.features,
            importance: &scene.attention,
            retain,
        };
        let reduced = reducer.reduce(&ctx);
        let mut hyp: Vec<usize> = reduced.iter().map(|t| decode(gen, &t.feature)).collect();
        hyp.dedup(); // run-length collapse
        total += wer(&hyp, &scene.transcript);
    }
    total / n_scenes as f64
}

/// Full-token reference WER (decoding every frame).
pub fn baseline_wer(gen: &AudioSceneGen, n_scenes: usize, frames: usize) -> f64 {
    let mut total = 0.0f64;
    for i in 0..n_scenes {
        let scene = gen.scene(i as u64, frames);
        let mut hyp: Vec<usize> =
            scene.features.iter().map(|f| decode(gen, f)).collect();
        hyp.dedup();
        total += wer(&hyp, &scene.transcript);
    }
    total / n_scenes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::token_prune::audio::{AToMe, Samp};

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1);
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
        assert_eq!(edit_distance(&[1, 2], &[2, 1]), 2);
    }

    #[test]
    fn baseline_wer_low() {
        let gen = AudioSceneGen::new(24, 24, 0.1, 0);
        let w = baseline_wer(&gen, 20, 150);
        assert!(w < 10.0, "baseline WER {w}");
    }

    #[test]
    fn samp_beats_pure_merge_at_aggressive_compression() {
        let gen = AudioSceneGen::new(24, 24, 0.12, 1);
        let samp = eval_wer(&gen, &Samp::default(), 0.6, 25, 150);
        let atome = eval_wer(&gen, &AToMe, 0.6, 25, 150);
        assert!(
            samp <= atome + 2.0,
            "samp {samp} should be competitive with a-tome {atome}"
        );
    }

    #[test]
    fn heavier_compression_hurts() {
        let gen = AudioSceneGen::new(24, 24, 0.1, 2);
        let mild = eval_wer(&gen, &Samp::default(), 0.7, 20, 150);
        let harsh = eval_wer(&gen, &Samp::default(), 0.3, 20, 150);
        assert!(harsh >= mild - 1.0, "mild {mild} harsh {harsh}");
    }
}
