//! Evaluation suites — the "automated benchmarking" layer of the paper:
//! perplexity + task accuracy for quantization (Tables 1, 4-6), the
//! LongBench-proxy suite for sparse attention (Table 11), the VQA-proxy
//! for visual pruning (Table 12) and the ASR-proxy WER for audio reduction
//! (Table 13).

pub mod asr;
pub mod longbench;
pub mod perplexity;
pub mod vqa;

pub use asr::{eval_wer, wer};
pub use longbench::eval_sparse_accuracy;
pub use perplexity::{corpus_nll, task_accuracy};
pub use vqa::eval_pruner_accuracy;
