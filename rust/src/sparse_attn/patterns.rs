//! Sparse-attention pattern generators: static heuristics + dynamic
//! estimators (§4.1.1). All produce `BlockMask` metadata at a target
//! density budget (fraction of causal blocks kept).

use crate::tensor::{ops::dot, Tensor};

use super::mask::BlockMask;

// --------------------------------------------------------------------------
// static patterns
// --------------------------------------------------------------------------

/// A-shape: attention sinks (first blocks) + local window. The window
/// width is chosen to hit the budget.
pub fn a_shape(t: usize, block: usize, budget: f64) -> BlockMask {
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    let sink = 1usize;
    let target = (budget * m.causal_total() as f64).ceil() as usize;
    // blocks used: nb sinks + window*(nb) approx — solve window
    let window = ((target.saturating_sub(nb)) as f64 / nb as f64).ceil() as usize;
    for qi in 0..nb {
        for s in 0..sink.min(qi + 1) {
            m.set(qi, s, true);
        }
        for w in 0..=window {
            m.set(qi, qi.saturating_sub(w), true);
        }
    }
    m.ensure_diagonal();
    m
}

/// Tri-shape: A-shape + a dense band of final query rows (the "recent
/// queries see everything" triangle).
pub fn tri_shape(t: usize, block: usize, budget: f64) -> BlockMask {
    let mut m = a_shape(t, block, budget * 0.7);
    let nb = m.nb;
    // last rows dense until budget is spent
    let target = (budget * m.causal_total() as f64).ceil() as usize;
    let mut qi = nb;
    while m.kept() < target && qi > 0 {
        qi -= 1;
        for ki in 0..=qi {
            m.set(qi, ki, true);
        }
    }
    m
}

/// Dilated: keep every d-th block diagonal stripe.
pub fn dilated(t: usize, block: usize, budget: f64) -> BlockMask {
    let mut m = BlockMask::empty(t, block);
    let stride = (1.0 / budget.max(1e-3)).round().max(1.0) as usize;
    for qi in 0..m.nb {
        for ki in (0..=qi).rev() {
            let dist = qi - ki;
            if dist % stride == 0 {
                m.set(qi, ki, true);
            }
        }
    }
    m.ensure_diagonal();
    m
}

/// Strided: local window + periodic global columns.
pub fn strided(t: usize, block: usize, budget: f64) -> BlockMask {
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    let stride = ((nb as f64) / (budget * nb as f64).max(1.0)).ceil() as usize;
    for qi in 0..nb {
        m.set(qi, qi, true);
        if qi > 0 {
            m.set(qi, qi - 1, true);
        }
        for ki in (0..=qi).step_by(stride.max(1)) {
            m.set(qi, ki, true);
        }
    }
    m
}

// --------------------------------------------------------------------------
// dynamic estimators (consume per-head q, k [t, dh])
// --------------------------------------------------------------------------

/// Mean attention score between a sampled set of q rows in block qb and
/// all k rows in block kb.
fn block_score(q: &Tensor, k: &Tensor, qb: usize, kb: usize, block: usize) -> f32 {
    let t = q.rows();
    let q_lo = qb * block;
    let q_hi = ((qb + 1) * block).min(t);
    let k_lo = kb * block;
    let k_hi = ((kb + 1) * block).min(t);
    let mut s = 0.0f32;
    let mut n = 0;
    // sample every 4th row for speed (pattern computation must be cheap)
    for qi in (q_lo..q_hi).step_by(4) {
        for ki in (k_lo..k_hi).step_by(4) {
            if ki <= qi {
                s += dot(q.row(qi), k.row(ki)).exp().min(1e6);
                n += 1;
            }
        }
    }
    if n == 0 {
        0.0
    } else {
        s / n as f32
    }
}

/// MInference-style vertical-slash: estimate from the *last* q block which
/// kv columns (vertical lines) and which diagonals (slashes) carry mass;
/// keep the top ones within budget.
pub fn minference(q: &Tensor, k: &Tensor, block: usize, budget: f64) -> BlockMask {
    let t = q.rows();
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    let target = (budget * m.causal_total() as f64).ceil() as usize;

    // vertical scores: importance of each kv block to the last q block
    let last_qb = nb - 1;
    let mut vertical: Vec<(usize, f32)> = (0..nb)
        .map(|kb| (kb, block_score(q, k, last_qb, kb, block)))
        .collect();
    vertical.sort_by(|a, b| b.1.total_cmp(&a.1));
    // slash scores: strength of each diagonal offset (sampled rows)
    let mut slash: Vec<(usize, f32)> = (0..nb)
        .map(|off| {
            let mut s = 0.0;
            let mut n = 0;
            for qb in off..nb {
                s += block_score(q, k, qb, qb - off, block);
                n += 1;
            }
            (off, if n == 0 { 0.0 } else { s / n as f32 })
        })
        .collect();
    slash.sort_by(|a, b| b.1.total_cmp(&a.1));

    // alternate verticals and slashes until the budget is filled
    let mut vi = 0;
    let mut si = 0;
    while m.kept() < target && (vi < vertical.len() || si < slash.len()) {
        if si < slash.len() && (vi >= vertical.len() || si <= vi) {
            let off = slash[si].0;
            for qb in off..nb {
                m.set(qb, qb - off, true);
            }
            si += 1;
        } else {
            let kb = vertical[vi].0;
            for qb in kb..nb {
                m.set(qb, kb, true);
            }
            vi += 1;
        }
    }
    m.ensure_diagonal();
    m
}

/// XAttention-style antidiagonal scoring: each block is scored by strided
/// antidiagonal samples of q·k (cheap but unbiased across the block);
/// top-scoring blocks are kept per query row.
pub fn xattention(q: &Tensor, k: &Tensor, block: usize, budget: f64) -> BlockMask {
    let t = q.rows();
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    for qb in 0..nb {
        let causal = qb + 1;
        let keep_n = ((budget * causal as f64).ceil() as usize).clamp(1, causal);
        let mut scores: Vec<(usize, f32)> = (0..causal)
            .map(|kb| {
                // antidiagonal sampling inside the block
                let q_lo = qb * block;
                let k_lo = kb * block;
                let mut s = 0.0f32;
                let mut n = 0;
                for d in (0..block).step_by(2) {
                    let qi = q_lo + d;
                    let ki = k_lo + (block - 1 - d);
                    if qi < t && ki < t && ki <= qi {
                        s += dot(q.row(qi), k.row(ki)).exp().min(1e6);
                        n += 1;
                    }
                }
                (kb, if n == 0 { 0.0 } else { s / n as f32 })
            })
            .collect();
        scores.sort_by(|a, b| b.1.total_cmp(&a.1));
        for &(kb, _) in scores.iter().take(keep_n) {
            m.set(qb, kb, true);
        }
    }
    m.ensure_diagonal();
    m
}

/// FlexPrefill-style query-aware cumulative-mass selection: per query
/// block, keep the smallest block set whose estimated attention mass
/// reaches the budget-implied coverage τ.
pub fn flexprefill(q: &Tensor, k: &Tensor, block: usize, budget: f64) -> BlockMask {
    let t = q.rows();
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    let tau = (0.5 + 0.5 * budget).min(0.99); // coverage target
    for qb in 0..nb {
        let causal = qb + 1;
        let mut scores: Vec<(usize, f32)> = (0..causal)
            .map(|kb| (kb, block_score(q, k, qb, kb, block)))
            .collect();
        let total: f32 = scores.iter().map(|s| s.1).sum::<f32>().max(1e-12);
        scores.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut mass = 0.0f32;
        for &(kb, s) in &scores {
            if mass / total >= tau as f32 {
                break;
            }
            m.set(qb, kb, true);
            mass += s;
        }
    }
    m.ensure_diagonal();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn qk(t: usize, dh: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            Tensor::randn(&[t, dh], 0.3, &mut rng),
            Tensor::randn(&[t, dh], 0.3, &mut rng),
        )
    }

    #[test]
    fn static_patterns_hit_budget_roughly() {
        for budget in [0.3, 0.5] {
            for f in [a_shape, tri_shape, dilated, strided] {
                let m = f(256, 16, budget);
                let d = m.density();
                assert!(
                    d > budget * 0.4 && d < budget * 2.5 + 0.2,
                    "density {d} for budget {budget}"
                );
            }
        }
    }

    #[test]
    fn a_shape_keeps_sink_and_local() {
        let m = a_shape(256, 16, 0.3);
        let nb = m.nb;
        for qi in 0..nb {
            assert!(m.get(qi, 0), "sink kept");
            assert!(m.get(qi, qi), "diagonal kept");
        }
    }

    #[test]
    fn tri_shape_last_row_dense() {
        let m = tri_shape(256, 16, 0.5);
        let nb = m.nb;
        for ki in 0..nb {
            assert!(m.get(nb - 1, ki), "last row must be dense");
        }
    }

    #[test]
    fn dynamic_estimators_respect_causality_and_diag() {
        let (q, k) = qk(128, 16, 0);
        for f in [minference, xattention, flexprefill] {
            let m = f(&q, &k, 16, 0.4);
            for qb in 0..m.nb {
                assert!(m.get(qb, qb));
                for kb in qb + 1..m.nb {
                    assert!(!m.get(qb, kb), "acausal block kept");
                }
            }
        }
    }

    #[test]
    fn estimators_find_planted_column() {
        // make kv block 1 highly attractive to all queries
        let (mut q, mut k) = qk(128, 16, 1);
        for ki in 16..32 {
            for j in 0..16 {
                k.row_mut(ki)[j] = 2.0;
            }
        }
        for qi in 0..128 {
            for j in 0..16 {
                q.row_mut(qi)[j] = q.row(qi)[j].abs();
            }
        }
        for (name, f) in [
            ("minf", minference as fn(&Tensor, &Tensor, usize, f64) -> BlockMask),
            ("xattn", xattention),
            ("flex", flexprefill),
        ] {
            let m = f(&q, &k, 16, 0.35);
            // most query blocks >= 1 should keep kv block 1
            let kept = (1..m.nb).filter(|&qb| m.get(qb, 1)).count();
            assert!(kept * 2 >= m.nb - 1, "{name} kept planted column {kept}/{}", m.nb - 1);
        }
    }
}
