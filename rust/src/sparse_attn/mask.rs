//! Block-mask metadata: the interchange format between pattern algorithms
//! and sparse kernels (the paper's "metadata-driven configuration system").

#[derive(Clone, Debug, PartialEq)]
pub struct BlockMask {
    pub t: usize,
    pub block: usize,
    pub nb: usize,
    /// row-major [nb, nb]; only the causal lower triangle is meaningful
    pub keep: Vec<bool>,
}

impl BlockMask {
    pub fn empty(t: usize, block: usize) -> Self {
        let nb = t.div_ceil(block);
        BlockMask { t, block, nb, keep: vec![false; nb * nb] }
    }

    pub fn dense(t: usize, block: usize) -> Self {
        let nb = t.div_ceil(block);
        let mut m = BlockMask { t, block, nb, keep: vec![false; nb * nb] };
        for qi in 0..nb {
            for ki in 0..=qi {
                m.set(qi, ki, true);
            }
        }
        m
    }

    #[inline]
    pub fn get(&self, qb: usize, kb: usize) -> bool {
        self.keep[qb * self.nb + kb]
    }

    #[inline]
    pub fn set(&mut self, qb: usize, kb: usize, v: bool) {
        // never keep acausal blocks
        if kb <= qb {
            self.keep[qb * self.nb + kb] = v;
        }
    }

    /// Number of kept causal blocks.
    pub fn kept(&self) -> usize {
        let mut n = 0;
        for qi in 0..self.nb {
            for ki in 0..=qi {
                if self.get(qi, ki) {
                    n += 1;
                }
            }
        }
        n
    }

    /// Total causal blocks.
    pub fn causal_total(&self) -> usize {
        self.nb * (self.nb + 1) / 2
    }

    /// Fraction of causal blocks kept.
    pub fn density(&self) -> f64 {
        self.kept() as f64 / self.causal_total() as f64
    }

    /// Force the diagonal (every query must see its own block — avoids
    /// fully-masked rows).
    pub fn ensure_diagonal(&mut self) {
        for i in 0..self.nb {
            self.set(i, i, true);
        }
    }

    /// Expand to a token-level [t, t] keep mask (combined with causality by
    /// the consumer).
    pub fn to_token_mask(&self) -> Vec<bool> {
        let mut m = vec![false; self.t * self.t];
        for qi in 0..self.t {
            for ki in 0..=qi {
                m[qi * self.t + ki] = self.get(qi / self.block, ki / self.block);
            }
        }
        m
    }

    /// As f32 (the Pallas kernel artifact's mask input).
    pub fn to_f32(&self) -> Vec<f32> {
        self.keep.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()
    }

    /// Union with another mask.
    pub fn union(&mut self, other: &BlockMask) {
        assert_eq!(self.keep.len(), other.keep.len());
        for (a, b) in self.keep.iter_mut().zip(&other.keep) {
            *a |= b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mask_full_causal() {
        let m = BlockMask::dense(64, 16);
        assert_eq!(m.nb, 4);
        assert_eq!(m.kept(), 10);
        assert_eq!(m.density(), 1.0);
        assert!(m.get(3, 0) && m.get(0, 0));
    }

    #[test]
    fn set_refuses_acausal() {
        let mut m = BlockMask::empty(64, 16);
        m.set(0, 3, true);
        assert!(!m.get(0, 3));
        m.set(3, 0, true);
        assert!(m.get(3, 0));
    }

    #[test]
    fn token_mask_expansion() {
        let mut m = BlockMask::empty(32, 16);
        m.ensure_diagonal();
        let tm = m.to_token_mask();
        assert!(tm[0]); // (0,0)
        assert!(tm[17 * 32 + 16]); // (17,16) in diag block (1,1)
        assert!(!tm[17 * 32 + 2]); // (17,2) in dropped block (1,0)
    }

    #[test]
    fn density_partial() {
        let mut m = BlockMask::empty(64, 16);
        m.ensure_diagonal();
        assert_eq!(m.kept(), 4);
        assert!((m.density() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn union_merges() {
        let mut a = BlockMask::empty(32, 16);
        a.set(1, 0, true);
        let mut b = BlockMask::empty(32, 16);
        b.set(1, 1, true);
        a.union(&b);
        assert!(a.get(1, 0) && a.get(1, 1));
    }
}
