//! Stem — position-aware, output-aware sparse prefill (paper §4.1.2).
//!
//! Two ideas on top of uniform top-k block selection:
//!
//! * **Token Position-Decay (TPD)** — early tokens are "recursive anchors"
//!   that many later tokens depend on; they get higher retention stability.
//!   The per-query-block budget is allocated non-uniformly: each kv block's
//!   effective score is boosted by a decay factor that favours early
//!   positions, so anchors survive even under aggressive global sparsity.
//!
//! * **Output-Aware Metric (OAM)** — selection weighs attention affinity by
//!   the *value-state contribution* ‖V_block‖: a block with high scores but
//!   weak value signal distorts the output less than its score suggests,
//!   and vice versa. OAM ranks blocks by score × value-norm.

use crate::tensor::{ops::dot, Tensor};

use super::mask::BlockMask;

#[derive(Clone, Debug)]
pub struct StemCfg {
    /// TPD decay rate: anchor boost = 1 + tpd_strength * exp(-pos/tau)
    pub tpd_strength: f32,
    /// decay horizon as a fraction of the sequence (in blocks)
    pub tpd_tau_frac: f32,
    /// weight of the value-norm term in OAM (0 = plain attention scores)
    pub oam_weight: f32,
}

impl Default for StemCfg {
    fn default() -> Self {
        StemCfg { tpd_strength: 2.0, tpd_tau_frac: 0.15, oam_weight: 1.0 }
    }
}

/// OAM block score: mean sampled attention score × (value norm)^oam_weight.
fn oam_score(
    q: &Tensor,
    k: &Tensor,
    vnorm: &[f32],
    qb: usize,
    kb: usize,
    block: usize,
    cfg: &StemCfg,
) -> f32 {
    let t = q.rows();
    let q_lo = qb * block;
    let q_hi = ((qb + 1) * block).min(t);
    let k_lo = kb * block;
    let k_hi = ((kb + 1) * block).min(t);
    // max-pooled affinity: retrieval spikes (a needle's key matching the
    // query) must not be diluted by averaging over a mostly-flat block
    let mut best = f32::NEG_INFINITY;
    let mut any = false;
    for qi in (q_lo..q_hi).step_by(2) {
        for ki in (k_lo..k_hi).step_by(2) {
            if ki <= qi {
                best = best.max(dot(q.row(qi), k.row(ki)));
                any = true;
            }
        }
    }
    let attn = if any { best.exp().min(1e6) } else { 0.0 };
    attn * vnorm[kb].powf(cfg.oam_weight)
}

/// Build the Stem block mask.
pub fn stem(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    budget: f64,
    cfg: &StemCfg,
) -> BlockMask {
    let t = q.rows();
    let mut m = BlockMask::empty(t, block);
    let nb = m.nb;
    let tau = (cfg.tpd_tau_frac * nb as f32).max(1.0);

    // per-kv-block mean value norm (the OAM contribution term)
    let mut vnorm = vec![0.0f32; nb];
    for kb in 0..nb {
        let lo = kb * block;
        let hi = ((kb + 1) * block).min(t);
        let mut s = 0.0;
        for r in lo..hi {
            s += v.row(r).iter().map(|x| x * x).sum::<f32>().sqrt();
        }
        vnorm[kb] = s / (hi - lo).max(1) as f32 + 1e-6;
    }

    for qb in 0..nb {
        let causal = qb + 1;
        // per-row budget matches the uniform baselines; TPD redistributes
        // *within* the row toward early-KV anchors instead of shrinking it
        let keep_n = ((budget * causal as f64).ceil() as usize).clamp(1, causal);

        let mut scores: Vec<(usize, f32)> = (0..causal)
            .map(|kb| {
                let base = oam_score(q, k, &vnorm, qb, kb, block, cfg);
                // TPD: early kv blocks are "recursive anchors" with boosted
                // retention stability; the boost decays toward later kv
                // positions where redundancy is typically higher
                let anchor = 1.0 + cfg.tpd_strength * (-(kb as f32) / tau).exp();
                (kb, base * anchor)
            })
            .collect();
        scores.sort_by(|a, b| b.1.total_cmp(&a.1));
        for &(kb, _) in scores.iter().take(keep_n) {
            m.set(qb, kb, true);
        }
        // local window: the diagonal neighbourhood is always causally hot
        if qb > 0 {
            m.set(qb, qb - 1, true);
        }
    }
    m.ensure_diagonal();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse_attn::patterns::xattention;
    use crate::util::Rng;

    fn qkv(t: usize, dh: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            Tensor::randn(&[t, dh], 0.3, &mut rng),
            Tensor::randn(&[t, dh], 0.3, &mut rng),
            Tensor::randn(&[t, dh], 0.5, &mut rng),
        )
    }

    #[test]
    fn keeps_early_anchors() {
        let (q, k, v) = qkv(256, 16, 0);
        let m = stem(&q, &k, &v, 16, 0.3, &StemCfg::default());
        // kv block 0 (anchor) kept by almost all query blocks
        let kept0 = (0..m.nb).filter(|&qb| m.get(qb, 0)).count();
        assert!(kept0 as f64 >= 0.8 * m.nb as f64, "anchors kept {kept0}/{}", m.nb);
    }

    #[test]
    fn uniform_baseline_drops_anchors_more() {
        let (q, k, v) = qkv(256, 16, 1);
        let stem_m = stem(&q, &k, &v, 16, 0.25, &StemCfg::default());
        let uni_m = xattention(&q, &k, 16, 0.25);
        let anchors_stem = (0..stem_m.nb).filter(|&qb| stem_m.get(qb, 0)).count();
        let anchors_uni = (0..uni_m.nb).filter(|&qb| uni_m.get(qb, 0)).count();
        assert!(
            anchors_stem >= anchors_uni,
            "stem {anchors_stem} vs uniform {anchors_uni}"
        );
    }

    #[test]
    fn oam_downweights_weak_values() {
        let (q, k, mut v) = qkv(128, 16, 2);
        // kv block 2 has near-zero values: high-score-low-value trap
        for r in 32..48 {
            for j in 0..16 {
                v.row_mut(r)[j] = 1e-4;
            }
        }
        let m = stem(&q, &k, &v, 16, 0.4, &StemCfg::default());
        let m0 = stem(&q, &k, &v, 16, 0.4, &StemCfg { oam_weight: 0.0, ..Default::default() });
        let kept_oam = (2..m.nb).filter(|&qb| m.get(qb, 2)).count();
        let kept_plain = (2..m0.nb).filter(|&qb| m0.get(qb, 2)).count();
        assert!(kept_oam <= kept_plain, "oam {kept_oam} vs plain {kept_plain}");
    }

    #[test]
    fn density_near_budget() {
        let (q, k, v) = qkv(256, 16, 3);
        for budget in [0.2, 0.4, 0.6] {
            let m = stem(&q, &k, &v, 16, budget, &StemCfg::default());
            let d = m.density();
            // ceil-per-row + the local window add a small density floor
            assert!(d > budget * 0.6 && d < budget * 1.6 + 0.25, "{d} vs {budget}");
        }
    }
}
