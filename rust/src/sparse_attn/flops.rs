//! Analytical FLOP accounting for sparse prefill attention — the compute
//! model behind the latency columns of Figure 11 (measured wall-clock of
//! the masked kernels is reported alongside).

use super::mask::BlockMask;

/// FLOPs for one head of dense causal prefill attention at length t:
/// scores (2·t·(t+1)/2·dh) + softmax (~5 per score) + weighted sum (same as
/// scores).
pub fn attn_flops(t: usize, dh: usize) -> f64 {
    let pairs = (t * (t + 1) / 2) as f64;
    pairs * (2.0 * dh as f64) * 2.0 + pairs * 5.0
}

/// FLOPs under a block mask: only kept blocks pay the score/value cost;
/// add the pattern-estimation overhead (sampled scores).
pub fn masked_attn_flops(mask: &BlockMask, dh: usize, estimation_samples: usize) -> f64 {
    let per_block = (mask.block * mask.block) as f64 * (2.0 * dh as f64) * 2.0
        + (mask.block * mask.block) as f64 * 5.0;
    mask.kept() as f64 * per_block + estimation_samples as f64 * 2.0 * dh as f64
}

/// Speedup of a mask vs dense (pure compute model).
pub fn speedup(mask: &BlockMask, dh: usize, estimation_samples: usize) -> f64 {
    attn_flops(mask.t, dh) / masked_attn_flops(mask, dh, estimation_samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mask_speedup_near_one() {
        let m = BlockMask::dense(256, 16);
        let s = speedup(&m, 32, 0);
        assert!((0.8..1.3).contains(&s), "{s}");
    }

    #[test]
    fn quarter_density_speeds_up() {
        let mut m = BlockMask::empty(256, 16);
        m.ensure_diagonal();
        for qb in 0..m.nb {
            m.set(qb, 0, true);
        }
        let s = speedup(&m, 32, 0);
        assert!(s > 3.0, "{s}");
    }

    #[test]
    fn estimation_overhead_reduces_speedup() {
        let mut m = BlockMask::empty(256, 16);
        m.ensure_diagonal();
        let cheap = speedup(&m, 32, 0);
        let pricey = speedup(&m, 32, 100_000);
        assert!(pricey < cheap);
    }
}
