//! Training-free sparse attention for long-context prefill — pillar 3 of
//! the paper (§4.1).
//!
//! The framework follows the paper's decoupling: *pattern computation*
//! (this module — static A-shape/Tri-shape/dilated/strided heuristics and
//! dynamic MInference / XAttention / FlexPrefill / Stem estimators) emits a
//! `BlockMask` as metadata; *sparse execution* consumes it — either the
//! Pallas block-sparse kernel artifact (runtime::AttnExecutable) or the
//! pure-Rust transformer's `AttnOverride::Mask`.

pub mod flops;
pub mod mask;
pub mod patterns;
pub mod stem;

pub use flops::attn_flops;
pub use mask::BlockMask;
pub use patterns::{
    a_shape, dilated, flexprefill, minference, strided, tri_shape, xattention,
};
pub use stem::{stem, StemCfg};

use crate::tensor::Tensor;

/// A dynamic sparse-attention algorithm: estimates a block mask from
/// (per-head) Q, K, V at prefill time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SparseAlgo {
    Dense,
    AShape,
    TriShape,
    Dilated,
    Strided,
    MInference,
    XAttention,
    FlexPrefill,
    Stem,
}

impl SparseAlgo {
    pub fn all_dynamic() -> [SparseAlgo; 4] {
        [
            SparseAlgo::MInference,
            SparseAlgo::XAttention,
            SparseAlgo::FlexPrefill,
            SparseAlgo::Stem,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            SparseAlgo::Dense => "Dense",
            SparseAlgo::AShape => "A-shape",
            SparseAlgo::TriShape => "Tri-shape",
            SparseAlgo::Dilated => "Dilated",
            SparseAlgo::Strided => "Strided",
            SparseAlgo::MInference => "MINF",
            SparseAlgo::XAttention => "XATTN",
            SparseAlgo::FlexPrefill => "FLEX",
            SparseAlgo::Stem => "Stem",
        }
    }

    /// Build the block mask for one head's (q, k, v), each [t, dh], at the
    /// given density budget (fraction of causal blocks kept).
    pub fn mask(&self, q: &Tensor, k: &Tensor, v: &Tensor, block: usize, budget: f64) -> BlockMask {
        let t = q.rows();
        match self {
            SparseAlgo::Dense => BlockMask::dense(t, block),
            SparseAlgo::AShape => a_shape(t, block, budget),
            SparseAlgo::TriShape => tri_shape(t, block, budget),
            SparseAlgo::Dilated => dilated(t, block, budget),
            SparseAlgo::Strided => strided(t, block, budget),
            SparseAlgo::MInference => minference(q, k, block, budget),
            SparseAlgo::XAttention => xattention(q, k, block, budget),
            SparseAlgo::FlexPrefill => flexprefill(q, k, block, budget),
            SparseAlgo::Stem => stem(q, k, v, block, budget, &StemCfg::default()),
        }
    }
}
