//! Synthetic classification tasks for the QAT benches — a graded,
//! deterministic stand-in for the paper's zero-shot suites (PIQA, ARC,
//! HellaSwag, ...). Each named task is a different nonlinear decision
//! structure so the Table 2 bench can report a row of per-task accuracies.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ClassTask {
    pub name: &'static str,
    pub dim: usize,
    pub classes: usize,
    /// class prototype directions
    protos: Vec<Vec<f32>>,
    /// task-specific nonlinearity selector
    kind: usize,
    noise: f32,
    seed: u64,
}

impl ClassTask {
    /// The five tasks of the Table 2 analogue.
    pub fn suite(dim: usize, seed: u64) -> Vec<ClassTask> {
        ["piqa-s", "arc-e-s", "arc-c-s", "hels-s", "wing-s"]
            .into_iter()
            .enumerate()
            .map(|(i, name)| ClassTask::new(name, dim, 4 + (i % 2) * 4, i, seed + i as u64))
            .collect()
    }

    pub fn new(name: &'static str, dim: usize, classes: usize, kind: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7A5C);
        let protos = (0..classes)
            .map(|_| {
                let mut v = rng.normal_vec(dim, 1.0);
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                v.iter_mut().for_each(|x| *x /= n);
                v
            })
            .collect();
        // harder tasks (higher kind) get more noise — gives the suite a
        // difficulty spread like ARC-e vs ARC-c
        let noise = 0.35 + 0.12 * kind as f32;
        ClassTask { name, dim, classes, protos, kind, noise, seed }
    }

    /// Sample (x, label).
    pub fn sample(&self, rng: &mut Rng) -> (Vec<f32>, usize) {
        let label = rng.below(self.classes);
        let mut x: Vec<f32> = self.protos[label].clone();
        // task-specific structure
        match self.kind % 3 {
            0 => {} // pure prototype + noise
            1 => {
                // XOR-ish: flip half the coordinates for odd labels
                if label % 2 == 1 {
                    for v in x.iter_mut().take(self.dim / 2) {
                        *v = -*v;
                    }
                }
            }
            _ => {
                // multiplicative interaction between halves
                for i in 0..self.dim / 2 {
                    let j = self.dim / 2 + i;
                    let a = x[i];
                    x[i] = a * x[j].signum();
                }
            }
        }
        for v in x.iter_mut() {
            *v += rng.normal() * self.noise;
        }
        (x, label)
    }

    pub fn batch(&self, n: usize, rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let (x, y) = self.sample(rng);
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    /// Fixed held-out evaluation set (deterministic per task).
    pub fn eval_set(&self, n: usize) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut rng = Rng::new(self.seed ^ 0xEEE);
        self.batch(n, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_five_tasks() {
        let suite = ClassTask::suite(32, 0);
        assert_eq!(suite.len(), 5);
        let names: Vec<_> = suite.iter().map(|t| t.name).collect();
        assert!(names.contains(&"piqa-s"));
    }

    #[test]
    fn eval_set_deterministic() {
        let t = ClassTask::suite(16, 1).remove(0);
        let (a, la) = t.eval_set(32);
        let (b, lb) = t.eval_set(32);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn labels_in_range() {
        let t = ClassTask::suite(16, 2).remove(2);
        let (_, ys) = t.eval_set(100);
        assert!(ys.iter().all(|&y| y < t.classes));
        // all classes appear
        let mut seen = vec![false; t.classes];
        ys.iter().for_each(|&y| seen[y] = true);
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn task_is_learnable_better_than_chance() {
        // nearest-prototype classifier should beat chance on kind-0 tasks
        let t = ClassTask::new("probe", 32, 4, 0, 9);
        let (xs, ys) = t.eval_set(200);
        let mut correct = 0;
        for (x, &y) in xs.iter().zip(&ys) {
            let mut best = 0;
            let mut best_dot = f32::NEG_INFINITY;
            for (c, p) in t.protos.iter().enumerate() {
                let d: f32 = x.iter().zip(p).map(|(a, b)| a * b).sum();
                if d > best_dot {
                    best_dot = d;
                    best = c;
                }
            }
            if best == y {
                correct += 1;
            }
        }
        assert!(correct > 100, "nearest-proto acc {correct}/200");
    }
}
