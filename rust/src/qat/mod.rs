//! Quantization-Aware Training harness — the substrate behind Table 1
//! (SEQ 2-bit QAT vs PTQ vs small-dense) and Table 2 (Tequila / Sherry vs
//! ternary baselines).
//!
//! The paper QAT-trains billion-parameter LLMs on 89B tokens; here the same
//! mechanisms (STE fake-quant, deadzone-bias reactivation, Arenas annealing)
//! are exercised on a tiny MLP classifier over synthetic data — small
//! enough to train hundreds of times inside a bench, big enough that the
//! *ordering* of methods (fp32 > {Tequila, Sherry} > plain ternary ≫
//! collapse) reproduces. The trained-transformer side of Table 1 runs on
//! the python-built artifacts instead (model_target_seq2qat vs seq2 PTQ).

pub mod mlp;
pub mod tasks;
pub mod trainer;

pub use mlp::Mlp;
pub use tasks::ClassTask;
pub use trainer::{train, QatMethod, TrainCfg, TrainReport};
