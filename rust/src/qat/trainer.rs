//! QAT training loop with method-specific fake-quant forwards — the engine
//! behind the Table 1 / Table 2 benches.
//!
//! Methods:
//!   * Fp32         — full-precision reference
//!   * Int4         — group-wise int4 fake-quant + STE
//!   * Seq2         — SEQ 2-bit fake-quant + STE (§2.1.2)
//!   * BitNetProxy  — absmean ternary (BitNet b1.58-style), STE
//!   * Twn          — threshold ternary (TWN), STE — the "plain ternary"
//!                    baseline whose deadzone traps weights
//!   * LlmQatProxy  — per-tensor threshold ternary (coarser scale), STE
//!   * Tequila      — Twn + dead-weight dynamic bias C(W) (§2.2.1): biases
//!                    enter the forward and dead weights get the extra λ
//!                    gradient path; bias is merged post-training
//!   * Sherry       — 3:4 structured ternary + Arenas residual (§2.2.2):
//!                    forward uses Q(W) + λ_t·W with λ_t annealed to 0

use crate::quant::{
    sherry::{ArenasSchedule, Sherry},
    tequila::Tequila,
    AffineQuantizer, Seq2Quantizer, TernaryQuantizer, WeightQuantizer,
};
use crate::util::Rng;

use super::{mlp::Mlp, tasks::ClassTask};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QatMethod {
    Fp32,
    Int4,
    Seq2,
    BitNetProxy,
    Twn,
    LlmQatProxy,
    Tequila,
    Sherry,
}

impl QatMethod {
    pub fn name(&self) -> &'static str {
        match self {
            QatMethod::Fp32 => "FP32",
            QatMethod::Int4 => "INT4",
            QatMethod::Seq2 => "SEQ-2bit",
            QatMethod::BitNetProxy => "BitNet*",
            QatMethod::Twn => "TernaryLLM*",
            QatMethod::LlmQatProxy => "LLM-QAT*",
            QatMethod::Tequila => "Tequila",
            QatMethod::Sherry => "Sherry",
        }
    }

    pub fn bits(&self) -> f64 {
        match self {
            QatMethod::Fp32 => 16.0, // reported as the paper's BF16 rows
            QatMethod::Int4 => 4.0,
            QatMethod::Seq2 => 2.0,
            QatMethod::BitNetProxy | QatMethod::Twn | QatMethod::LlmQatProxy => 1.67,
            QatMethod::Tequila => 1.67,
            QatMethod::Sherry => 1.25,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub hidden: usize,
    pub eval_n: usize,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { steps: 1200, lr: 0.03, hidden: 48, eval_n: 400, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: QatMethod,
    pub task: &'static str,
    pub accuracy: f64,
    pub final_loss: f32,
}

/// Fake-quant the latent weights per method; returns (qw, per-row bias,
/// per-weight grad scale multiplier) — grad scale encodes the Tequila dead
/// path and the Arenas residual.
fn effective_weights(
    method: QatMethod,
    w: &[f32],
    n: usize,
    k: usize,
    step: usize,
    arenas: &ArenasSchedule,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut qw = w.to_vec();
    let bias = vec![0.0f32; n];
    let gscale = vec![1.0f32; w.len()];
    match method {
        QatMethod::Fp32 => (qw, bias, gscale),
        QatMethod::Int4 => {
            let g = if k % 32 == 0 { 32 } else { k };
            AffineQuantizer::new(4, crate::quant::Granularity::Group(g)).qdq(&mut qw, n, k);
            (qw, bias, gscale)
        }
        QatMethod::Seq2 => {
            let g = if k % 32 == 0 { 32 } else { k };
            Seq2Quantizer::new(g).qdq(&mut qw, n, k);
            (qw, bias, gscale)
        }
        QatMethod::BitNetProxy => {
            // absmean scaling, round to {-1,0,1}
            let mean_abs = w.iter().map(|x| x.abs()).sum::<f32>() / w.len() as f32;
            let s = mean_abs.max(1e-8);
            for v in qw.iter_mut() {
                *v = (*v / s).round().clamp(-1.0, 1.0) * s;
            }
            (qw, bias, gscale)
        }
        QatMethod::Twn => {
            TernaryQuantizer::default().qdq(&mut qw, n, k);
            (qw, bias, gscale)
        }
        QatMethod::LlmQatProxy => {
            // per-tensor threshold ternary (coarsest granularity)
            TernaryQuantizer::default().qdq(&mut qw, 1, n * k);
            (qw, bias, gscale)
        }
        QatMethod::Tequila => {
            let tq = Tequila::default();
            let q = tq.quantize(w, n, k);
            let qw = TernaryQuantizer::dequantize_codes(&q.codes, &q.alphas, n, k);
            let mut gscale = vec![1.0f32; w.len()];
            for (i, &c) in q.codes.iter().enumerate() {
                gscale[i] = tq.grad_scale(c);
            }
            (qw, q.bias, gscale)
        }
        QatMethod::Sherry => {
            let (codes, alphas) = Sherry::quantize_codes(w, n, k);
            let mut qw = Sherry::dequantize_codes(&codes, &alphas, n, k);
            let lambda = arenas.lambda(step);
            if lambda > 0.0 {
                for (qv, &wv) in qw.iter_mut().zip(w) {
                    *qv += lambda * wv; // Arenas residual synapse (eq. 4)
                }
            }
            let gscale = vec![1.0 + lambda; w.len()];
            (qw, bias, gscale)
        }
    }
}

/// Deploy-time weights: what inference actually uses (Arenas residual gone,
/// Tequila bias merged statically).
pub fn deploy_weights(method: QatMethod, w: &[f32], n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let arenas = ArenasSchedule::new(0.0, 1);
    let (qw, bias, _) = effective_weights(method, w, n, k, usize::MAX, &arenas);
    (qw, bias)
}

pub fn train(task: &ClassTask, method: QatMethod, cfg: &TrainCfg) -> TrainReport {
    let mut rng = Rng::new(cfg.seed ^ 0x9A7);
    let mut mlp = Mlp::new(task.dim, cfg.hidden, task.classes, &mut rng);
    let arenas = ArenasSchedule::new(0.3, cfg.steps);
    let mut last_loss = 0.0f32;

    for step in 0..cfg.steps {
        let (x, y) = task.sample(&mut rng);
        let (qw1, b1, gs1) =
            effective_weights(method, &mlp.w1, mlp.dh, mlp.din, step, &arenas);
        let (qw2, b2, gs2) =
            effective_weights(method, &mlp.w2, mlp.dout, mlp.dh, step, &arenas);
        let cache = mlp.forward_with_bias(&qw1, &qw2, &b1, &b2, &x);
        let (loss, dlogits) = Mlp::ce_grad(&cache.logits, y);
        last_loss = loss;
        let (gw1, gw2, dh) = mlp.backward_ext(&qw2, &cache, &dlogits);

        let lr = cfg.lr * (1.0 - 0.9 * step as f32 / cfg.steps as f32);
        // STE update with per-weight grad scaling; Tequila's dead weights
        // additionally receive the bias-path gradient λ·dL/dy_row
        let tq_lambda = if method == QatMethod::Tequila { Tequila::default().lambda } else { 0.0 };
        for r in 0..mlp.dh {
            for c in 0..mlp.din {
                let i = r * mlp.din + c;
                let mut g = gw1[i] * gs1[i];
                if tq_lambda > 0.0 && gs1[i] > 1.0 {
                    g = gw1[i] + tq_lambda * dh[r]; // explicit dead path
                }
                mlp.w1[i] -= lr * g;
            }
        }
        for r in 0..mlp.dout {
            for c in 0..mlp.dh {
                let i = r * mlp.dh + c;
                let mut g = gw2[i] * gs2[i];
                if tq_lambda > 0.0 && gs2[i] > 1.0 {
                    g = gw2[i] + tq_lambda * dlogits[r];
                }
                mlp.w2[i] -= lr * g;
            }
        }
    }

    // evaluate with deploy-time weights (bias merged, residual annealed off)
    let (qw1, b1) = deploy_weights(method, &mlp.w1, mlp.dh, mlp.din);
    let (qw2, b2) = deploy_weights(method, &mlp.w2, mlp.dout, mlp.dh);
    let (xs, ys) = task.eval_set(cfg.eval_n);
    let mut correct = 0usize;
    for (x, &y) in xs.iter().zip(&ys) {
        let c = mlp.forward_with_bias(&qw1, &qw2, &b1, &b2, x);
        if crate::tensor::ops::argmax(&c.logits) == y {
            correct += 1;
        }
    }
    TrainReport {
        method,
        task: task.name,
        accuracy: correct as f64 / xs.len() as f64,
        final_loss: last_loss,
    }
}

/// Train a method over the whole task suite; returns (per-task accs, mean).
pub fn train_suite(method: QatMethod, dim: usize, cfg: &TrainCfg) -> (Vec<TrainReport>, f64) {
    let suite = ClassTask::suite(dim, 7);
    let reports: Vec<TrainReport> = suite.iter().map(|t| train(t, method, cfg)).collect();
    let mean = reports.iter().map(|r| r.accuracy).sum::<f64>() / reports.len() as f64;
    (reports, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TrainCfg {
        TrainCfg { steps: 700, lr: 0.03, hidden: 40, eval_n: 250, seed: 1 }
    }

    #[test]
    fn fp32_learns_task() {
        let task = ClassTask::suite(24, 7).remove(0);
        let r = train(&task, QatMethod::Fp32, &quick_cfg());
        assert!(r.accuracy > 0.6, "fp32 acc {}", r.accuracy);
    }

    #[test]
    fn int4_close_to_fp32() {
        let task = ClassTask::suite(24, 7).remove(0);
        let f = train(&task, QatMethod::Fp32, &quick_cfg());
        let q = train(&task, QatMethod::Int4, &quick_cfg());
        assert!(q.accuracy > f.accuracy - 0.12, "int4 {} fp32 {}", q.accuracy, f.accuracy);
    }

    #[test]
    fn seq2_qat_beats_chance_substantially() {
        let task = ClassTask::suite(24, 7).remove(0);
        let r = train(&task, QatMethod::Seq2, &quick_cfg());
        let chance = 1.0 / task.classes as f64;
        assert!(r.accuracy > chance * 2.0, "seq2 acc {}", r.accuracy);
    }

    #[test]
    fn tequila_not_worse_than_twn_on_suite_mean() {
        let cfg = quick_cfg();
        let (_, twn) = train_suite(QatMethod::Twn, 24, &cfg);
        let (_, teq) = train_suite(QatMethod::Tequila, 24, &cfg);
        assert!(teq >= twn - 0.03, "tequila {teq} vs twn {twn}");
    }

    #[test]
    fn sherry_not_worse_than_twn_on_suite_mean() {
        let cfg = quick_cfg();
        let (_, twn) = train_suite(QatMethod::Twn, 24, &cfg);
        let (_, sh) = train_suite(QatMethod::Sherry, 24, &cfg);
        assert!(sh >= twn - 0.05, "sherry {sh} vs twn {twn}");
    }

    #[test]
    fn deploy_weights_are_pure_ternary_for_tequila() {
        let mut rng = Rng::new(0);
        let w = rng.normal_vec(8 * 16, 0.5);
        let (qw, bias) = deploy_weights(QatMethod::Tequila, &w, 8, 16);
        // exactly {-a, 0, +a} per row
        for r in 0..8 {
            let vals: std::collections::BTreeSet<i64> = qw[r * 16..(r + 1) * 16]
                .iter()
                .map(|v| (v * 1e4).round() as i64)
                .collect();
            assert!(vals.len() <= 3, "row {r} has {} levels", vals.len());
        }
        assert_eq!(bias.len(), 8);
    }

    #[test]
    fn deploy_weights_sherry_has_no_residual() {
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(4 * 16, 0.5);
        let (qw, _) = deploy_weights(QatMethod::Sherry, &w, 4, 16);
        // 3:4 sparsity must hold exactly (residual would break the zeros)
        let nz = qw.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 4 * 16 * 3 / 4);
    }
}
