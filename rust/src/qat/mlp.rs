//! Tiny 2-layer MLP with manual backprop — the QAT training substrate.
//!
//! Forward: logits = W2 · relu(W1 · x). Backprop is hand-written (no
//! autograd offline); the trainer quantizes W1/W2 with a fake-quant forward
//! and routes gradients through STE (optionally with Tequila's dead-weight
//! bias path or Sherry's Arenas residual).

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Mlp {
    pub din: usize,
    pub dh: usize,
    pub dout: usize,
    /// latent full-precision weights (what QAT updates)
    pub w1: Vec<f32>, // [dh, din]
    pub w2: Vec<f32>, // [dout, dh]
}

/// Per-example forward cache for backprop.
pub struct Cache {
    pub x: Vec<f32>,
    pub h_pre: Vec<f32>,
    pub h: Vec<f32>,
    pub logits: Vec<f32>,
}

impl Mlp {
    pub fn new(din: usize, dh: usize, dout: usize, rng: &mut Rng) -> Self {
        Mlp {
            din,
            dh,
            dout,
            w1: rng.normal_vec(dh * din, (din as f32).powf(-0.5)),
            w2: rng.normal_vec(dout * dh, (dh as f32).powf(-0.5)),
        }
    }

    /// Forward with *given* effective weights (the trainer passes the
    /// fake-quantized image of w1/w2 here).
    pub fn forward_with(&self, qw1: &[f32], qw2: &[f32], x: &[f32]) -> Cache {
        let mut h_pre = vec![0.0f32; self.dh];
        for r in 0..self.dh {
            h_pre[r] = crate::tensor::ops::dot(&qw1[r * self.din..(r + 1) * self.din], x);
        }
        let h: Vec<f32> = h_pre.iter().map(|&v| v.max(0.0)).collect();
        let mut logits = vec![0.0f32; self.dout];
        for r in 0..self.dout {
            logits[r] = crate::tensor::ops::dot(&qw2[r * self.dh..(r + 1) * self.dh], &h);
        }
        Cache { x: x.to_vec(), h_pre, h, logits }
    }

    /// Softmax-CE loss + gradient wrt logits.
    pub fn ce_grad(logits: &[f32], label: usize) -> (f32, Vec<f32>) {
        let lp = crate::tensor::ops::log_softmax(logits);
        let loss = -lp[label];
        let mut g: Vec<f32> = lp.iter().map(|&l| l.exp()).collect();
        g[label] -= 1.0;
        (loss, g)
    }

    /// Forward with per-layer biases (Tequila's dynamic dead-weight bias).
    pub fn forward_with_bias(
        &self,
        qw1: &[f32],
        qw2: &[f32],
        b1: &[f32],
        b2: &[f32],
        x: &[f32],
    ) -> Cache {
        let mut h_pre = vec![0.0f32; self.dh];
        for r in 0..self.dh {
            h_pre[r] =
                crate::tensor::ops::dot(&qw1[r * self.din..(r + 1) * self.din], x) + b1[r];
        }
        let h: Vec<f32> = h_pre.iter().map(|&v| v.max(0.0)).collect();
        let mut logits = vec![0.0f32; self.dout];
        for r in 0..self.dout {
            logits[r] =
                crate::tensor::ops::dot(&qw2[r * self.dh..(r + 1) * self.dh], &h) + b2[r];
        }
        Cache { x: x.to_vec(), h_pre, h, logits }
    }

    /// Backprop through the *quantized* forward (STE: gradients flow to the
    /// latent weights as if the quantizer were identity). Returns
    /// (grad_w1, grad_w2, dh) where dh is the post-relu-gate hidden grad —
    /// Tequila's dead-weight bias path needs it.
    pub fn backward_ext(
        &self,
        qw2: &[f32],
        cache: &Cache,
        dlogits: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut gw2 = vec![0.0f32; self.dout * self.dh];
        for r in 0..self.dout {
            for c in 0..self.dh {
                gw2[r * self.dh + c] = dlogits[r] * cache.h[c];
            }
        }
        // dh = W2^T dlogits, gated by relu
        let mut dh = vec![0.0f32; self.dh];
        for c in 0..self.dh {
            let mut acc = 0.0;
            for r in 0..self.dout {
                acc += qw2[r * self.dh + c] * dlogits[r];
            }
            dh[c] = if cache.h_pre[c] > 0.0 { acc } else { 0.0 };
        }
        let mut gw1 = vec![0.0f32; self.dh * self.din];
        for r in 0..self.dh {
            if dh[r] == 0.0 {
                continue;
            }
            for c in 0..self.din {
                gw1[r * self.din + c] = dh[r] * cache.x[c];
            }
        }
        (gw1, gw2, dh)
    }

    /// Convenience wrapper for callers that don't need dh.
    pub fn backward(&self, qw2: &[f32], cache: &Cache, dlogits: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let (gw1, gw2, _) = self.backward_ext(qw2, cache, dlogits);
        (gw1, gw2)
    }

    /// Accuracy with given effective weights on a labelled set.
    pub fn accuracy(&self, qw1: &[f32], qw2: &[f32], xs: &[Vec<f32>], ys: &[usize]) -> f64 {
        let mut correct = 0usize;
        for (x, &y) in xs.iter().zip(ys) {
            let c = self.forward_with(qw1, qw2, x);
            if crate::tensor::ops::argmax(&c.logits) == y {
                correct += 1;
            }
        }
        correct as f64 / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(0);
        let m = Mlp::new(8, 16, 4, &mut rng);
        let x = rng.normal_vec(8, 1.0);
        let c = m.forward_with(&m.w1, &m.w2, &x);
        assert_eq!(c.logits.len(), 4);
        assert!(c.h.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn ce_grad_sums_to_zero() {
        let (loss, g) = Mlp::ce_grad(&[1.0, 2.0, 0.5], 1);
        assert!(loss > 0.0);
        assert!((g.iter().sum::<f32>()).abs() < 1e-6);
        assert!(g[1] < 0.0);
    }

    #[test]
    fn numerical_gradient_check() {
        let mut rng = Rng::new(1);
        let m = Mlp::new(6, 10, 3, &mut rng);
        let x = rng.normal_vec(6, 1.0);
        let label = 2;
        let c = m.forward_with(&m.w1, &m.w2, &x);
        let (_, dlogits) = Mlp::ce_grad(&c.logits, label);
        let (gw1, gw2) = m.backward(&m.w2, &c, &dlogits);

        let eps = 1e-3;
        let mut check = |widx: usize, is_w1: bool, analytic: f32| {
            let mut mp = m.clone();
            let w = if is_w1 { &mut mp.w1 } else { &mut mp.w2 };
            w[widx] += eps;
            let cp = mp.forward_with(&mp.w1, &mp.w2, &x);
            let (lp, _) = Mlp::ce_grad(&cp.logits, label);
            let w = if is_w1 { &mut mp.w1 } else { &mut mp.w2 };
            w[widx] -= 2.0 * eps;
            let cm = mp.forward_with(&mp.w1, &mp.w2, &x);
            let (lm, _) = Mlp::ce_grad(&cm.logits, label);
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 2e-2 * (1.0 + analytic.abs()),
                "numeric {numeric} vs analytic {analytic}"
            );
        };
        for idx in [0, 7, 23] {
            check(idx, true, gw1[idx]);
        }
        for idx in [0, 11, 29] {
            check(idx, false, gw2[idx]);
        }
    }

    #[test]
    fn sgd_reduces_loss_fp32() {
        let mut rng = Rng::new(2);
        let mut m = Mlp::new(8, 24, 4, &mut rng);
        let task = crate::qat::tasks::ClassTask::new("t", 8, 4, 0, 3);
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..300 {
            let (x, y) = task.sample(&mut rng);
            let c = m.forward_with(&m.w1.clone(), &m.w2.clone(), &x);
            let (loss, dl) = Mlp::ce_grad(&c.logits, y);
            let (gw1, gw2) = m.backward(&m.w2.clone(), &c, &dl);
            for (w, g) in m.w1.iter_mut().zip(&gw1) {
                *w -= 0.05 * g;
            }
            for (w, g) in m.w2.iter_mut().zip(&gw2) {
                *w -= 0.05 * g;
            }
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first * 0.7, "loss {first} -> {last}");
    }
}
