//! Synthetic audio-token streams — the ASR-benchmark substitute for the
//! audio token merge/prune evaluation (paper Table 13).
//!
//! Speech tokens have strong *temporal* redundancy: a phoneme spans several
//! consecutive frames whose features are near-identical. A stream here is a
//! sequence of phoneme segments (variable duration) with per-frame features
//! near the phoneme centroid, plus encoder attention scores that peak at
//! segment boundaries / stressed phonemes. The ASR proxy (eval/asr.rs)
//! decodes the phoneme sequence from the (possibly merged/pruned) tokens
//! and computes an edit-distance WER against the ground-truth transcript —
//! the same failure mode real ASR pruning benchmarks measure: dropping or
//! over-merging frames deletes/garbles phonemes.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct AudioScene {
    /// frame features [n_frames][dim]
    pub features: Vec<Vec<f32>>,
    /// encoder attention score per frame (importance analogue)
    pub attention: Vec<f32>,
    /// per-frame phoneme id
    pub frame_phonemes: Vec<usize>,
    /// ground-truth transcript: run-length-collapsed phoneme sequence
    pub transcript: Vec<usize>,
}

pub struct AudioSceneGen {
    pub dim: usize,
    pub n_phonemes: usize,
    pub mean_segment_len: usize,
    /// frame-level feature noise around the phoneme centroid; Table 13's
    /// three model rows map to three noise profiles
    pub noise: f32,
    pub centroids: Vec<Vec<f32>>,
    seed: u64,
}

impl AudioSceneGen {
    pub fn new(dim: usize, n_phonemes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x0A0D10);
        let centroids = (0..n_phonemes)
            .map(|_| {
                let mut v = rng.normal_vec(dim, 1.0);
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                v.iter_mut().for_each(|x| *x *= 2.0 / n);
                v
            })
            .collect();
        AudioSceneGen {
            dim,
            n_phonemes,
            mean_segment_len: 3,
            noise,
            centroids,
            seed,
        }
    }

    pub fn scene(&self, idx: u64, n_frames: usize) -> AudioScene {
        let mut rng = Rng::new(self.seed.wrapping_add(idx.wrapping_mul(0xA11CE)));
        let mut features = Vec::with_capacity(n_frames);
        let mut attention = Vec::with_capacity(n_frames);
        let mut frame_phonemes = Vec::with_capacity(n_frames);
        let mut transcript = Vec::new();

        let mut prev = usize::MAX;
        while features.len() < n_frames {
            let mut ph = rng.below(self.n_phonemes);
            if ph == prev {
                ph = (ph + 1) % self.n_phonemes;
            }
            prev = ph;
            transcript.push(ph);
            let dur = 1 + rng.below(self.mean_segment_len * 2 - 1);
            let stressed = rng.bool(0.3);
            for f in 0..dur {
                if features.len() >= n_frames {
                    break;
                }
                // attention peaks on the first frame of a segment and on
                // stressed phonemes; mid-segment frames are redundant
                let base = if f == 0 { 1.0 } else { 0.3 / (1.0 + f as f32) };
                let a = base + if stressed { 0.5 } else { 0.0 } + rng.f32() * 0.35;
                attention.push(a);
                // articulation: high-attention frames are cleaner — this is
                // what attention-*weighted* merging (Samp eq. 9) exploits
                // over uniform averaging
                let frame_noise = self.noise * (1.6 - a.min(1.5));
                let mut feat = self.centroids[ph].clone();
                for x in feat.iter_mut() {
                    *x += rng.normal() * frame_noise;
                }
                features.push(feat);
                frame_phonemes.push(ph);
            }
        }
        // transcript may have a trailing phoneme with zero frames if we
        // broke early — trim it
        if let Some(&last) = frame_phonemes.last() {
            while transcript.last() != Some(&last) {
                transcript.pop();
            }
        }
        AudioScene { features, attention, frame_phonemes, transcript }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_shapes() {
        let gen = AudioSceneGen::new(24, 32, 0.15, 0);
        let s = gen.scene(0, 200);
        assert_eq!(s.features.len(), 200);
        assert_eq!(s.attention.len(), 200);
        assert_eq!(s.frame_phonemes.len(), 200);
        assert!(!s.transcript.is_empty());
    }

    #[test]
    fn transcript_matches_frames() {
        let gen = AudioSceneGen::new(16, 16, 0.1, 1);
        let s = gen.scene(2, 150);
        // run-length-collapse the frame phonemes; must equal transcript
        let mut collapsed = Vec::new();
        for &p in &s.frame_phonemes {
            if collapsed.last() != Some(&p) {
                collapsed.push(p);
            }
        }
        assert_eq!(collapsed, s.transcript);
    }

    #[test]
    fn adjacent_frames_similar_within_segment() {
        let gen = AudioSceneGen::new(24, 32, 0.1, 3);
        let s = gen.scene(1, 120);
        let mut same_sim = Vec::new();
        let mut diff_sim = Vec::new();
        for i in 1..s.features.len() {
            let sim = crate::util::stats::cosine(&s.features[i - 1], &s.features[i]);
            if s.frame_phonemes[i - 1] == s.frame_phonemes[i] {
                same_sim.push(sim);
            } else {
                diff_sim.push(sim);
            }
        }
        let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(avg(&same_sim) > avg(&diff_sim) + 0.2);
    }

    #[test]
    fn segment_starts_get_attention() {
        let gen = AudioSceneGen::new(16, 16, 0.1, 5);
        let s = gen.scene(0, 200);
        let mut starts = Vec::new();
        let mut mids = Vec::new();
        for i in 0..s.frame_phonemes.len() {
            if i == 0 || s.frame_phonemes[i] != s.frame_phonemes[i - 1] {
                starts.push(s.attention[i]);
            } else {
                mids.push(s.attention[i]);
            }
        }
        let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(avg(&starts) > avg(&mids) + 0.3);
    }
}
