//! Synthetic workload generators — the DataFactory substrate.
//!
//! The paper evaluates on proprietary corpora and public benchmark suites
//! (GSM8K, LongBench, RULER, LibriSpeech, ...). None are available here, so
//! each generator produces a *deterministic, seeded* synthetic equivalent
//! that exercises the same code path and yields a graded metric with the
//! same comparison structure (see DESIGN.md §3).

pub mod audio;
pub mod corpus;
pub mod longctx;
pub mod vision;

pub use audio::{AudioScene, AudioSceneGen};
pub use corpus::{load_corpus, markov_corpus, RequestGen, TokenRequest};
pub use longctx::{LongCtxTask, LongCtxTaskKind, NeedleTask};
pub use vision::{VisionScene, VisionSceneGen};
