//! Long-context task builders — the LongBench / RULER substitute.
//!
//! Each task is a token sequence with a *planted dependency*: the answer at
//! the final query position is determined by content placed somewhere in
//! the (long) context. Sparse-attention methods that drop the wrong blocks
//! break the dependency and score measurably worse — precisely what
//! LongBench/RULER measure for the paper's Table 11.
//!
//! Task families mirror the paper's column structure:
//!   CC  (code completion)   -> periodic pattern continuation
//!   FSL (few-shot learning) -> repeated key->value mappings, query at end
//!   MD  (multi-doc QA)      -> needle(s) buried among distractor "docs"
//!   SUM (summarization)     -> majority-symbol report
//!   SYN (synthetic)         -> classic single-needle retrieval

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LongCtxTaskKind {
    CodeCompletion,
    FewShot,
    MultiDoc1,
    MultiDoc2,
    Summarize,
    Synthetic,
}

impl LongCtxTaskKind {
    pub fn all() -> [LongCtxTaskKind; 6] {
        [
            LongCtxTaskKind::CodeCompletion,
            LongCtxTaskKind::FewShot,
            LongCtxTaskKind::MultiDoc1,
            LongCtxTaskKind::MultiDoc2,
            LongCtxTaskKind::Summarize,
            LongCtxTaskKind::Synthetic,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            LongCtxTaskKind::CodeCompletion => "CC",
            LongCtxTaskKind::FewShot => "FSL",
            LongCtxTaskKind::MultiDoc1 => "MD1",
            LongCtxTaskKind::MultiDoc2 => "MD2",
            LongCtxTaskKind::Summarize => "SUM",
            LongCtxTaskKind::Synthetic => "SYN",
        }
    }
}

/// A single long-context example: `tokens` ends with a query; the model (or
/// attention-mass proxy) must produce `answer` by attending to
/// `evidence_positions`.
#[derive(Clone, Debug)]
pub struct LongCtxTask {
    pub kind: LongCtxTaskKind,
    pub tokens: Vec<u8>,
    pub answer: u8,
    /// positions whose content determines the answer
    pub evidence_positions: Vec<usize>,
}

/// Simple single-needle retrieval task (RULER-style), exposed separately
/// because several tests/benches want just this.
#[derive(Clone, Debug)]
pub struct NeedleTask {
    pub tokens: Vec<u8>,
    pub needle_pos: usize,
    pub answer: u8,
}

const KEY: u8 = 200; // marker byte introducing a key-value pair
const QUERY: u8 = 201; // marker byte introducing the final query
const DOC_SEP: u8 = 202;

fn filler(rng: &mut Rng, n: usize, out: &mut Vec<u8>) {
    for _ in 0..n {
        out.push(rng.below(64) as u8);
    }
}

pub fn needle_task(seq_len: usize, seed: u64) -> NeedleTask {
    let mut rng = Rng::new(seed);
    let key = (64 + rng.below(32)) as u8;
    let answer = (128 + rng.below(32)) as u8;
    let needle_pos = 4 + rng.below(seq_len.saturating_sub(16).max(1));
    let mut tokens = Vec::with_capacity(seq_len);
    filler(&mut rng, needle_pos, &mut tokens);
    tokens.push(KEY);
    tokens.push(key);
    tokens.push(answer);
    let tail = seq_len.saturating_sub(tokens.len() + 2);
    filler(&mut rng, tail, &mut tokens);
    tokens.push(QUERY);
    tokens.push(key);
    NeedleTask { tokens, needle_pos, answer }
}

/// Build one example of the given kind at the given length.
pub fn build(kind: LongCtxTaskKind, seq_len: usize, seed: u64) -> LongCtxTask {
    let mut rng = Rng::new(seed ^ (kind as u64) << 32);
    match kind {
        LongCtxTaskKind::Synthetic => {
            let n = needle_task(seq_len, seed);
            let ev = vec![n.needle_pos + 1, n.needle_pos + 2];
            LongCtxTask {
                kind,
                tokens: n.tokens,
                answer: n.answer,
                evidence_positions: ev,
            }
        }
        LongCtxTaskKind::CodeCompletion => {
            // periodic "function body": pattern of period p repeats; answer
            // is the continuation of the pattern at the end.
            let p = 3 + rng.below(5);
            let pattern: Vec<u8> = (0..p).map(|_| (64 + rng.below(32)) as u8).collect();
            let mut tokens = Vec::with_capacity(seq_len);
            // noise prefix, then the repeating block dominates the tail
            filler(&mut rng, seq_len / 4, &mut tokens);
            while tokens.len() < seq_len {
                tokens.push(pattern[tokens.len() % p]);
            }
            let answer = pattern[tokens.len() % p];
            let evidence: Vec<usize> =
                (seq_len.saturating_sub(2 * p)..seq_len).collect();
            LongCtxTask { kind, tokens, answer, evidence_positions: evidence }
        }
        LongCtxTaskKind::FewShot => {
            // k key->value shots scattered early; query repeats one key.
            let shots = 4;
            let keys: Vec<u8> = (0..shots).map(|i| (64 + i) as u8).collect();
            let vals: Vec<u8> = (0..shots).map(|_| (128 + rng.below(32)) as u8).collect();
            let mut tokens = Vec::new();
            let mut evidence = Vec::new();
            for i in 0..shots {
                filler(&mut rng, seq_len / (shots * 3), &mut tokens);
                tokens.push(KEY);
                evidence.push(tokens.len());
                tokens.push(keys[i]);
                evidence.push(tokens.len());
                tokens.push(vals[i]);
            }
            let pick = rng.below(shots);
            let tail = seq_len.saturating_sub(tokens.len() + 2);
            filler(&mut rng, tail, &mut tokens);
            tokens.push(QUERY);
            tokens.push(keys[pick]);
            LongCtxTask { kind, tokens, answer: vals[pick], evidence_positions: evidence }
        }
        LongCtxTaskKind::MultiDoc1 | LongCtxTaskKind::MultiDoc2 => {
            // docs separated by DOC_SEP; one doc holds the key-value fact;
            // MD2 buries it deeper among more docs.
            let docs = if kind == LongCtxTaskKind::MultiDoc1 { 4 } else { 8 };
            let key = (64 + rng.below(32)) as u8;
            let answer = (128 + rng.below(32)) as u8;
            let target_doc = rng.below(docs);
            let mut tokens = Vec::new();
            let mut evidence = Vec::new();
            let doc_len = seq_len / docs;
            for d in 0..docs {
                tokens.push(DOC_SEP);
                if d == target_doc {
                    let off = rng.below(doc_len.saturating_sub(6).max(1));
                    filler(&mut rng, off, &mut tokens);
                    tokens.push(KEY);
                    evidence.push(tokens.len());
                    tokens.push(key);
                    evidence.push(tokens.len());
                    tokens.push(answer);
                    filler(&mut rng, doc_len.saturating_sub(off + 4), &mut tokens);
                } else {
                    filler(&mut rng, doc_len.saturating_sub(1), &mut tokens);
                }
            }
            tokens.truncate(seq_len.saturating_sub(2));
            tokens.push(QUERY);
            tokens.push(key);
            LongCtxTask { kind, tokens, answer, evidence_positions: evidence }
        }
        LongCtxTaskKind::Summarize => {
            // majority symbol over the whole context: answer = most frequent
            // marked symbol; evidence is spread everywhere (summarization
            // punishes overly-local sparsity).
            let cands: Vec<u8> = (0..4).map(|i| (96 + i) as u8).collect();
            let majority = rng.below(cands.len());
            let mut tokens = Vec::with_capacity(seq_len);
            let mut evidence = Vec::new();
            while tokens.len() < seq_len.saturating_sub(1) {
                if rng.bool(0.3) {
                    let c = if rng.bool(0.6) { majority } else { rng.below(cands.len()) };
                    evidence.push(tokens.len());
                    tokens.push(cands[c]);
                } else {
                    tokens.push(rng.below(64) as u8);
                }
            }
            tokens.push(QUERY);
            LongCtxTask {
                kind,
                tokens,
                answer: cands[majority],
                evidence_positions: evidence,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_is_planted() {
        let t = needle_task(256, 5);
        assert_eq!(t.tokens.len(), 256);
        assert_eq!(t.tokens[t.needle_pos], KEY);
        assert_eq!(t.tokens[t.needle_pos + 2], t.answer);
        // query repeats the key
        assert_eq!(t.tokens[t.tokens.len() - 1], t.tokens[t.needle_pos + 1]);
    }

    #[test]
    fn all_kinds_build() {
        for kind in LongCtxTaskKind::all() {
            let t = build(kind, 512, 11);
            assert!(t.tokens.len() <= 512 + 8, "{:?} len {}", kind, t.tokens.len());
            assert!(!t.evidence_positions.is_empty());
            for &p in &t.evidence_positions {
                assert!(p < t.tokens.len(), "{kind:?} evidence oob");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = build(LongCtxTaskKind::FewShot, 256, 3);
        let b = build(LongCtxTaskKind::FewShot, 256, 3);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.answer, b.answer);
    }

    #[test]
    fn code_completion_continues_pattern() {
        let t = build(LongCtxTaskKind::CodeCompletion, 300, 7);
        // last tokens repeat with some period; answer continues it
        let n = t.tokens.len();
        let found = (3..8).any(|p| t.tokens[n - p] == t.answer);
        assert!(found);
    }
}
