//! Synthetic vision-token scenes — the VQA-benchmark substitute for the
//! token-pruning evaluation (paper Table 12).
//!
//! A scene is a grid of token features with the structure visual pruners
//! must navigate: a small set of *salient* tokens carrying task signal,
//! clusters of near-duplicate background tokens (spatial redundancy), and
//! i.i.d. noise tokens. The task proxy (eval/vqa.rs) classifies the scene
//! from an attention-pooled embedding; pruning quality is how well the
//! kept subset preserves the full-scene decision — exactly the importance
//! vs diversity trade-off IDPruner's MMR objective targets.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct VisionScene {
    /// token features [n_tokens][dim]
    pub features: Vec<Vec<f32>>,
    /// importance scores (e.g. CLS-attention analogue), one per token
    pub importance: Vec<f32>,
    /// ground-truth class of the scene
    pub label: usize,
    /// indices of the salient tokens (diagnostics only)
    pub salient: Vec<usize>,
}

pub struct VisionSceneGen {
    pub n_tokens: usize,
    pub dim: usize,
    pub n_classes: usize,
    pub n_salient: usize,
    pub n_clusters: usize,
    /// class prototype directions [n_classes][dim]
    pub prototypes: Vec<Vec<f32>>,
    seed: u64,
}

impl VisionSceneGen {
    pub fn new(n_tokens: usize, dim: usize, n_classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_0515);
        let prototypes = (0..n_classes)
            .map(|_| {
                let mut v = rng.normal_vec(dim, 1.0);
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                v.iter_mut().for_each(|x| *x /= n);
                v
            })
            .collect();
        VisionSceneGen {
            n_tokens,
            dim,
            n_classes,
            n_salient: (n_tokens / 24).max(4),
            n_clusters: 6,
            prototypes,
            seed,
        }
    }

    pub fn scene(&self, idx: u64) -> VisionScene {
        let mut rng = Rng::new(self.seed.wrapping_add(idx.wrapping_mul(0x9E37)));
        let label = rng.below(self.n_classes);
        let proto = &self.prototypes[label];

        let mut features = Vec::with_capacity(self.n_tokens);
        let mut importance = vec![0.0f32; self.n_tokens];

        // background: a few clusters of near-duplicates (redundancy),
        // unit-norm so they don't drown the class signal in pooled space
        let centers: Vec<Vec<f32>> = (0..self.n_clusters)
            .map(|_| {
                let mut v = rng.normal_vec(self.dim, 1.0);
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                v.iter_mut().for_each(|x| *x /= n);
                v
            })
            .collect();
        for _ in 0..self.n_tokens {
            let c = &centers[rng.below(self.n_clusters)];
            let mut f = c.clone();
            for x in f.iter_mut() {
                *x += rng.normal() * 0.08; // tight cluster
            }
            features.push(f);
        }

        // salient tokens: carry the class prototype + moderate importance;
        // several of them are *mutually redundant* copies, so a pruner that
        // only ranks by importance wastes budget (DivPrune/IDPruner story).
        let salient = rng.choose(self.n_tokens, self.n_salient);
        for (si, &t) in salient.iter().enumerate() {
            let strength = rng.range_f32(0.55, 1.0);
            // half the salient set duplicates direction 0 of the prototype
            let mut dir = proto.clone();
            if si % 2 == 0 {
                for (j, x) in dir.iter_mut().enumerate() {
                    *x += 0.3 * centers[0][j];
                }
            } else {
                // unique complementary evidence
                for (j, x) in dir.iter_mut().enumerate() {
                    *x = *x * 0.7 + 0.7 * ((j as f32 * (si as f32 + 2.0)).sin());
                }
            }
            for j in 0..self.dim {
                features[t][j] = dir[j] * strength + rng.normal() * 0.05;
            }
            importance[t] = strength;
        }

        // importance noise: many background tokens *look* important
        // (high-attention sinks) — the trap single-metric pruners fall into.
        for _ in 0..self.n_salient * 2 {
            let t = rng.below(self.n_tokens);
            if !salient.contains(&t) {
                importance[t] = rng.range_f32(0.5, 1.0);
            }
        }
        for imp in importance.iter_mut() {
            *imp += rng.f32() * 0.1;
        }

        VisionScene { features, importance, label, salient }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_shapes() {
        let gen = VisionSceneGen::new(144, 32, 8, 0);
        let s = gen.scene(0);
        assert_eq!(s.features.len(), 144);
        assert_eq!(s.features[0].len(), 32);
        assert_eq!(s.importance.len(), 144);
        assert!(s.label < 8);
        assert!(!s.salient.is_empty());
    }

    #[test]
    fn deterministic_per_index() {
        let gen = VisionSceneGen::new(64, 16, 4, 1);
        let a = gen.scene(5);
        let b = gen.scene(5);
        assert_eq!(a.features, b.features);
        assert_eq!(a.label, b.label);
        let c = gen.scene(6);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn salient_tokens_have_high_importance() {
        let gen = VisionSceneGen::new(144, 32, 8, 2);
        let s = gen.scene(3);
        let avg_salient: f32 = s.salient.iter().map(|&t| s.importance[t]).sum::<f32>()
            / s.salient.len() as f32;
        let avg_all: f32 = s.importance.iter().sum::<f32>() / s.importance.len() as f32;
        assert!(avg_salient > avg_all * 2.0);
    }

    #[test]
    fn labels_cover_classes() {
        let gen = VisionSceneGen::new(32, 8, 4, 3);
        let mut seen = std::collections::HashSet::new();
        for i in 0..40 {
            seen.insert(gen.scene(i).label);
        }
        assert!(seen.len() >= 3);
    }
}
