//! AngelSlim-RS: a unified large-model compression and acceleration toolkit.
//!
//! Reproduction of "AngelSlim: A more accessible, comprehensive, and
//! efficient toolkit for large model compression" (Tencent Hunyuan, 2026).
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod models;
pub mod qat;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod sparse_attn;
pub mod spec_decode;
pub mod tensor;
pub mod token_prune;
pub mod util;
