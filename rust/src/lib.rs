//! AngelSlim-RS: a unified large-model compression and acceleration toolkit.
//!
//! Reproduction of "AngelSlim: A more accessible, comprehensive, and
//! efficient toolkit for large model compression" (Tencent Hunyuan, 2026).
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

// Kernel-style index loops mirror the packed-weight memory layouts on
// purpose (the iterator forms obscure the stride arithmetic the packing
// codecs and GEMV kernels are demonstrating), and several public entry
// points take the full pipeline-configuration argument list.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy
)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod models;
pub mod qat;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod sparse_attn;
pub mod spec_decode;
pub mod tensor;
pub mod token_prune;
pub mod util;
