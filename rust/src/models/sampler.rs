//! Token sampling policies for generation.

use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub enum Sampler {
    Greedy,
    Temperature(f32),
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u8 {
        match self {
            Sampler::Greedy => argmax(logits) as u8,
            Sampler::Temperature(t) => {
                let mut p: Vec<f32> = logits.iter().map(|&l| l / t.max(1e-3)).collect();
                softmax_inplace(&mut p);
                rng.weighted(&p) as u8
            }
        }
    }

    /// Probability of `token` under this sampler's distribution.
    pub fn prob(&self, logits: &[f32], token: u8) -> f32 {
        match self {
            Sampler::Greedy => {
                if argmax(logits) == token as usize {
                    1.0
                } else {
                    0.0
                }
            }
            Sampler::Temperature(t) => {
                let mut p: Vec<f32> = logits.iter().map(|&l| l / t.max(1e-3)).collect();
                softmax_inplace(&mut p);
                p[token as usize]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let mut logits = vec![0.0f32; 256];
        logits[42] = 5.0;
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 42);
        assert_eq!(Sampler::Greedy.prob(&logits, 42), 1.0);
        assert_eq!(Sampler::Greedy.prob(&logits, 41), 0.0);
    }

    #[test]
    fn temperature_sampling_follows_distribution() {
        let mut rng = Rng::new(1);
        let mut logits = vec![0.0f32; 4];
        logits[2] = 3.0;
        let s = Sampler::Temperature(1.0);
        let hits = (0..500)
            .filter(|_| s.sample(&logits[..], &mut rng) == 2)
            .count();
        assert!(hits > 350, "hits {hits}");
        assert!(s.prob(&logits, 2) > 0.7);
    }
}
