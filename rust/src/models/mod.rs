//! Model substrate: loads artifacts/weights.bin + meta.json into a
//! pure-Rust TinyTransformer whose forward matches python/compile/model.py
//! op-for-op. This is the calibration / PTQ / sparse-attention
//! experimentation path; the PJRT artifacts (runtime/) carry the serving
//! hot path.

pub mod kv_cache;
pub mod kv_paged;
pub mod packed;
pub mod packed_store;
pub mod sampler;
pub mod transformer;
pub mod weights;

pub use kv_cache::{KvCache, LayerKv};
pub use kv_paged::{
    is_pool_exhausted, BlockPool, PagedKvCache, PoolExhausted, POOL_EXHAUSTED_PREFIX,
};
pub use packed::PackedLinear;
pub use sampler::Sampler;
pub use transformer::{AttnOverride, Transformer, TransformerCfg};
pub use weights::WeightStore;
