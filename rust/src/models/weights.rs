//! weights.bin / meta.json loader — the layout contract with
//! python/compile/aot.py::export_weights (flat f32 LE, param_spec order).

use crate::config::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub model: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

#[derive(Clone, Debug)]
pub struct WeightStore {
    pub data: Vec<f32>,
    pub params: Vec<ParamInfo>,
    index: BTreeMap<(String, String), usize>,
    pub meta: Json,
}

impl WeightStore {
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let meta_src = std::fs::read_to_string(format!("{artifacts_dir}/meta.json"))
            .context("reading meta.json")?;
        let meta = Json::parse(&meta_src).context("parsing meta.json")?;
        let bin = std::fs::read(format!("{artifacts_dir}/weights.bin"))
            .context("reading weights.bin")?;
        if bin.len() % 4 != 0 {
            bail!("weights.bin length {} not a multiple of 4", bin.len());
        }
        let data: Vec<f32> = bin
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut params = Vec::new();
        let mut index = BTreeMap::new();
        for (i, p) in meta
            .get("layout")
            .and_then(Json::as_arr)
            .context("meta.layout missing")?
            .iter()
            .enumerate()
        {
            let info = ParamInfo {
                model: p.get("model").and_then(Json::as_str).context("model")?.into(),
                name: p.get("name").and_then(Json::as_str).context("name")?.into(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                offset: p.get("offset").and_then(Json::as_usize).context("offset")?,
                len: p.get("len").and_then(Json::as_usize).context("len")?,
            };
            if info.offset + info.len > data.len() {
                bail!("param {} out of bounds", info.name);
            }
            index.insert((info.model.clone(), info.name.clone()), i);
            params.push(info);
        }
        Ok(WeightStore { data, params, index, meta })
    }

    pub fn get(&self, model: &str, name: &str) -> Result<(&[f32], &[usize])> {
        let i = self
            .index
            .get(&(model.to_string(), name.to_string()))
            .with_context(|| format!("param {model}/{name} not found"))?;
        let p = &self.params[*i];
        Ok((&self.data[p.offset..p.offset + p.len], &p.shape))
    }

    /// Model config block from meta.json ("target" / "draft").
    pub fn model_cfg(&self, model: &str) -> Result<super::TransformerCfg> {
        let m = self.meta.get(model).with_context(|| format!("meta.{model}"))?;
        let g = |k: &str| m.get(k).and_then(Json::as_usize).context(k.to_string());
        Ok(super::TransformerCfg {
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            max_t: g("max_t")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_artifacts() -> WeightStore {
        WeightStore::load("artifacts")
            .expect("artifacts missing — run `make artifacts` before `cargo test -- --ignored`")
    }

    #[test]
    #[ignore = "needs artifacts/ on disk — run `make artifacts`, then `cargo test -- --ignored`"]
    fn loads_real_artifacts() {
        let ws = load_artifacts();
        let (embed, shape) = ws.get("target", "embed").unwrap();
        assert_eq!(shape, &[256, 128]);
        assert_eq!(embed.len(), 256 * 128);
        assert!(embed.iter().all(|v| v.is_finite()));
        let cfg = ws.model_cfg("target").unwrap();
        assert_eq!(cfg.d_model, 128);
        assert_eq!(cfg.n_layers, 4);
        let dcfg = ws.model_cfg("draft").unwrap();
        assert_eq!(dcfg.d_model, 64);
    }

    #[test]
    #[ignore = "needs artifacts/ on disk — run `make artifacts`, then `cargo test -- --ignored`"]
    fn missing_param_errors() {
        let ws = load_artifacts();
        assert!(ws.get("target", "nope").is_err());
    }

    #[test]
    fn missing_store_is_an_error_not_a_skip() {
        // a clean checkout has no artifacts — loading must fail loudly
        let r = WeightStore::load("target/definitely-not-artifacts");
        assert!(r.is_err());
    }
}
