//! Paged KV cache: block-granular allocation with copy-on-write prefix
//! sharing — the vLLM-style memory manager behind `serve --paged`.
//!
//! A [`BlockPool`] owns fixed-size pages ("blocks") of `block_tokens`
//! K/V rows per layer; a [`PagedKvCache`] maps a session's logical token
//! positions onto a block table. Identical prompt prefixes hash to the
//! same sealed blocks (chain-hashed per block, verified token-exact on
//! lookup), so a shared system prompt is materialized once and refcounted
//! instead of once per request. Writes into a shared or sealed page fork
//! it first (copy-on-write), and `truncate` releases whole pages, so
//! spec-decode rollback returns memory to the pool immediately.
//!
//! Sharing is **storage-only**: attention still computes the full
//! residual stream for every position, and `append_layer` simply skips
//! writing rows the attached prefix already holds. Because the model is
//! deterministic, those rows are bit-identical to what a fresh session
//! would have written — which is what makes the paged serving path
//! bit-exact against the contiguous [`super::KvCache`] twin.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Mutex};

/// Error returned when a bounded pool cannot supply the blocks an append
/// needs. The serving executors turn this into preemption (evict the
/// lowest-progress session) rather than a request failure.
///
/// The vendored `anyhow` shim carries messages, not payloads, so the
/// executors recognize this condition by the [`POOL_EXHAUSTED_PREFIX`]
/// marker via [`is_pool_exhausted`] instead of downcasting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Blocks the failed append needed (fresh + copy-on-write forks).
    pub needed_blocks: usize,
    /// Blocks the pool could still hand out when the append failed.
    pub free_blocks: usize,
}

/// Marker prefix of [`PoolExhausted`]'s display form; stable because the
/// scheduler-side preemption logic matches on it.
pub const POOL_EXHAUSTED_PREFIX: &str = "kv pool exhausted";

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{POOL_EXHAUSTED_PREFIX}: need {} block(s), {} free",
            self.needed_blocks, self.free_blocks
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// True when `err`'s context chain bottoms out in a [`PoolExhausted`]
/// (the vendored `anyhow` has no downcasting, so this matches the
/// stable message marker).
pub fn is_pool_exhausted(err: &anyhow::Error) -> bool {
    err.chain().any(|e| e.to_string().starts_with(POOL_EXHAUSTED_PREFIX))
}

/// Chain hash of one block's tokens given the parent block's chain hash:
/// FNV-1a-64 seeded with the parent, so equal hashes imply (modulo the
/// token-exact verification in [`BlockPool::lookup`]) equal full
/// prefixes, not just equal chunks.
pub fn chain_hash(parent: u64, chunk: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ parent.wrapping_mul(0x100_0000_01b3);
    for &t in chunk {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Chain-hash seed for a block with no parent (prefix starts at position 0).
pub const ROOT_HASH: u64 = 0x9E37_79B9_7F4A_7C15;

/// One page: `block_tokens` K and V rows for every layer, laid out
/// `(layer * block_tokens + slot) * d_model`. Rows never span blocks, so
/// an attention read of one position is one contiguous `d_model` slice.
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Identity of a sealed (immutable, shareable) block: the chain hash,
/// the parent block in the chain, and the exact tokens this block
/// covers. Lookup verifies all three, so a hash collision can never
/// alias two different prefixes.
struct SealMeta {
    hash: u64,
    parent: Option<usize>,
    tokens: Vec<u8>,
}

/// Fixed-page block allocator with refcounts, a sealed-prefix index for
/// copy-on-write sharing, and honest byte accounting (`allocated_bytes`
/// counts every page the pool has ever grown to, not just resident rows).
pub struct BlockPool {
    n_layers: usize,
    d_model: usize,
    block_tokens: usize,
    /// Hard page cap; 0 = unbounded (library use outside serving).
    max_blocks: usize,
    blocks: Vec<Block>,
    refcount: Vec<u32>,
    sealed: Vec<Option<SealMeta>>,
    /// Generation stamp per block; bumped whenever a block's identity
    /// dies (freed or reclaimed) so stale `evictable` entries are inert.
    stamp: Vec<u64>,
    /// Unsealed blocks with refcount 0 — immediately reusable (LIFO).
    free: Vec<usize>,
    /// chain hash -> sealed block holding that prefix chunk.
    index: HashMap<u64, usize>,
    /// Sealed blocks with refcount 0: kept as prefix cache, reclaimed
    /// FIFO under pressure. Entries are (block, stamp-at-push); stale
    /// entries are skipped on pop.
    evictable: VecDeque<(usize, u64)>,
    /// Count of sealed refcount-0 blocks (live `evictable` entries).
    cached_free: usize,
}

impl BlockPool {
    /// Unbounded pool (grows on demand; no admission pressure).
    pub fn new(n_layers: usize, d_model: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be >= 1");
        assert!(n_layers > 0 && d_model > 0, "degenerate pool shape");
        BlockPool {
            n_layers,
            d_model,
            block_tokens,
            max_blocks: 0,
            blocks: Vec::new(),
            refcount: Vec::new(),
            sealed: Vec::new(),
            stamp: Vec::new(),
            free: Vec::new(),
            index: HashMap::new(),
            evictable: VecDeque::new(),
            cached_free: 0,
        }
    }

    /// Pool capped at `budget_bytes` (at least one block so any request
    /// can make progress).
    pub fn new_bounded(
        n_layers: usize,
        d_model: usize,
        block_tokens: usize,
        budget_bytes: usize,
    ) -> Self {
        let mut p = BlockPool::new(n_layers, d_model, block_tokens);
        p.max_blocks = (budget_bytes / p.block_bytes()).max(1);
        p
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Bytes of one page: K and V rows for all layers.
    pub fn block_bytes(&self) -> usize {
        self.n_layers * 2 * self.block_tokens * self.d_model * std::mem::size_of::<f32>()
    }

    /// Page cap (0 = unbounded).
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Total pages the pool has grown to (free, cached, and in use).
    pub fn total_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Honest footprint: every allocated page, whether resident rows
    /// fill it or not. This is what the scheduler's KV accounting sees.
    pub fn allocated_bytes(&self) -> usize {
        self.blocks.len() * self.block_bytes()
    }

    /// Pages currently referenced by at least one session.
    pub fn in_use_blocks(&self) -> usize {
        self.blocks.len() - self.free.len() - self.cached_free
    }

    /// Sealed refcount-0 pages retained as prefix cache.
    pub fn cached_blocks(&self) -> usize {
        self.cached_free
    }

    /// Sealed (shareable) pages, any refcount.
    pub fn sealed_blocks(&self) -> usize {
        self.sealed.iter().filter(|s| s.is_some()).count()
    }

    /// Pages an allocation could obtain right now: the free list, the
    /// reclaimable prefix cache, and ungrown headroom under `max_blocks`.
    /// Unbounded pools report a saturating "effectively infinite" count.
    pub fn free_blocks(&self) -> usize {
        let headroom = if self.max_blocks == 0 {
            usize::MAX / 4
        } else {
            self.max_blocks.saturating_sub(self.blocks.len())
        };
        self.free.len() + self.cached_free + headroom
    }

    /// Current refcount of `b`.
    pub fn refcount(&self, b: usize) -> u32 {
        self.refcount[b]
    }

    /// Whether `b` is sealed (immutable/shareable).
    pub fn is_sealed(&self, b: usize) -> bool {
        self.sealed[b].is_some()
    }

    /// K row of (`b`, layer `li`, slot) — one `d_model`-wide slice.
    pub fn k_row(&self, b: usize, li: usize, slot: usize) -> &[f32] {
        let off = (li * self.block_tokens + slot) * self.d_model;
        &self.blocks[b].k[off..off + self.d_model]
    }

    /// V row of (`b`, layer `li`, slot).
    pub fn v_row(&self, b: usize, li: usize, slot: usize) -> &[f32] {
        let off = (li * self.block_tokens + slot) * self.d_model;
        &self.blocks[b].v[off..off + self.d_model]
    }

    fn k_row_mut(&mut self, b: usize, li: usize, slot: usize) -> &mut [f32] {
        let off = (li * self.block_tokens + slot) * self.d_model;
        &mut self.blocks[b].k[off..off + self.d_model]
    }

    fn v_row_mut(&mut self, b: usize, li: usize, slot: usize) -> &mut [f32] {
        let off = (li * self.block_tokens + slot) * self.d_model;
        &mut self.blocks[b].v[off..off + self.d_model]
    }

    /// Hand out one page with refcount 1. Order: free list, then grow
    /// (under the cap, or unconditionally when `force` — the overcommit
    /// valve that keeps an already-running session live), then reclaim
    /// from the prefix cache.
    pub fn alloc(&mut self, force: bool) -> Result<usize, PoolExhausted> {
        if let Some(b) = self.free.pop() {
            debug_assert_eq!(self.refcount[b], 0);
            debug_assert!(self.sealed[b].is_none());
            self.refcount[b] = 1;
            return Ok(b);
        }
        if self.max_blocks == 0 || self.blocks.len() < self.max_blocks || force {
            let n = self.n_layers * self.block_tokens * self.d_model;
            self.blocks.push(Block { k: vec![0.0; n], v: vec![0.0; n] });
            self.refcount.push(1);
            self.sealed.push(None);
            self.stamp.push(0);
            return Ok(self.blocks.len() - 1);
        }
        if let Some(b) = self.reclaim_one() {
            self.refcount[b] = 1;
            return Ok(b);
        }
        Err(PoolExhausted { needed_blocks: 1, free_blocks: 0 })
    }

    /// Pop the oldest still-valid prefix-cache entry, unseal it, and
    /// return it for reuse. Stale entries (stamp mismatch, re-attached,
    /// already recycled) are discarded.
    fn reclaim_one(&mut self) -> Option<usize> {
        while let Some((b, s)) = self.evictable.pop_front() {
            if self.stamp[b] != s || self.refcount[b] != 0 || self.sealed[b].is_none() {
                continue;
            }
            self.unseal(b);
            self.stamp[b] += 1;
            self.cached_free -= 1;
            return Some(b);
        }
        None
    }

    /// Drop one reference. At zero, sealed pages move to the prefix
    /// cache (still attachable); unsealed pages go straight to the free
    /// list.
    pub fn unref(&mut self, b: usize) {
        debug_assert!(self.refcount[b] > 0, "unref of free block {b}");
        self.refcount[b] -= 1;
        if self.refcount[b] == 0 {
            if self.sealed[b].is_some() {
                self.cached_free += 1;
                self.evictable.push_back((b, self.stamp[b]));
            } else {
                self.stamp[b] += 1;
                self.free.push(b);
            }
        }
    }

    /// Add a reference to a sealed block found via [`Self::lookup`]
    /// (prefix attach). Revives prefix-cache entries.
    pub fn bump(&mut self, b: usize) {
        if self.refcount[b] == 0 {
            debug_assert!(self.sealed[b].is_some(), "bump of unsealed free block {b}");
            self.cached_free -= 1;
        }
        self.refcount[b] += 1;
    }

    /// Seal `b` as holding `chunk` at chain position (`hash`, `parent`).
    /// Idempotent; first sealer of a hash wins the index slot.
    pub fn seal(&mut self, b: usize, hash: u64, parent: Option<usize>, chunk: &[u8]) {
        debug_assert_eq!(chunk.len(), self.block_tokens, "seal of a partial block");
        if self.sealed[b].is_some() {
            return;
        }
        self.sealed[b] = Some(SealMeta { hash, parent, tokens: chunk.to_vec() });
        self.index.entry(hash).or_insert(b);
    }

    /// Remove `b`'s seal (making it writable again) and drop its index
    /// entry if it owns one.
    pub fn unseal(&mut self, b: usize) {
        if let Some(meta) = self.sealed[b].take() {
            if self.index.get(&meta.hash) == Some(&b) {
                self.index.remove(&meta.hash);
            }
        }
    }

    /// Find the sealed block holding exactly `chunk` at chain position
    /// (`hash`, `parent`). Token-exact + parent-exact verification makes
    /// a match imply full-prefix equality, so the page contents are valid
    /// for the caller's sequence by model determinism.
    pub fn lookup(&self, hash: u64, parent: Option<usize>, chunk: &[u8]) -> Option<usize> {
        let b = *self.index.get(&hash)?;
        match &self.sealed[b] {
            Some(m) if m.hash == hash && m.parent == parent && m.tokens == chunk => Some(b),
            _ => None,
        }
    }

    /// Copy the first `slots` rows of every layer (K and V) from block
    /// `src` into block `dst` — the copy-on-write fork.
    fn copy_slots(&mut self, src: usize, dst: usize, slots: usize) {
        if slots == 0 || src == dst {
            return;
        }
        let (bt, dm, layers) = (self.block_tokens, self.d_model, self.n_layers);
        let (s, d) = if src < dst {
            let (a, b) = self.blocks.split_at_mut(dst);
            (&a[src], &mut b[0])
        } else {
            let (a, b) = self.blocks.split_at_mut(src);
            (&b[0], &mut a[dst])
        };
        for li in 0..layers {
            let at = li * bt * dm;
            let n = slots * dm;
            d.k[at..at + n].copy_from_slice(&s.k[at..at + n]);
            d.v[at..at + n].copy_from_slice(&s.v[at..at + n]);
        }
    }

    /// Pool-level invariant check (tests): refcounts, free list, and
    /// prefix cache partition the page set consistently.
    pub fn check_invariants(&self) {
        assert_eq!(self.refcount.len(), self.blocks.len());
        assert_eq!(self.sealed.len(), self.blocks.len());
        let free_set: std::collections::HashSet<usize> = self.free.iter().copied().collect();
        assert_eq!(free_set.len(), self.free.len(), "free list has duplicates");
        for &b in &self.free {
            assert_eq!(self.refcount[b], 0, "free block {b} has refs");
            assert!(self.sealed[b].is_none(), "free block {b} is sealed");
        }
        let cached = self
            .refcount
            .iter()
            .zip(&self.sealed)
            .filter(|(&rc, s)| rc == 0 && s.is_some())
            .count();
        assert_eq!(cached, self.cached_free, "cached_free count drifted");
        for (&h, &b) in &self.index {
            let m = self.sealed[b].as_ref().expect("index points at unsealed block");
            assert_eq!(m.hash, h, "index hash mismatch");
        }
        if self.max_blocks > 0 {
            // overcommit may have grown past the cap; accounting still
            // has to cover every page
            assert_eq!(
                self.in_use_blocks() + self.free.len() + self.cached_free,
                self.blocks.len()
            );
        }
    }
}

/// A session's view of the pool: logical token positions mapped onto a
/// block table. The cache mirrors the contiguous [`super::KvCache`]
/// protocol — `append_layer` per layer, then `advance` — plus the paged
/// extras: `attach_prefix`/`seal_prefix` for sharing, `prepare_append`
/// for fallible page allocation, `truncate` that returns whole pages.
pub struct PagedKvCache {
    pool: Arc<Mutex<BlockPool>>,
    table: Vec<usize>,
    len: usize,
    /// Positions `0..materialized` are held by attached shared pages;
    /// `append_layer` skips writing them (storage-only sharing).
    materialized: usize,
    /// When set, allocation failures grow the pool past its cap instead
    /// of erroring — the scheduler's last-resort liveness valve.
    overcommit: bool,
}

impl PagedKvCache {
    pub fn new(pool: Arc<Mutex<BlockPool>>) -> Self {
        PagedKvCache { pool, table: Vec::new(), len: 0, materialized: 0, overcommit: false }
    }

    /// Resident token positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shared handle to the backing pool (attention reads borrow it).
    pub fn pool(&self) -> &Arc<Mutex<BlockPool>> {
        &self.pool
    }

    /// The block table: `table()[pos / block_tokens]` holds position `pos`.
    pub fn table(&self) -> &[usize] {
        &self.table
    }

    /// Watermark below which rows live in attached shared pages.
    pub fn materialized(&self) -> usize {
        self.materialized
    }

    pub fn n_layers(&self) -> usize {
        self.pool.lock().unwrap().n_layers
    }

    pub fn d_model(&self) -> usize {
        self.pool.lock().unwrap().d_model
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.lock().unwrap().block_tokens
    }

    /// Enable/disable the past-cap allocation valve.
    pub fn set_overcommit(&mut self, on: bool) {
        self.overcommit = on;
    }

    /// Attach as many sealed full-block prefixes of `tokens` as the pool
    /// already holds (first extend only; no-op on a non-empty cache).
    /// Returns the number of positions attached. Attached rows are
    /// refcounted, never rewritten, and skipped by `append_layer`.
    pub fn attach_prefix(&mut self, tokens: &[u8]) -> usize {
        if self.len != 0 || !self.table.is_empty() {
            return 0;
        }
        let mut pool = self.pool.lock().unwrap();
        let bt = pool.block_tokens;
        let mut parent_hash = ROOT_HASH;
        let mut parent_block: Option<usize> = None;
        let mut matched = 0usize;
        for chunk in tokens.chunks_exact(bt) {
            let h = chain_hash(parent_hash, chunk);
            match pool.lookup(h, parent_block, chunk) {
                Some(b) => {
                    pool.bump(b);
                    self.table.push(b);
                    parent_hash = h;
                    parent_block = Some(b);
                    matched += bt;
                }
                None => break,
            }
        }
        self.materialized = matched;
        matched
    }

    /// Seal every full block covered by `tokens` (and resident rows) so
    /// later sessions with the same prefix can attach it. Idempotent.
    pub fn seal_prefix(&mut self, tokens: &[u8]) {
        let mut pool = self.pool.lock().unwrap();
        let bt = pool.block_tokens;
        let full = (tokens.len().min(self.len)) / bt;
        let mut parent_hash = ROOT_HASH;
        let mut parent_block: Option<usize> = None;
        for (i, chunk) in tokens.chunks_exact(bt).take(full).enumerate() {
            let h = chain_hash(parent_hash, chunk);
            let b = self.table[i];
            pool.seal(b, h, parent_block, chunk);
            parent_hash = h;
            parent_block = Some(b);
        }
    }

    /// Make the table cover `len + t_new` positions, forking a shared or
    /// sealed final page copy-on-write if the first write lands mid-page.
    /// Atomic: on failure nothing is allocated or changed, so the caller
    /// can retry after the scheduler frees pages.
    pub fn prepare_append(&mut self, t_new: usize) -> Result<(), PoolExhausted> {
        if t_new == 0 {
            return Ok(());
        }
        let mut pool = self.pool.lock().unwrap();
        let bt = pool.block_tokens;
        let write_from = self.len.max(self.materialized);
        let target_blocks = (self.len + t_new).div_ceil(bt);
        let fresh_needed = target_blocks.saturating_sub(self.table.len());

        // Copy-on-write: only the page containing the first written row
        // can be shared (every later written page is freshly allocated
        // below), and only a mid-page write can land in it.
        let mut fork_at: Option<usize> = None;
        if write_from % bt != 0 && write_from < self.len + t_new {
            let bi = write_from / bt;
            let b = self.table[bi];
            if pool.refcount(b) > 1 {
                fork_at = Some(bi);
            } else if pool.is_sealed(b) {
                // private but sealed (e.g. rollback into a sealed page):
                // reclaim it for writing in place
                pool.unseal(b);
            }
        }

        let total_needed = fresh_needed + usize::from(fork_at.is_some());
        let mut got: Vec<usize> = Vec::with_capacity(total_needed);
        for _ in 0..total_needed {
            match pool.alloc(self.overcommit) {
                Ok(b) => got.push(b),
                Err(_) => {
                    let free_now = pool.free.len() + pool.cached_free;
                    for b in got {
                        pool.unref(b);
                    }
                    return Err(PoolExhausted {
                        needed_blocks: total_needed,
                        free_blocks: free_now,
                    });
                }
            }
        }

        if let Some(bi) = fork_at {
            let dst = got.pop().expect("fork block was allocated");
            let src = self.table[bi];
            pool.copy_slots(src, dst, write_from % bt);
            pool.unref(src);
            self.table[bi] = dst;
        }
        self.table.extend(got);
        Ok(())
    }

    /// Write the new K/V rows of layer `li` at positions `len..len +
    /// rows` into their pages, skipping rows the attached prefix already
    /// materializes. Requires a successful [`Self::prepare_append`].
    pub fn append_layer(&mut self, li: usize, k_rows: &[f32], v_rows: &[f32]) {
        let mut pool = self.pool.lock().unwrap();
        let d = pool.d_model;
        let bt = pool.block_tokens;
        debug_assert_eq!(k_rows.len(), v_rows.len());
        debug_assert_eq!(k_rows.len() % d, 0);
        for (i, (krow, vrow)) in
            k_rows.chunks_exact(d).zip(v_rows.chunks_exact(d)).enumerate()
        {
            let pos = self.len + i;
            if pos < self.materialized {
                continue;
            }
            let b = self.table[pos / bt];
            pool.k_row_mut(b, li, pos % bt).copy_from_slice(krow);
            pool.v_row_mut(b, li, pos % bt).copy_from_slice(vrow);
        }
    }

    /// Commit `t_new` appended positions (mirrors `KvCache::advance`).
    pub fn advance(&mut self, t_new: usize) {
        self.len += t_new;
        debug_assert!(self.table.len() * self.pool.lock().unwrap().block_tokens >= self.len);
    }

    /// Keep the first `keep` positions, releasing every no-longer-needed
    /// page back to the pool immediately (spec-decode rollback is the
    /// hot caller). Shared pages just drop a reference.
    pub fn truncate(&mut self, keep: usize) {
        if keep >= self.len {
            return;
        }
        let mut pool = self.pool.lock().unwrap();
        let keep_blocks = keep.div_ceil(pool.block_tokens);
        while self.table.len() > keep_blocks {
            let b = self.table.pop().expect("table len checked");
            pool.unref(b);
        }
        self.len = keep;
        // rows past `keep` will be rewritten for the *new* sequence, so
        // the shared-prefix watermark must not cover them anymore
        self.materialized = self.materialized.min(keep);
    }

    /// Release everything.
    pub fn clear(&mut self) {
        let mut pool = self.pool.lock().unwrap();
        for b in self.table.drain(..) {
            pool.unref(b);
        }
        self.len = 0;
        self.materialized = 0;
    }

    /// Logical resident bytes of this session (same formula as the
    /// contiguous cache); the pool's `allocated_bytes` is the honest
    /// page-granular footprint.
    pub fn bytes(&self) -> usize {
        let p = self.pool.lock().unwrap();
        self.len * p.n_layers * 2 * p.d_model * std::mem::size_of::<f32>()
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        // a peer thread panicking with the lock held poisons it; a drop
        // during unwind must not double-panic, so skip cleanup on poison
        if let Ok(mut pool) = self.pool.lock() {
            for b in self.table.drain(..) {
                pool.unref(b);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(max_blocks: usize) -> Arc<Mutex<BlockPool>> {
        let mut p = BlockPool::new(2, 4, 4);
        p.max_blocks = max_blocks;
        Arc::new(Mutex::new(p))
    }

    /// Fill positions `from..to` of every layer with rows of `base + pos`.
    fn append_rows(c: &mut PagedKvCache, from: usize, to: usize, base: f32) {
        let d = c.d_model();
        let t = to - from;
        c.prepare_append(t).expect("prepare");
        for li in 0..c.n_layers() {
            let mut k = Vec::with_capacity(t * d);
            let mut v = Vec::with_capacity(t * d);
            for pos in from..to {
                k.extend(vec![base + pos as f32; d]);
                v.extend(vec![-(base + pos as f32); d]);
            }
            c.append_layer(li, &k, &v);
        }
        c.advance(t);
    }

    #[test]
    fn alloc_free_roundtrip_reuses_pages() {
        let p = pool(0);
        let (a, b) = {
            let mut p = p.lock().unwrap();
            (p.alloc(false).unwrap(), p.alloc(false).unwrap())
        };
        assert_ne!(a, b);
        let mut pm = p.lock().unwrap();
        pm.unref(b);
        pm.unref(a);
        assert_eq!(pm.in_use_blocks(), 0);
        // LIFO free list: last freed is first reused, no new growth
        assert_eq!(pm.alloc(false).unwrap(), a);
        assert_eq!(pm.alloc(false).unwrap(), b);
        assert_eq!(pm.total_blocks(), 2);
        pm.check_invariants();
    }

    #[test]
    fn bounded_pool_exhausts_then_force_grows() {
        let p = pool(2);
        let mut pm = p.lock().unwrap();
        let _a = pm.alloc(false).unwrap();
        let _b = pm.alloc(false).unwrap();
        let err = pm.alloc(false).unwrap_err();
        assert_eq!(err.free_blocks, 0);
        assert!(err.to_string().starts_with(POOL_EXHAUSTED_PREFIX));
        // overcommit valve grows past the cap and accounting follows
        let c = pm.alloc(true).unwrap();
        assert_eq!(pm.total_blocks(), 3);
        assert_eq!(pm.allocated_bytes(), 3 * pm.block_bytes());
        pm.unref(c);
        pm.check_invariants();
    }

    #[test]
    fn seal_attach_shares_pages_and_refcounts() {
        let p = pool(0);
        let toks: Vec<u8> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p));
        assert_eq!(a.attach_prefix(&toks), 0);
        append_rows(&mut a, 0, 8, 100.0);
        a.seal_prefix(&toks);
        assert_eq!(p.lock().unwrap().sealed_blocks(), 2);

        let mut b = PagedKvCache::new(Arc::clone(&p));
        assert_eq!(b.attach_prefix(&toks), 8);
        assert_eq!(b.table(), a.table());
        {
            let pm = p.lock().unwrap();
            assert_eq!(pm.refcount(a.table()[0]), 2);
            assert_eq!(pm.total_blocks(), 2, "no new pages for the shared prefix");
        }
        // b's shared rows read back a's bytes
        assert_eq!(p.lock().unwrap().k_row(b.table()[1], 0, 3)[0], 107.0);

        // divergent prefix attaches only the common chunk
        let mut other = toks.clone();
        other[6] = 99;
        let mut c = PagedKvCache::new(Arc::clone(&p));
        assert_eq!(c.attach_prefix(&other), 4);
        drop(c);
        drop(b);
        drop(a);
        let pm = p.lock().unwrap();
        assert_eq!(pm.in_use_blocks(), 0);
        assert_eq!(pm.cached_blocks(), 2, "sealed pages stay cached after release");
        pm.check_invariants();
    }

    #[test]
    fn cow_fork_preserves_shared_bytes() {
        let p = pool(0);
        let toks: Vec<u8> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 8, 100.0);
        a.seal_prefix(&toks);

        let mut b = PagedKvCache::new(Arc::clone(&p));
        b.attach_prefix(&toks);
        // roll b back mid-page and append divergent rows: the sealed,
        // shared page must fork, leaving a's copy untouched
        b.truncate(6);
        assert_eq!(b.len(), 6);
        let shared = b.table()[1];
        append_rows(&mut b, 6, 8, 500.0);
        assert_ne!(b.table()[1], shared, "write into a shared page must fork");
        let pm = p.lock().unwrap();
        // a's original page: untouched
        assert_eq!(pm.k_row(shared, 0, 2)[0], 106.0);
        assert_eq!(pm.k_row(shared, 1, 3)[0], 107.0);
        // b's fork: copied prefix rows + new divergent rows
        assert_eq!(pm.k_row(b.table()[1], 0, 0)[0], 104.0);
        assert_eq!(pm.k_row(b.table()[1], 0, 1)[0], 105.0);
        assert_eq!(pm.k_row(b.table()[1], 0, 2)[0], 506.0);
        assert_eq!(pm.refcount(shared), 1);
        pm.check_invariants();
    }

    #[test]
    fn private_sealed_page_unseals_in_place_on_rollback_write() {
        let p = pool(0);
        let toks: Vec<u8> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 8, 100.0);
        a.seal_prefix(&toks);
        assert_eq!(p.lock().unwrap().sealed_blocks(), 2);
        // nobody shares the page, so rollback + rewrite reuses it
        a.truncate(6);
        let page = a.table()[1];
        append_rows(&mut a, 6, 8, 500.0);
        assert_eq!(a.table()[1], page, "rc==1 sealed page is unsealed in place");
        let pm = p.lock().unwrap();
        assert!(!pm.is_sealed(page));
        assert_eq!(pm.sealed_blocks(), 1);
        assert_eq!(pm.k_row(page, 0, 2)[0], 506.0);
        pm.check_invariants();
    }

    #[test]
    fn truncate_returns_whole_pages_immediately() {
        let p = pool(4);
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 16, 0.0);
        assert_eq!(p.lock().unwrap().in_use_blocks(), 4);
        assert_eq!(p.lock().unwrap().free_blocks(), 0);
        a.truncate(5);
        {
            let pm = p.lock().unwrap();
            assert_eq!(pm.in_use_blocks(), 2);
            assert_eq!(pm.free_blocks(), 2, "released pages are reusable at once");
        }
        // rollback to a page boundary keeps exactly ceil(keep/bt) pages
        a.truncate(4);
        assert_eq!(p.lock().unwrap().in_use_blocks(), 1);
        append_rows(&mut a, 4, 12, 9.0);
        assert_eq!(p.lock().unwrap().in_use_blocks(), 3);
        p.lock().unwrap().check_invariants();
    }

    #[test]
    fn prepare_append_failure_is_atomic() {
        let p = pool(2);
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 8, 0.0); // both pages in use
        let mut b = PagedKvCache::new(Arc::clone(&p));
        let err = b.prepare_append(5).unwrap_err();
        assert_eq!(err.needed_blocks, 2);
        assert_eq!(err.free_blocks, 0);
        assert_eq!(b.table().len(), 0, "failed prepare must not leak pages");
        assert_eq!(p.lock().unwrap().in_use_blocks(), 2);
        // freeing the victim makes the same prepare succeed
        a.clear();
        b.prepare_append(5).unwrap();
        assert_eq!(b.table().len(), 2);
        p.lock().unwrap().check_invariants();
    }

    #[test]
    fn pressure_reclaims_cached_prefix_pages() {
        let p = pool(2);
        let toks: Vec<u8> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 8, 1.0);
        a.seal_prefix(&toks);
        drop(a); // both pages now cached (sealed, rc 0)
        assert_eq!(p.lock().unwrap().cached_blocks(), 2);
        assert_eq!(p.lock().unwrap().free_blocks(), 2);
        // a new unrelated session must be able to take those pages
        let mut b = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut b, 0, 8, 7.0);
        let pm = p.lock().unwrap();
        assert_eq!(pm.total_blocks(), 2, "reclaimed, not grown");
        assert_eq!(pm.cached_blocks(), 0);
        assert_eq!(pm.sealed_blocks(), 0, "reclaimed pages lost their seal");
        pm.check_invariants();
    }

    #[test]
    fn attach_revives_cached_pages_before_reclaim() {
        let p = pool(2);
        let toks: Vec<u8> = (0..8).collect();
        let mut a = PagedKvCache::new(Arc::clone(&p));
        append_rows(&mut a, 0, 8, 1.0);
        a.seal_prefix(&toks);
        drop(a);
        let mut b = PagedKvCache::new(Arc::clone(&p));
        assert_eq!(b.attach_prefix(&toks), 8, "cached pages still attachable");
        assert_eq!(p.lock().unwrap().cached_blocks(), 0);
        assert_eq!(p.lock().unwrap().in_use_blocks(), 2);
        b.clear();
        p.lock().unwrap().check_invariants();
    }

    #[test]
    fn is_pool_exhausted_matches_through_context_chain() {
        fn inner() -> anyhow::Result<()> {
            Err(PoolExhausted { needed_blocks: 3, free_blocks: 1 })?;
            Ok(())
        }
        use anyhow::Context as _;
        let err = inner().context("request 7: decode step failed").unwrap_err();
        assert!(is_pool_exhausted(&err));
        assert!(!is_pool_exhausted(&anyhow::anyhow!("some other failure")));
    }

    #[test]
    fn randomized_alloc_free_refcount_balance() {
        // deterministic LCG driving a mixed alloc/attach/truncate load
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut rnd = move |m: usize| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as usize) % m
        };
        let p = pool(6);
        let prompts: Vec<Vec<u8>> =
            (0..4).map(|s| (0..12).map(|i| (s * 40 + i) as u8).collect()).collect();
        let mut live: Vec<(PagedKvCache, Vec<u8>)> = Vec::new();
        for step in 0..400 {
            match rnd(4) {
                0 => {
                    let toks = prompts[rnd(prompts.len())].clone();
                    let mut c = PagedKvCache::new(Arc::clone(&p));
                    let got = c.attach_prefix(&toks);
                    let need = toks.len() - got;
                    if c.prepare_append(need).is_ok() {
                        for li in 0..c.n_layers() {
                            let rows = vec![step as f32; need * c.d_model()];
                            c.append_layer(li, &rows, &rows);
                        }
                        c.advance(need);
                        c.seal_prefix(&toks);
                        live.push((c, toks));
                    }
                }
                1 if !live.is_empty() => {
                    let i = rnd(live.len());
                    live.swap_remove(i);
                }
                2 if !live.is_empty() => {
                    let i = rnd(live.len());
                    let keep = rnd(live[i].0.len() + 1);
                    live[i].0.truncate(keep);
                }
                _ if !live.is_empty() => {
                    let i = rnd(live.len());
                    let t = 1 + rnd(3);
                    let c = &mut live[i].0;
                    if c.prepare_append(t).is_ok() {
                        for li in 0..c.n_layers() {
                            let rows = vec![-(step as f32); t * c.d_model()];
                            c.append_layer(li, &rows, &rows);
                        }
                        c.advance(t);
                    }
                }
                _ => {}
            }
            p.lock().unwrap().check_invariants();
        }
        live.clear();
        let pm = p.lock().unwrap();
        assert_eq!(pm.in_use_blocks(), 0, "all refs returned");
        pm.check_invariants();
    }
}
