//! Per-request KV cache for incremental decoding.
//!
//! A [`KvCache`] holds, for every transformer layer, the key/value rows of
//! all tokens processed so far, so `Transformer::prefill` /
//! `Transformer::decode_step` compute Q/K/V only for new positions and
//! attend against cached rows — turning T tokens of generation from
//! O(T³) (full re-forward per token) into O(T²) total work, bit-identical
//! to the full `forward` path.
//!
//! Rollback (`truncate`) supports the speculative-decoding rejection
//! path: the target cache rewinds to the accepted prefix instead of
//! re-forwarding the whole sequence. `bytes()` gives the resident-memory
//! accounting the serving engine reports per in-flight request.

use super::TransformerCfg;

/// Cached key/value rows for one layer, stored flat row-major with
/// `d_model` columns (heads packed along the row, same as the
/// transformer's K/V projections).
#[derive(Clone, Debug, Default)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Per-layer K/V row buffers for one decoding session.
#[derive(Clone, Debug)]
pub struct KvCache {
    d_model: usize,
    max_t: usize,
    len: usize,
    layers: Vec<LayerKv>,
}

impl KvCache {
    /// Empty cache sized for a model config; buffers reserve `max_t` rows
    /// up front so decode steps never reallocate.
    pub fn new(cfg: &TransformerCfg) -> Self {
        Self::new_bounded(cfg, cfg.max_t)
    }

    /// Empty cache whose buffers reserve only `cap_t` rows (clamped to
    /// `max_t`). The serving scheduler sizes each session to its request's
    /// projected peak, so resident allocation matches the KV admission
    /// budget instead of every session malloc'ing the full `max_t`.
    /// Growing past the reservation stays correct (buffers reallocate).
    pub fn new_bounded(cfg: &TransformerCfg, cap_t: usize) -> Self {
        let cap = cap_t.min(cfg.max_t);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: Vec::with_capacity(cap * cfg.d_model),
                v: Vec::with_capacity(cap * cfg.d_model),
            })
            .collect();
        KvCache { d_model: cfg.d_model, max_t: cfg.max_t, len: 0, layers }
    }

    /// Tokens cached so far (the next token decodes at this position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum tokens the owning model can cache.
    pub fn capacity(&self) -> usize {
        self.max_t
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Cached rows of one layer.
    pub fn layer(&self, li: usize) -> &LayerKv {
        &self.layers[li]
    }

    /// Roll the cache back to its first `keep` tokens — the speculative
    /// rejection path. No-op if the cache already holds fewer.
    pub fn truncate(&mut self, keep: usize) {
        if keep >= self.len {
            return;
        }
        let nd = keep * self.d_model;
        for l in &mut self.layers {
            l.k.truncate(nd);
            l.v.truncate(nd);
        }
        self.len = keep;
    }

    pub fn clear(&mut self) {
        self.truncate(0);
    }

    /// Resident bytes of cached K/V rows (2 buffers × layers × len × d).
    pub fn bytes(&self) -> usize {
        self.layers.len() * 2 * self.len * self.d_model * std::mem::size_of::<f32>()
    }

    /// Bytes a full-length (`max_t`) session holds.
    pub fn capacity_bytes(&self) -> usize {
        self.layers.len() * 2 * self.max_t * self.d_model * std::mem::size_of::<f32>()
    }

    /// Append freshly-computed K/V rows to layer `li`. Called once per
    /// layer by `Transformer::prefill` / `decode_step`, which commit the
    /// new length via [`KvCache::advance`] after all layers are extended.
    pub(crate) fn append_layer(&mut self, li: usize, k_rows: &[f32], v_rows: &[f32]) {
        debug_assert_eq!(k_rows.len(), v_rows.len());
        debug_assert_eq!(k_rows.len() % self.d_model, 0);
        let l = &mut self.layers[li];
        // Grow by the exact deficit: `extend_from_slice` alone doubles the
        // buffer when it outgrows `new_bounded`'s reservation, silently
        // allocating far past the admission budget while `bytes()` keeps
        // reporting only resident rows. `reserve_exact` keeps the real
        // allocation tied to what was admitted.
        let deficit = |buf: &Vec<f32>, add: usize| (buf.len() + add).saturating_sub(buf.capacity());
        let dk = deficit(&l.k, k_rows.len());
        if dk > 0 {
            l.k.reserve_exact(dk);
        }
        let dv = deficit(&l.v, v_rows.len());
        if dv > 0 {
            l.v.reserve_exact(dv);
        }
        l.k.extend_from_slice(k_rows);
        l.v.extend_from_slice(v_rows);
    }

    /// Commit `t_new` appended tokens (every layer must have been extended).
    pub(crate) fn advance(&mut self, t_new: usize) {
        self.len += t_new;
        debug_assert!(
            self.layers.iter().all(|l| l.k.len() == self.len * self.d_model),
            "cache advance without matching per-layer rows"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TransformerCfg {
        TransformerCfg { vocab: 256, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64, max_t: 48 }
    }

    #[test]
    fn empty_cache_accounting() {
        let c = KvCache::new(&cfg());
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.capacity(), 48);
        assert_eq!(c.capacity_bytes(), 2 * 2 * 48 * 32 * 4);
    }

    #[test]
    fn bounded_cache_reserves_only_the_cap() {
        let c = KvCache::new_bounded(&cfg(), 10);
        let reserved = c.layer(0).k.capacity();
        assert!(
            (10 * 32..48 * 32).contains(&reserved),
            "reserved {reserved} rows*d, want ~10 tokens not max_t"
        );
        assert_eq!(c.capacity(), 48, "logical capacity stays max_t");
        // the cap clamps to max_t
        let big = KvCache::new_bounded(&cfg(), 1000);
        assert!(big.layer(0).k.capacity() >= 48 * 32);
    }

    #[test]
    fn growth_past_the_reservation_stays_exact() {
        // a bounded cache that outgrows its reservation must not let Vec
        // doubling balloon the real allocation past the admitted bytes
        let mut c = KvCache::new_bounded(&cfg(), 4);
        let row = vec![0.25f32; 32];
        for t in 0..12 {
            for li in 0..2 {
                c.append_layer(li, &row, &row);
            }
            c.advance(1);
            if t >= 4 {
                for li in 0..2 {
                    let l = c.layer(li);
                    assert_eq!(l.k.capacity(), l.k.len(), "k grew non-exactly at t={t}");
                    assert_eq!(l.v.capacity(), l.v.len(), "v grew non-exactly at t={t}");
                }
            }
        }
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn append_advance_truncate_roundtrip() {
        let mut c = KvCache::new(&cfg());
        let rows = vec![0.5f32; 3 * 32];
        for li in 0..2 {
            c.append_layer(li, &rows, &rows);
        }
        c.advance(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 32 * 4);
        c.truncate(1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.layer(0).k.len(), 32);
        assert_eq!(c.bytes(), 2 * 2 * 32 * 4);
        // truncating past the end is a no-op
        c.truncate(10);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
    }
}
