//! `PackedLinear` — the quantized execution bridge between compression and
//! serving. A `Transformer` holds one `PackedLinear` per weight matrix; f32
//! models keep dense tensors while compressed models store the packed codec
//! (`rust/src/quant/packing.rs`) and route the decode hot path through the
//! LUT GEMV kernels, reading 4–26x fewer weight bytes per token.
//!
//! Correctness contract: `matmul` (prefill, t>1) is **bit-identical** to
//! `matmul_transb(x, &self.dequantize())` — the fused path dequantizes each
//! weight row with the quantizer's exact `dequantize_codes` arithmetic and
//! preserves `matmul_transb`'s accumulation order. `matvec` (decode, t=1)
//! uses the fast LUT kernels, which reassociate the dot product; it matches
//! the dequantized model to float tolerance, and end-to-end greedy decode on
//! the fixtures is token-identical (logit margins dwarf the kernel deltas).

use crate::quant::packing::{
    PackFormat, Packed2Bit, PackedInt4, PackedSherry, PackedTernary167,
};
use crate::quant::{AffineQuantizer, Granularity, Sherry, TernaryQuantizer};
use crate::tensor::ops::{matmul_transb, matmul_transb_rows, matvec_transb};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// One linear weight matrix, either dense f32 or in a packed storage format.
#[derive(Clone, Debug)]
pub enum PackedLinear {
    F32(Tensor),
    Int4(PackedInt4),
    TwoBit(Packed2Bit),
    Ternary167(PackedTernary167),
    Sherry125(PackedSherry),
}

impl From<Tensor> for PackedLinear {
    fn from(t: Tensor) -> Self {
        PackedLinear::F32(t)
    }
}

impl PackedLinear {
    /// Quantize + pack a dense weight into `fmt` storage. `group` is the
    /// int4 group size (ignored by other formats). Shape constraints are
    /// reported as errors here rather than asserts so pipeline stages can
    /// surface them with layer context.
    pub fn from_tensor(w: &Tensor, fmt: PackFormat, group: usize) -> Result<PackedLinear> {
        let (n, k) = (w.rows(), w.cols());
        Ok(match fmt {
            PackFormat::F32 => PackedLinear::F32(w.clone()),
            PackFormat::F16 => bail!("f16 is accounting-only; it has no packed execution kernel"),
            PackFormat::Int4 => {
                if group == 0 || group % 2 != 0 {
                    bail!("int4 group {group} must be even and non-zero");
                }
                if k % group != 0 {
                    bail!("cols {k} not divisible by int4 group {group}");
                }
                let q = AffineQuantizer::new(4, Granularity::Group(group));
                let (codes, scales) = q.quantize_codes(&w.data, n, k);
                PackedLinear::Int4(PackedInt4::from_codes(&codes, &scales, n, k, group))
            }
            PackFormat::TwoBit => {
                if k % 4 != 0 {
                    bail!("cols {k} not divisible by 4 (2-bit packs 4 codes per byte)");
                }
                let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w.data, n, k);
                PackedLinear::TwoBit(Packed2Bit::from_codes(&codes, &alphas, n, k))
            }
            PackFormat::Ternary167 => {
                let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w.data, n, k);
                PackedLinear::Ternary167(PackedTernary167::from_codes(&codes, &alphas, n, k))
            }
            PackFormat::Sherry125 => {
                if k % 4 != 0 {
                    bail!("cols {k} not divisible by 4 (sherry packs 4-weight blocks)");
                }
                let (codes, alphas) = Sherry::quantize_codes(&w.data, n, k);
                PackedLinear::Sherry125(PackedSherry::from_codes(&codes, &alphas, n, k))
            }
        })
    }

    pub fn rows(&self) -> usize {
        match self {
            PackedLinear::F32(t) => t.rows(),
            PackedLinear::Int4(p) => p.n,
            PackedLinear::TwoBit(p) => p.n,
            PackedLinear::Ternary167(p) => p.n,
            PackedLinear::Sherry125(p) => p.n,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedLinear::F32(t) => t.cols(),
            PackedLinear::Int4(p) => p.k,
            PackedLinear::TwoBit(p) => p.k,
            PackedLinear::Ternary167(p) => p.k,
            PackedLinear::Sherry125(p) => p.k,
        }
    }

    pub fn dims(&self) -> [usize; 2] {
        [self.rows(), self.cols()]
    }

    pub fn numel(&self) -> usize {
        self.rows() * self.cols()
    }

    pub fn format(&self) -> PackFormat {
        match self {
            PackedLinear::F32(_) => PackFormat::F32,
            PackedLinear::Int4(_) => PackFormat::Int4,
            PackedLinear::TwoBit(_) => PackFormat::TwoBit,
            PackedLinear::Ternary167(_) => PackFormat::Ternary167,
            PackedLinear::Sherry125(_) => PackFormat::Sherry125,
        }
    }

    pub fn is_packed(&self) -> bool {
        !matches!(self, PackedLinear::F32(_))
    }

    /// Bytes this weight actually occupies in memory / on disk (packed
    /// payload plus per-row or per-group float metadata).
    pub fn stored_bytes(&self) -> usize {
        match self {
            PackedLinear::F32(t) => t.numel() * 4,
            PackedLinear::Int4(p) => p.bytes.len() + p.scales.len() * 4,
            PackedLinear::TwoBit(p) => p.bytes.len() + p.alphas.len() * 4,
            PackedLinear::Ternary167(p) => p.bytes.len() + p.alphas.len() * 4,
            PackedLinear::Sherry125(p) => p.bytes.len() + p.alphas.len() * 4,
        }
    }

    /// Dense-f32 view; panics loudly on packed weights so callers that
    /// genuinely need mutable f32 data (QDQ passes, flat_weights snapshots)
    /// fail with a clear message instead of silently reading garbage.
    pub fn f32(&self) -> &Tensor {
        match self {
            PackedLinear::F32(t) => t,
            other => panic!(
                "weight is {}-packed; call dequantize() instead of f32()",
                other.format().name()
            ),
        }
    }

    pub fn f32_mut(&mut self) -> &mut Tensor {
        match self {
            PackedLinear::F32(t) => t,
            other => panic!(
                "weight is {}-packed; packed weights cannot be mutated as f32",
                other.format().name()
            ),
        }
    }

    /// Dequantize row `j` into `out`, bit-identical to the quantizer's
    /// `dequantize_codes` for that row (f32 weights just copy).
    pub fn dequant_row(&self, j: usize, out: &mut [f32]) {
        match self {
            PackedLinear::F32(t) => out.copy_from_slice(t.row(j)),
            PackedLinear::Int4(p) => p.dequant_row(j, out),
            PackedLinear::TwoBit(p) => p.dequant_row(j, out),
            PackedLinear::Ternary167(p) => p.dequant_row(j, out),
            PackedLinear::Sherry125(p) => p.dequant_row(j, out),
        }
    }

    /// The exact f32 image the packed kernels compute with.
    pub fn dequantize(&self) -> Tensor {
        match self {
            PackedLinear::F32(t) => t.clone(),
            _ => {
                let (n, k) = (self.rows(), self.cols());
                let mut t = Tensor::zeros(&[n, k]);
                for j in 0..n {
                    self.dequant_row(j, t.row_mut(j));
                }
                t
            }
        }
    }

    /// Decode hot path: y = W x for a single token. Packed formats with a
    /// half-byte LUT kernel (2-bit, int4) use it; `scratch` holds the LUT
    /// tables and is reused across calls to avoid per-token allocation.
    pub fn matvec(&self, x: &[f32], scratch: &mut Vec<f32>) -> Vec<f32> {
        match self {
            PackedLinear::F32(t) => matvec_transb(x, t),
            PackedLinear::Int4(p) => {
                let mut y = vec![0.0; p.n];
                p.gemv_fast(x, &mut y, scratch);
                y
            }
            PackedLinear::TwoBit(p) => {
                let mut y = vec![0.0; p.n];
                p.gemv_fast(x, &mut y, scratch);
                y
            }
            PackedLinear::Ternary167(p) => {
                let mut y = vec![0.0; p.n];
                p.gemv(x, &mut y);
                y
            }
            PackedLinear::Sherry125(p) => {
                let mut y = vec![0.0; p.n];
                p.gemv(x, &mut y);
                y
            }
        }
    }

    /// Prefill path: x `[m,k]` times W^T, fused per-row dequant for packed
    /// formats. Bit-identical to `matmul_transb(x, &self.dequantize())`.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        match self {
            PackedLinear::F32(t) => matmul_transb(x, t),
            packed => matmul_transb_rows(x, packed.rows(), packed.cols(), |j, buf| {
                packed.dequant_row(j, buf)
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_allclose;
    use crate::util::Rng;

    fn weight(n: usize, k: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&[n, k], 0.3, &mut rng)
    }

    const FORMATS: [PackFormat; 4] = [
        PackFormat::Int4,
        PackFormat::TwoBit,
        PackFormat::Ternary167,
        PackFormat::Sherry125,
    ];

    #[test]
    fn matmul_bit_identical_to_dequantized_dense() {
        let w = weight(24, 32, 7);
        let mut rng = Rng::new(11);
        let x = Tensor::randn(&[6, 32], 1.0, &mut rng);
        for fmt in FORMATS {
            let p = PackedLinear::from_tensor(&w, fmt, 16).unwrap();
            let fused = p.matmul(&x);
            let dense = matmul_transb(&x, &p.dequantize());
            assert_eq!(fused.data, dense.data, "{} fused prefill drifted", fmt.name());
        }
    }

    #[test]
    fn matvec_matches_dequantized_dense() {
        let w = weight(24, 32, 3);
        let mut rng = Rng::new(13);
        let x = Tensor::randn(&[1, 32], 1.0, &mut rng);
        let mut scratch = Vec::new();
        for fmt in FORMATS {
            let p = PackedLinear::from_tensor(&w, fmt, 16).unwrap();
            let fast = p.matvec(&x.data, &mut scratch);
            let dense = matvec_transb(&x.data, &p.dequantize());
            assert_allclose(&fast, &dense, 1e-5, 1e-5);
        }
    }

    #[test]
    fn packed_formats_shrink_storage() {
        let w = weight(64, 64, 5);
        let f32_bytes = PackedLinear::from(w.clone()).stored_bytes();
        assert_eq!(f32_bytes, 64 * 64 * 4);
        for fmt in FORMATS {
            let p = PackedLinear::from_tensor(&w, fmt, 32).unwrap();
            assert!(p.is_packed());
            assert_eq!(p.format(), fmt);
            assert_eq!(p.dims(), [64, 64]);
            assert!(
                p.stored_bytes() * 4 < f32_bytes,
                "{} stored {} bytes, expected > 4x shrink vs {f32_bytes}",
                fmt.name(),
                p.stored_bytes()
            );
        }
    }

    #[test]
    fn from_tensor_rejects_bad_shapes() {
        let w = weight(4, 10, 9); // k=10: not divisible by 4, not by group 16
        assert!(PackedLinear::from_tensor(&w, PackFormat::TwoBit, 0).is_err());
        assert!(PackedLinear::from_tensor(&w, PackFormat::Sherry125, 0).is_err());
        assert!(PackedLinear::from_tensor(&w, PackFormat::Int4, 16).is_err());
        assert!(PackedLinear::from_tensor(&w, PackFormat::Int4, 3).is_err(), "odd group");
        assert!(PackedLinear::from_tensor(&w, PackFormat::F16, 0).is_err());
        // ternary 1.67 pads rows, so any k works
        assert!(PackedLinear::from_tensor(&w, PackFormat::Ternary167, 0).is_ok());
    }

    #[test]
    #[should_panic(expected = "packed")]
    fn f32_accessor_panics_on_packed() {
        let w = weight(8, 16, 1);
        let p = PackedLinear::from_tensor(&w, PackFormat::TwoBit, 0).unwrap();
        let _ = p.f32();
    }
}
