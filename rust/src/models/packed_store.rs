//! On-disk packed-model artifact: the contract between `angelslim
//! compress` (the `export-packed` pipeline stage) and `angelslim serve`
//! (the `packed-artifact` model factory). Two files per artifact dir:
//!
//! - `packed_meta.json` — model shape plus one `{name, format, n, k,
//!   group}` entry per linear weight, in [`Transformer::named_weights`]
//!   order.
//! - `packed_weights.bin` — length-prefixed sections (u64 LE count, then
//!   payload): embed, pos, ln_f, per-layer ln1+ln2, then each weight's
//!   sections in meta order. f32 sections store LE floats; packed weights
//!   store their per-row scale/alpha floats first, then the raw code
//!   bytes exactly as the in-memory packed structs hold them.
//!
//! The round trip is bit-exact: loading rebuilds the packed structs from
//! the stored bytes verbatim (no re-quantization), so a served packed
//! artifact produces the same tokens as the model that exported it.

use crate::config::Json;
use crate::quant::packing::{
    PackFormat, Packed2Bit, PackedInt4, PackedSherry, PackedTernary167,
};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

use super::packed::PackedLinear;
use super::transformer::Layer;
use super::{Transformer, TransformerCfg};

/// Artifact file names — shared with the serve path and CI so the
/// compress→serve handoff never drifts.
pub const META_FILE: &str = "packed_meta.json";
pub const WEIGHTS_FILE: &str = "packed_weights.bin";

fn push_f32s(buf: &mut Vec<u8>, vals: &[f32]) {
    buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn push_bytes(buf: &mut Vec<u8>, vals: &[u8]) {
    buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    buf.extend_from_slice(vals);
}

fn push_weight(buf: &mut Vec<u8>, w: &PackedLinear) {
    match w {
        PackedLinear::F32(t) => push_f32s(buf, &t.data),
        PackedLinear::Int4(p) => {
            push_f32s(buf, &p.scales);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::TwoBit(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::Ternary167(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::Sherry125(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "packed weights truncated: need {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn read_len(&mut self) -> Result<usize> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()) as usize)
    }

    fn read_f32s(&mut self, expect: usize) -> Result<Vec<f32>> {
        let n = self.read_len()?;
        if n != expect {
            bail!("packed weights: section holds {n} f32s, expected {expect}");
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn read_bytes(&mut self, expect: usize) -> Result<Vec<u8>> {
        let n = self.read_len()?;
        if n != expect {
            bail!("packed weights: section holds {n} bytes, expected {expect}");
        }
        Ok(self.take(n)?.to_vec())
    }
}

/// Serialize a (possibly packed) model into `dir`. Returns the total
/// bytes written across both artifact files.
pub fn save_packed(model: &Transformer, dir: &str) -> Result<usize> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating artifact dir {dir}"))?;
    let cfg = model.cfg;

    let mut buf = Vec::new();
    push_f32s(&mut buf, &model.embed.data);
    push_f32s(&mut buf, &model.pos.data);
    push_f32s(&mut buf, &model.ln_f);
    for l in &model.layers {
        push_f32s(&mut buf, &l.ln1);
        push_f32s(&mut buf, &l.ln2);
    }

    let mut entries = Vec::new();
    for (name, w) in model.named_weights() {
        let group = match w {
            PackedLinear::Int4(p) => p.group,
            _ => 0,
        };
        let [n, k] = w.dims();
        entries.push(format!(
            "{{\"name\":\"{name}\",\"format\":\"{}\",\"n\":{n},\"k\":{k},\"group\":{group}}}",
            w.format().name()
        ));
        push_weight(&mut buf, w);
    }

    let meta = format!(
        "{{\"kind\":\"packed-model\",\"cfg\":{{\"vocab\":{},\"d_model\":{},\"n_layers\":{},\"n_heads\":{},\"d_ff\":{},\"max_t\":{}}},\"weights\":[{}]}}",
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_t,
        entries.join(",")
    );

    let meta_path = format!("{dir}/{META_FILE}");
    let bin_path = format!("{dir}/{WEIGHTS_FILE}");
    std::fs::write(&meta_path, meta.as_bytes()).with_context(|| format!("writing {meta_path}"))?;
    std::fs::write(&bin_path, &buf).with_context(|| format!("writing {bin_path}"))?;
    Ok(meta.len() + buf.len())
}

/// Byte length of a weight's packed code payload — must agree with the
/// `from_codes` packers in `quant::packing` or loads reject the file.
fn payload_bytes(fmt: PackFormat, n: usize, k: usize) -> usize {
    match fmt {
        PackFormat::Int4 => n * k / 2,
        PackFormat::TwoBit => n * k / 4,
        PackFormat::Ternary167 => (n * k.div_ceil(3) * 5).div_ceil(8),
        PackFormat::Sherry125 => (n * (k / 4) * 5).div_ceil(8),
        PackFormat::F32 | PackFormat::F16 => 0,
    }
}

fn read_weight(
    r: &mut Reader,
    fmt: PackFormat,
    n: usize,
    k: usize,
    group: usize,
) -> Result<PackedLinear> {
    Ok(match fmt {
        PackFormat::F32 => PackedLinear::F32(Tensor::from_vec(&[n, k], r.read_f32s(n * k)?)),
        PackFormat::F16 => bail!("f16 is accounting-only and never serialized"),
        PackFormat::Int4 => {
            if group == 0 || group % 2 != 0 || k % group != 0 {
                bail!("int4 weight needs an even group dividing k={k}, meta says {group}");
            }
            let scales = r.read_f32s(n * (k / group))?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Int4(PackedInt4 { n, k, group, bytes, scales })
        }
        PackFormat::TwoBit => {
            if k % 4 != 0 {
                bail!("2bit weight needs k divisible by 4, meta says k={k}");
            }
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::TwoBit(Packed2Bit { n, k, bytes, alphas })
        }
        PackFormat::Ternary167 => {
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Ternary167(PackedTernary167 { n, k, bytes, alphas })
        }
        PackFormat::Sherry125 => {
            if k % 4 != 0 {
                bail!("sherry weight needs k divisible by 4, meta says k={k}");
            }
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Sherry125(PackedSherry { n, k, bytes, alphas })
        }
    })
}

/// Load a packed artifact back into a servable [`Transformer`],
/// bit-exactly reproducing the model [`save_packed`] was given.
pub fn load_packed(dir: &str) -> Result<Transformer> {
    let meta_path = format!("{dir}/{META_FILE}");
    let src = std::fs::read_to_string(&meta_path).with_context(|| {
        format!("reading {meta_path} — run a pipeline with an `export-packed` stage first")
    })?;
    let meta = Json::parse(&src).with_context(|| format!("parsing {meta_path}"))?;
    match meta.get("kind").and_then(Json::as_str) {
        Some("packed-model") => {}
        other => bail!("{meta_path}: kind is {other:?}, expected \"packed-model\""),
    }

    let cfgj = meta.get("cfg").with_context(|| format!("{meta_path}: missing cfg"))?;
    let dim = |key: &str| -> Result<usize> {
        cfgj.get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("{meta_path}: cfg.{key} missing or not a count"))
    };
    let cfg = TransformerCfg {
        vocab: dim("vocab")?,
        d_model: dim("d_model")?,
        n_layers: dim("n_layers")?,
        n_heads: dim("n_heads")?,
        d_ff: dim("d_ff")?,
        max_t: dim("max_t")?,
    };

    let bin_path = format!("{dir}/{WEIGHTS_FILE}");
    let raw = std::fs::read(&bin_path).with_context(|| format!("reading {bin_path}"))?;
    let mut r = Reader { buf: &raw, pos: 0 };
    let d = cfg.d_model;
    let embed = Tensor::from_vec(&[cfg.vocab, d], r.read_f32s(cfg.vocab * d)?);
    let pos = Tensor::from_vec(&[cfg.max_t, d], r.read_f32s(cfg.max_t * d)?);
    let ln_f = r.read_f32s(d)?;
    let mut norms = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        let ln1 = r.read_f32s(d)?;
        let ln2 = r.read_f32s(d)?;
        norms.push((ln1, ln2));
    }

    let entries = meta
        .get("weights")
        .and_then(Json::as_arr)
        .with_context(|| format!("{meta_path}: missing weights array"))?;
    let expected = cfg.n_layers * 7 + 1;
    if entries.len() != expected {
        bail!(
            "{meta_path}: lists {} weights, a {}-layer model has {expected}",
            entries.len(),
            cfg.n_layers
        );
    }

    // expected shapes in named_weights order, to cross-check the meta
    let mut shapes = Vec::with_capacity(expected);
    for i in 0..cfg.n_layers {
        for w in ["wq", "wk", "wv", "wo"] {
            shapes.push((format!("layer{i}.{w}"), d, d));
        }
        shapes.push((format!("layer{i}.w_gate"), cfg.d_ff, d));
        shapes.push((format!("layer{i}.w_up"), cfg.d_ff, d));
        shapes.push((format!("layer{i}.w_down"), d, cfg.d_ff));
    }
    shapes.push(("head".to_string(), cfg.vocab, d));

    let mut linears = Vec::with_capacity(expected);
    for (entry, (want_name, want_n, want_k)) in entries.iter().zip(&shapes) {
        let name = entry
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("{meta_path}: weight entry missing name"))?;
        if name != want_name.as_str() {
            bail!("{meta_path}: weight `{name}` out of order, expected `{want_name}`");
        }
        let field = |key: &str| -> Result<usize> {
            entry
                .get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("{meta_path}: weight `{name}`: bad {key}"))
        };
        let (n, k, group) = (field("n")?, field("k")?, field("group")?);
        if n != *want_n || k != *want_k {
            bail!("{meta_path}: weight `{name}` is [{n}, {k}], cfg implies [{want_n}, {want_k}]");
        }
        let fmt_s = entry
            .get("format")
            .and_then(Json::as_str)
            .with_context(|| format!("{meta_path}: weight `{name}`: missing format"))?;
        let fmt = PackFormat::parse(fmt_s)
            .with_context(|| format!("{meta_path}: weight `{name}`: unknown format `{fmt_s}`"))?;
        let w = read_weight(&mut r, fmt, n, k, group)
            .with_context(|| format!("{bin_path}: weight `{name}`"))?;
        linears.push(w);
    }
    if r.pos != raw.len() {
        bail!("{bin_path}: {} trailing bytes after last weight", raw.len() - r.pos);
    }

    let mut it = linears.into_iter();
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for (ln1, ln2) in norms {
        layers.push(Layer {
            ln1,
            wq: it.next().unwrap(),
            wk: it.next().unwrap(),
            wv: it.next().unwrap(),
            wo: it.next().unwrap(),
            ln2,
            w_gate: it.next().unwrap(),
            w_up: it.next().unwrap(),
            w_down: it.next().unwrap(),
        });
    }
    let head = it.next().unwrap();
    Ok(Transformer { cfg, embed, pos, layers, ln_f, head })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AttnOverride;
    use crate::util::fixtures::fixture_target;
    use crate::util::Selector;

    fn tmp_dir(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("angelslim_packed_store_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip_is_bit_exact_for_mixed_formats() {
        let mut m = fixture_target(7);
        // mixed precision: 2bit MLP gates, int4 attention, f32 the rest
        let sel = Selector::new(&["w_gate".into(), "w_up".into()], &[]).unwrap();
        assert!(m.pack_weights(&sel, PackFormat::TwoBit, 0).unwrap() > 0);
        let sel = Selector::new(&["wq".into(), "wv".into()], &[]).unwrap();
        assert!(m.pack_weights(&sel, PackFormat::Int4, 16).unwrap() > 0);

        let dir = tmp_dir("roundtrip");
        let bytes = save_packed(&m, &dir).unwrap();
        assert!(bytes > 0);
        let loaded = load_packed(&dir).unwrap();

        assert_eq!(loaded.cfg, m.cfg);
        assert_eq!(loaded.embed.data, m.embed.data);
        for (a, b) in m.named_weights().iter().zip(loaded.named_weights().iter()) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.format(), b.1.format(), "{}", a.0);
            assert_eq!(a.1.stored_bytes(), b.1.stored_bytes(), "{}", a.0);
        }
        let toks = [3u8, 8, 13, 18];
        let la = m.forward(&toks, &AttnOverride::None);
        let lb = loaded.forward(&toks, &AttnOverride::None);
        assert_eq!(la.data, lb.data, "loaded artifact must forward bit-identically");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrip_covers_every_pack_format() {
        for fmt in [
            PackFormat::Int4,
            PackFormat::TwoBit,
            PackFormat::Ternary167,
            PackFormat::Sherry125,
        ] {
            let mut m = fixture_target(3);
            m.pack_weights(&Selector::all(), fmt, 16).unwrap();
            let dir = tmp_dir(fmt.name());
            save_packed(&m, &dir).unwrap();
            let loaded = load_packed(&dir).unwrap();
            let la = m.forward(&[5u8, 10, 15], &AttnOverride::None);
            let lb = loaded.forward(&[5u8, 10, 15], &AttnOverride::None);
            assert_eq!(la.data, lb.data, "{} artifact drifted", fmt.name());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn load_rejects_missing_artifact() {
        let err = load_packed("/nonexistent/packed/dir").unwrap_err();
        assert!(err.to_string().contains("export-packed"), "{err}");
    }

    #[test]
    fn load_rejects_corrupt_payload() {
        let mut m = fixture_target(4);
        m.pack_weights(&Selector::all(), PackFormat::TwoBit, 0).unwrap();
        let dir = tmp_dir("corrupt");
        save_packed(&m, &dir).unwrap();
        let bin = format!("{dir}/{WEIGHTS_FILE}");
        let mut raw = std::fs::read(&bin).unwrap();
        raw.truncate(raw.len() - 9);
        std::fs::write(&bin, &raw).unwrap();
        assert!(load_packed(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
