//! On-disk packed-model artifact: the contract between `angelslim
//! compress` (the `export-packed` pipeline stage) and `angelslim serve`
//! (the `packed-artifact` model factory). Two files per artifact dir:
//!
//! - `packed_meta.json` — model shape plus one `{name, format, n, k,
//!   group}` entry per linear weight, in [`Transformer::named_weights`]
//!   order.
//! - `packed_weights.bin` — length-prefixed sections (u64 LE count, then
//!   payload): embed, pos, ln_f, per-layer ln1+ln2, then each weight's
//!   sections in meta order. f32 sections store LE floats; packed weights
//!   store their per-row scale/alpha floats first, then the raw code
//!   bytes exactly as the in-memory packed structs hold them.
//!
//! The round trip is bit-exact: loading rebuilds the packed structs from
//! the stored bytes verbatim (no re-quantization), so a served packed
//! artifact produces the same tokens as the model that exported it.

use crate::config::Json;
use crate::quant::packing::{
    PackFormat, Packed2Bit, PackedInt4, PackedSherry, PackedTernary167,
};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

use super::packed::PackedLinear;
use super::transformer::Layer;
use super::{Transformer, TransformerCfg};

/// Artifact file names — shared with the serve path and CI so the
/// compress→serve handoff never drifts.
pub const META_FILE: &str = "packed_meta.json";
pub const WEIGHTS_FILE: &str = "packed_weights.bin";

/// FNV-1a 64 over the weights buffer — a cheap, dependency-free integrity
/// check. The digest is stored in `packed_meta.json` and re-verified on
/// load, so a truncated or bit-flipped `packed_weights.bin` fails loudly
/// instead of decoding into silently-wrong weights.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_f32s(buf: &mut Vec<u8>, vals: &[f32]) {
    buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn push_bytes(buf: &mut Vec<u8>, vals: &[u8]) {
    buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    buf.extend_from_slice(vals);
}

fn push_weight(buf: &mut Vec<u8>, w: &PackedLinear) {
    match w {
        PackedLinear::F32(t) => push_f32s(buf, &t.data),
        PackedLinear::Int4(p) => {
            push_f32s(buf, &p.scales);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::TwoBit(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::Ternary167(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
        PackedLinear::Sherry125(p) => {
            push_f32s(buf, &p.alphas);
            push_bytes(buf, &p.bytes);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        match self.pos.checked_add(n) {
            Some(end) if end <= self.buf.len() => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            _ => bail!(
                "packed weights truncated: need {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            ),
        }
    }

    fn read_len(&mut self) -> Result<usize> {
        let b = self.take(8)?;
        let n = u64::from_le_bytes(b.try_into().unwrap());
        // a length prefix can never legitimately exceed what's left of the
        // file; bounding it here keeps a corrupt prefix from driving huge
        // (or overflowing) downstream allocations
        let remaining = (self.buf.len() - self.pos) as u64;
        if n > remaining {
            bail!(
                "packed weights: section length {n} at offset {} exceeds the \
                 {remaining} bytes left in the file (corrupt length prefix?)",
                self.pos - 8
            );
        }
        Ok(n as usize)
    }

    fn read_f32s(&mut self, expect: usize) -> Result<Vec<f32>> {
        let n = self.read_len()?;
        if n != expect {
            bail!("packed weights: section holds {n} f32s, expected {expect}");
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn read_bytes(&mut self, expect: usize) -> Result<Vec<u8>> {
        let n = self.read_len()?;
        if n != expect {
            bail!("packed weights: section holds {n} bytes, expected {expect}");
        }
        Ok(self.take(n)?.to_vec())
    }
}

/// Serialize a (possibly packed) model into `dir`. Returns the total
/// bytes written across both artifact files.
pub fn save_packed(model: &Transformer, dir: &str) -> Result<usize> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating artifact dir {dir}"))?;
    let cfg = model.cfg;

    let mut buf = Vec::new();
    push_f32s(&mut buf, &model.embed.data);
    push_f32s(&mut buf, &model.pos.data);
    push_f32s(&mut buf, &model.ln_f);
    for l in &model.layers {
        push_f32s(&mut buf, &l.ln1);
        push_f32s(&mut buf, &l.ln2);
    }

    let mut entries = Vec::new();
    for (name, w) in model.named_weights() {
        let group = match w {
            PackedLinear::Int4(p) => p.group,
            _ => 0,
        };
        let [n, k] = w.dims();
        entries.push(format!(
            "{{\"name\":\"{name}\",\"format\":\"{}\",\"n\":{n},\"k\":{k},\"group\":{group}}}",
            w.format().name()
        ));
        push_weight(&mut buf, w);
    }

    let meta = format!(
        "{{\"kind\":\"packed-model\",\"checksum\":\"{:016x}\",\"cfg\":{{\"vocab\":{},\"d_model\":{},\"n_layers\":{},\"n_heads\":{},\"d_ff\":{},\"max_t\":{}}},\"weights\":[{}]}}",
        fnv1a64(&buf),
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_t,
        entries.join(",")
    );

    let meta_path = format!("{dir}/{META_FILE}");
    let bin_path = format!("{dir}/{WEIGHTS_FILE}");
    std::fs::write(&meta_path, meta.as_bytes()).with_context(|| format!("writing {meta_path}"))?;
    std::fs::write(&bin_path, &buf).with_context(|| format!("writing {bin_path}"))?;
    Ok(meta.len() + buf.len())
}

/// Byte length of a weight's packed code payload — must agree with the
/// `from_codes` packers in `quant::packing` or loads reject the file.
fn payload_bytes(fmt: PackFormat, n: usize, k: usize) -> usize {
    match fmt {
        PackFormat::Int4 => n * k / 2,
        PackFormat::TwoBit => n * k / 4,
        PackFormat::Ternary167 => (n * k.div_ceil(3) * 5).div_ceil(8),
        PackFormat::Sherry125 => (n * (k / 4) * 5).div_ceil(8),
        PackFormat::F32 | PackFormat::F16 => 0,
    }
}

fn read_weight(
    r: &mut Reader,
    fmt: PackFormat,
    n: usize,
    k: usize,
    group: usize,
) -> Result<PackedLinear> {
    Ok(match fmt {
        PackFormat::F32 => PackedLinear::F32(Tensor::from_vec(&[n, k], r.read_f32s(n * k)?)),
        PackFormat::F16 => bail!("f16 is accounting-only and never serialized"),
        PackFormat::Int4 => {
            if group == 0 || group % 2 != 0 || k % group != 0 {
                bail!("int4 weight needs an even group dividing k={k}, meta says {group}");
            }
            let scales = r.read_f32s(n * (k / group))?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Int4(PackedInt4 { n, k, group, bytes, scales })
        }
        PackFormat::TwoBit => {
            if k % 4 != 0 {
                bail!("2bit weight needs k divisible by 4, meta says k={k}");
            }
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::TwoBit(Packed2Bit { n, k, bytes, alphas })
        }
        PackFormat::Ternary167 => {
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Ternary167(PackedTernary167 { n, k, bytes, alphas })
        }
        PackFormat::Sherry125 => {
            if k % 4 != 0 {
                bail!("sherry weight needs k divisible by 4, meta says k={k}");
            }
            let alphas = r.read_f32s(n)?;
            let bytes = r.read_bytes(payload_bytes(fmt, n, k))?;
            PackedLinear::Sherry125(PackedSherry { n, k, bytes, alphas })
        }
    })
}

/// Load a packed artifact back into a servable [`Transformer`],
/// bit-exactly reproducing the model [`save_packed`] was given.
pub fn load_packed(dir: &str) -> Result<Transformer> {
    let meta_path = format!("{dir}/{META_FILE}");
    let src = std::fs::read_to_string(&meta_path).with_context(|| {
        format!("reading {meta_path} — run a pipeline with an `export-packed` stage first")
    })?;
    let meta = Json::parse(&src).with_context(|| format!("parsing {meta_path}"))?;
    match meta.get("kind").and_then(Json::as_str) {
        Some("packed-model") => {}
        other => bail!("{meta_path}: kind is {other:?}, expected \"packed-model\""),
    }

    let cfgj = meta.get("cfg").with_context(|| format!("{meta_path}: missing cfg"))?;
    let dim = |key: &str| -> Result<usize> {
        cfgj.get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("{meta_path}: cfg.{key} missing or not a count"))
    };
    let cfg = TransformerCfg {
        vocab: dim("vocab")?,
        d_model: dim("d_model")?,
        n_layers: dim("n_layers")?,
        n_heads: dim("n_heads")?,
        d_ff: dim("d_ff")?,
        max_t: dim("max_t")?,
    };

    let bin_path = format!("{dir}/{WEIGHTS_FILE}");
    let raw = std::fs::read(&bin_path).with_context(|| format!("reading {bin_path}"))?;
    let want = meta
        .get("checksum")
        .and_then(Json::as_str)
        .with_context(|| {
            format!("{meta_path}: missing checksum — re-export the artifact")
        })?;
    let got = format!("{:016x}", fnv1a64(&raw));
    if got != want {
        bail!(
            "{bin_path}: checksum {got} does not match {meta_path}'s {want} — \
             the artifact is corrupt (truncated or bit-flipped?)"
        );
    }
    let mut r = Reader { buf: &raw, pos: 0 };
    let d = cfg.d_model;
    let embed = Tensor::from_vec(&[cfg.vocab, d], r.read_f32s(cfg.vocab * d)?);
    let pos = Tensor::from_vec(&[cfg.max_t, d], r.read_f32s(cfg.max_t * d)?);
    let ln_f = r.read_f32s(d)?;
    let mut norms = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        let ln1 = r.read_f32s(d)?;
        let ln2 = r.read_f32s(d)?;
        norms.push((ln1, ln2));
    }

    let entries = meta
        .get("weights")
        .and_then(Json::as_arr)
        .with_context(|| format!("{meta_path}: missing weights array"))?;
    let expected = cfg.n_layers * 7 + 1;
    if entries.len() != expected {
        bail!(
            "{meta_path}: lists {} weights, a {}-layer model has {expected}",
            entries.len(),
            cfg.n_layers
        );
    }

    // expected shapes in named_weights order, to cross-check the meta
    let mut shapes = Vec::with_capacity(expected);
    for i in 0..cfg.n_layers {
        for w in ["wq", "wk", "wv", "wo"] {
            shapes.push((format!("layer{i}.{w}"), d, d));
        }
        shapes.push((format!("layer{i}.w_gate"), cfg.d_ff, d));
        shapes.push((format!("layer{i}.w_up"), cfg.d_ff, d));
        shapes.push((format!("layer{i}.w_down"), d, cfg.d_ff));
    }
    shapes.push(("head".to_string(), cfg.vocab, d));

    let mut linears = Vec::with_capacity(expected);
    for (entry, (want_name, want_n, want_k)) in entries.iter().zip(&shapes) {
        let name = entry
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("{meta_path}: weight entry missing name"))?;
        if name != want_name.as_str() {
            bail!("{meta_path}: weight `{name}` out of order, expected `{want_name}`");
        }
        let field = |key: &str| -> Result<usize> {
            entry
                .get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("{meta_path}: weight `{name}`: bad {key}"))
        };
        let (n, k, group) = (field("n")?, field("k")?, field("group")?);
        if n != *want_n || k != *want_k {
            bail!("{meta_path}: weight `{name}` is [{n}, {k}], cfg implies [{want_n}, {want_k}]");
        }
        let fmt_s = entry
            .get("format")
            .and_then(Json::as_str)
            .with_context(|| format!("{meta_path}: weight `{name}`: missing format"))?;
        let fmt = PackFormat::parse(fmt_s)
            .with_context(|| format!("{meta_path}: weight `{name}`: unknown format `{fmt_s}`"))?;
        let w = read_weight(&mut r, fmt, n, k, group)
            .with_context(|| format!("{bin_path}: weight `{name}`"))?;
        linears.push(w);
    }
    if r.pos != raw.len() {
        bail!("{bin_path}: {} trailing bytes after last weight", raw.len() - r.pos);
    }

    let mut it = linears.into_iter();
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for (ln1, ln2) in norms {
        layers.push(Layer {
            ln1,
            wq: it.next().unwrap(),
            wk: it.next().unwrap(),
            wv: it.next().unwrap(),
            wo: it.next().unwrap(),
            ln2,
            w_gate: it.next().unwrap(),
            w_up: it.next().unwrap(),
            w_down: it.next().unwrap(),
        });
    }
    let head = it.next().unwrap();
    Ok(Transformer { cfg, embed, pos, layers, ln_f, head })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AttnOverride;
    use crate::util::fixtures::fixture_target;
    use crate::util::Selector;

    fn tmp_dir(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("angelslim_packed_store_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip_is_bit_exact_for_mixed_formats() {
        let mut m = fixture_target(7);
        // mixed precision: 2bit MLP gates, int4 attention, f32 the rest
        let sel = Selector::new(&["w_gate".into(), "w_up".into()], &[]).unwrap();
        assert!(m.pack_weights(&sel, PackFormat::TwoBit, 0).unwrap() > 0);
        let sel = Selector::new(&["wq".into(), "wv".into()], &[]).unwrap();
        assert!(m.pack_weights(&sel, PackFormat::Int4, 16).unwrap() > 0);

        let dir = tmp_dir("roundtrip");
        let bytes = save_packed(&m, &dir).unwrap();
        assert!(bytes > 0);
        let loaded = load_packed(&dir).unwrap();

        assert_eq!(loaded.cfg, m.cfg);
        assert_eq!(loaded.embed.data, m.embed.data);
        for (a, b) in m.named_weights().iter().zip(loaded.named_weights().iter()) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.format(), b.1.format(), "{}", a.0);
            assert_eq!(a.1.stored_bytes(), b.1.stored_bytes(), "{}", a.0);
        }
        let toks = [3u8, 8, 13, 18];
        let la = m.forward(&toks, &AttnOverride::None);
        let lb = loaded.forward(&toks, &AttnOverride::None);
        assert_eq!(la.data, lb.data, "loaded artifact must forward bit-identically");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrip_covers_every_pack_format() {
        for fmt in [
            PackFormat::Int4,
            PackFormat::TwoBit,
            PackFormat::Ternary167,
            PackFormat::Sherry125,
        ] {
            let mut m = fixture_target(3);
            m.pack_weights(&Selector::all(), fmt, 16).unwrap();
            let dir = tmp_dir(fmt.name());
            save_packed(&m, &dir).unwrap();
            let loaded = load_packed(&dir).unwrap();
            let la = m.forward(&[5u8, 10, 15], &AttnOverride::None);
            let lb = loaded.forward(&[5u8, 10, 15], &AttnOverride::None);
            assert_eq!(la.data, lb.data, "{} artifact drifted", fmt.name());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn load_rejects_missing_artifact() {
        let err = load_packed("/nonexistent/packed/dir").unwrap_err();
        assert!(err.to_string().contains("export-packed"), "{err}");
    }

    #[test]
    fn load_rejects_corrupt_payload() {
        let mut m = fixture_target(4);
        m.pack_weights(&Selector::all(), PackFormat::TwoBit, 0).unwrap();
        let dir = tmp_dir("corrupt");
        save_packed(&m, &dir).unwrap();
        let bin = format!("{dir}/{WEIGHTS_FILE}");
        let mut raw = std::fs::read(&bin).unwrap();
        raw.truncate(raw.len() - 9);
        std::fs::write(&bin, &raw).unwrap();
        assert!(load_packed(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Corruption matrix: truncation mid-record, trailing garbage, and a
    /// single bit flip must all be `Err` (never a panic, never silently
    /// wrong weights) and name the artifact as corrupt.
    #[test]
    fn load_rejects_truncation_garbage_and_bit_flips() {
        let mut m = fixture_target(8);
        m.pack_weights(&Selector::all(), PackFormat::Int4, 16).unwrap();
        let dir = tmp_dir("chaos");
        save_packed(&m, &dir).unwrap();
        let bin = format!("{dir}/{WEIGHTS_FILE}");
        let orig = std::fs::read(&bin).unwrap();

        // truncation mid-record: cut inside the weight sections
        std::fs::write(&bin, &orig[..orig.len() / 2]).unwrap();
        let err = format!("{:#}", load_packed(&dir).unwrap_err());
        assert!(err.contains("corrupt"), "truncation: {err}");

        // trailing garbage after the last weight
        let mut fat = orig.clone();
        fat.extend_from_slice(&[0xAB; 7]);
        std::fs::write(&bin, &fat).unwrap();
        let err = format!("{:#}", load_packed(&dir).unwrap_err());
        assert!(err.contains("corrupt"), "trailing garbage: {err}");

        // one flipped bit deep in the payload — structurally still
        // parseable, so only the checksum can catch it
        let mut flipped = orig.clone();
        let mid = flipped.len() / 3;
        flipped[mid] ^= 0x10;
        std::fs::write(&bin, &flipped).unwrap();
        let err = format!("{:#}", load_packed(&dir).unwrap_err());
        assert!(err.contains("corrupt"), "bit flip: {err}");

        // restoring the original bytes loads cleanly again
        std::fs::write(&bin, &orig).unwrap();
        assert!(load_packed(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A shape edit in `packed_meta.json` (mismatched meta) must be a
    /// structured error even though the weights file itself is intact.
    #[test]
    fn load_rejects_meta_shape_edit_and_missing_checksum() {
        let m = fixture_target(2);
        let dir = tmp_dir("meta_edit");
        save_packed(&m, &dir).unwrap();
        let meta_path = format!("{dir}/{META_FILE}");
        let meta = std::fs::read_to_string(&meta_path).unwrap();

        // edit one weight entry's row count
        let needle = format!("\"n\":{}", m.cfg.d_ff);
        let tampered = meta.replacen(&needle, "\"n\":4096", 1);
        assert_ne!(tampered, meta, "fixture has a d_ff-row weight to tamper");
        std::fs::write(&meta_path, &tampered).unwrap();
        let err = format!("{:#}", load_packed(&dir).unwrap_err());
        assert!(err.contains("cfg implies"), "shape edit: {err}");

        // strip the checksum field: pre-checksum artifacts are rejected
        // with guidance instead of skipping verification
        let stripped = meta.replacen("\"checksum\"", "\"checksum_gone\"", 1);
        assert_ne!(stripped, meta);
        std::fs::write(&meta_path, &stripped).unwrap();
        let err = format!("{:#}", load_packed(&dir).unwrap_err());
        assert!(err.contains("missing checksum"), "{err}");

        std::fs::write(&meta_path, &meta).unwrap();
        assert!(load_packed(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
