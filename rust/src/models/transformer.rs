//! Pure-Rust TinyTransformer forward — op-for-op port of
//! python/compile/model.py (learned pos emb, pre-RMSNorm, causal MHA,
//! SwiGLU, untied head). Cross-validated against the PJRT fp32 artifact in
//! tests/test_runtime.rs.
//!
//! Extras the PTQ / sparse-attention frameworks need:
//!   * `apply_quantizer` — QDQ every linear in place (PTQ experiments)
//!   * `AttnOverride::Mask` — inject a token-level attention keep-mask
//!     (the sparse-attention accuracy evals)
//!   * `capture_activations` — per-layer linear inputs (calibration for
//!     GPTQ / AWQ / LeptoQuant)
//!
//! Incremental decoding: `prefill` / `decode_step` extend a [`KvCache`]
//! and compute Q/K/V only for new positions, attending against cached
//! rows — logits are bit-identical to `forward` over the full sequence
//! (asserted by tests/test_kv_cache.rs), but T tokens of generation cost
//! O(T²) total instead of O(T³).

use crate::quant::packing::PackFormat;
use crate::quant::WeightQuantizer;
use crate::tensor::ops::{add_inplace, argmax, dot, rmsnorm, silu, softmax_inplace};
use crate::tensor::Tensor;
use crate::util::Selector;
use anyhow::{bail, Context, Result};

use std::sync::{Arc, Mutex};

use super::kv_cache::KvCache;
use super::kv_paged::{BlockPool, PagedKvCache};
use super::packed::PackedLinear;
use super::weights::WeightStore;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_t: usize,
}

impl TransformerCfg {
    /// Bytes of cached K/V rows one token occupies across all layers —
    /// the unit of the serving scheduler's KV-memory admission budget
    /// (2 buffers × layers × d_model × f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.d_model * std::mem::size_of::<f32>()
    }
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub ln1: Vec<f32>,
    pub wq: PackedLinear,
    pub wk: PackedLinear,
    pub wv: PackedLinear,
    pub wo: PackedLinear,
    pub ln2: Vec<f32>,
    pub w_gate: PackedLinear,
    pub w_up: PackedLinear,
    pub w_down: PackedLinear,
}

#[derive(Clone, Debug)]
pub struct Transformer {
    pub cfg: TransformerCfg,
    pub embed: Tensor, // [vocab, d]
    pub pos: Tensor,   // [max_t, d]
    pub layers: Vec<Layer>,
    pub ln_f: Vec<f32>,
    pub head: PackedLinear, // [vocab, d]
}

/// Attention-behaviour override for sparse-attention experiments.
#[derive(Clone, Debug, Default)]
pub enum AttnOverride {
    #[default]
    None,
    /// token-level keep mask, row-major [t, t]; combined with causality
    Mask(Vec<bool>),
}

/// Captured per-layer activations (inputs to the linears) for calibration.
#[derive(Clone, Debug)]
pub struct LayerActivations {
    /// post-ln1 (input to wq/wk/wv) [t, d]
    pub attn_in: Tensor,
    /// post-ln2 (input to w_gate/w_up) [t, d]
    pub mlp_in: Tensor,
    /// SwiGLU product (input to w_down) [t, d_ff]
    pub mlp_mid: Tensor,
}

impl Transformer {
    pub fn from_store(ws: &WeightStore, model: &str) -> Result<Self> {
        let cfg = ws.model_cfg(model)?;
        let t2 = |name: &str| -> Result<Tensor> {
            let (data, shape) = ws.get(model, name)?;
            Ok(Tensor::from_vec(shape, data.to_vec()))
        };
        let v1 = |name: &str| -> Result<Vec<f32>> {
            let (data, _) = ws.get(model, name)?;
            Ok(data.to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i}.");
            layers.push(Layer {
                ln1: v1(&format!("{p}ln1"))?,
                wq: t2(&format!("{p}wq"))?.into(),
                wk: t2(&format!("{p}wk"))?.into(),
                wv: t2(&format!("{p}wv"))?.into(),
                wo: t2(&format!("{p}wo"))?.into(),
                ln2: v1(&format!("{p}ln2"))?,
                w_gate: t2(&format!("{p}w_gate"))?.into(),
                w_up: t2(&format!("{p}w_up"))?.into(),
                w_down: t2(&format!("{p}w_down"))?.into(),
            });
        }
        Ok(Transformer {
            cfg,
            embed: t2("embed")?,
            pos: t2("pos")?,
            layers,
            ln_f: v1("ln_f")?,
            head: t2("head")?.into(),
        })
    }

    /// QDQ every linear weight (and the head) with the given quantizer —
    /// the PTQ experiment entry point. Panics on packed weights (QDQ
    /// mutates dense f32; pack after, not before).
    pub fn apply_quantizer(&mut self, q: &dyn WeightQuantizer) {
        for layer in self.layers.iter_mut() {
            for w in [
                &mut layer.wq,
                &mut layer.wk,
                &mut layer.wv,
                &mut layer.wo,
                &mut layer.w_gate,
                &mut layer.w_up,
                &mut layer.w_down,
            ] {
                let (n, k) = (w.rows(), w.cols());
                q.qdq(&mut w.f32_mut().data, n, k);
            }
        }
        let (n, k) = (self.head.rows(), self.head.cols());
        q.qdq(&mut self.head.f32_mut().data, n, k);
    }

    /// Replace one layer's weight by an externally-quantized image (GPTQ /
    /// AWQ write-back path). `which` is one of wq|wk|wv|wo|w_gate|w_up|w_down.
    pub fn set_layer_weight(&mut self, layer: usize, which: &str, w: Tensor) {
        let l = &mut self.layers[layer];
        let slot = match which {
            "wq" => &mut l.wq,
            "wk" => &mut l.wk,
            "wv" => &mut l.wv,
            "wo" => &mut l.wo,
            "w_gate" => &mut l.w_gate,
            "w_up" => &mut l.w_up,
            "w_down" => &mut l.w_down,
            other => panic!("unknown weight {other}"),
        };
        assert_eq!(&slot.dims()[..], w.dims());
        *slot = w.into();
    }

    /// Every learned parameter flattened in a fixed traversal order —
    /// the bit-exactness witness pipeline-equivalence tests compare
    /// (`f32::to_bits` over this vector ⇔ identical model bytes).
    /// Panics on packed weights (the witness is defined over dense f32;
    /// compare `dequantized()` models instead).
    pub fn flat_weights(&self) -> Vec<f32> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.embed.data);
        out.extend_from_slice(&self.pos.data);
        for l in &self.layers {
            out.extend_from_slice(&l.ln1);
            for w in [&l.wq, &l.wk, &l.wv, &l.wo] {
                out.extend_from_slice(&w.f32().data);
            }
            out.extend_from_slice(&l.ln2);
            for w in [&l.w_gate, &l.w_up, &l.w_down] {
                out.extend_from_slice(&w.f32().data);
            }
        }
        out.extend_from_slice(&self.ln_f);
        out.extend_from_slice(&self.head.f32().data);
        out
    }

    fn embed_tokens(&self, tokens: &[u8]) -> Tensor {
        let t = tokens.len();
        let d = self.cfg.d_model;
        assert!(t <= self.cfg.max_t, "seq len {t} > max_t {}", self.cfg.max_t);
        let mut x = Tensor::zeros(&[t, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(i);
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        x
    }

    /// Q/K/V projections for one layer over normalized inputs `xn` [t, d]
    /// — the single site both `attn` and `capture_qk` compute them from.
    fn qkv_proj(&self, layer: &Layer, xn: &Tensor) -> (Tensor, Tensor, Tensor) {
        (layer.wq.matmul(xn), layer.wk.matmul(xn), layer.wv.matmul(xn))
    }

    /// Causal multi-head attention mix + output projection. `q` holds
    /// query rows for absolute positions `start..start + q.rows()`;
    /// `kbuf`/`vbuf` hold key/value rows for ALL positions `0..start +
    /// q.rows()`, flat with `d_model` columns (exactly a [`KvCache`]
    /// layer's layout). Mask overrides only apply to full-sequence calls
    /// (`start == 0`); the cached path always passes `AttnOverride::None`.
    fn attn_mix(
        &self,
        layer: &Layer,
        q: &Tensor,
        kbuf: &[f32],
        vbuf: &[f32],
        start: usize,
        ov: &AttnOverride,
    ) -> Tensor {
        let t_new = q.rows();
        let t_total = start + t_new;
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        debug_assert_eq!(kbuf.len(), t_total * d);
        debug_assert_eq!(vbuf.len(), t_total * d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ctx = Tensor::zeros(&[t_new, d]);
        let mut scores = vec![0.0f32; t_total];
        for head in 0..h {
            let off = head * dh;
            for qi in 0..t_new {
                let qrow = &q.row(qi)[off..off + dh];
                let limit = start + qi + 1;
                for ki in 0..limit {
                    let keep = match ov {
                        AttnOverride::None => true,
                        AttnOverride::Mask(m) => m[(start + qi) * t_total + ki],
                    };
                    scores[ki] = if keep {
                        dot(qrow, &kbuf[ki * d + off..ki * d + off + dh]) * scale
                    } else {
                        f32::NEG_INFINITY
                    };
                }
                softmax_inplace(&mut scores[..limit]);
                let crow = ctx.row_mut(qi);
                for ki in 0..limit {
                    let p = scores[ki];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &vbuf[ki * d + off..ki * d + off + dh];
                    for j in 0..dh {
                        crow[off + j] += p * vrow[j];
                    }
                }
            }
        }
        layer.wo.matmul(&ctx)
    }

    fn attn(&self, layer: &Layer, xn: &Tensor, ov: &AttnOverride) -> Tensor {
        let (q, k, v) = self.qkv_proj(layer, xn);
        self.attn_mix(layer, &q, &k.data, &v.data, 0, ov)
    }

    fn mlp(&self, layer: &Layer, xn: &Tensor) -> (Tensor, Tensor) {
        let gate = layer.w_gate.matmul(xn);
        let up = layer.w_up.matmul(xn);
        let mut mid = Tensor::zeros(&[xn.rows(), self.cfg.d_ff]);
        for i in 0..xn.rows() {
            let g = gate.row(i);
            let u = up.row(i);
            let m = mid.row_mut(i);
            for j in 0..self.cfg.d_ff {
                m[j] = silu(g[j]) * u[j];
            }
        }
        let out = layer.w_down.matmul(&mid);
        (out, mid)
    }

    fn norm(&self, x: &Tensor, g: &[f32]) -> Tensor {
        let mut out = Tensor::zeros(&[x.rows(), x.cols()]);
        for i in 0..x.rows() {
            rmsnorm(x.row(i), g, out.row_mut(i));
        }
        out
    }

    /// Residual stream after all blocks (pre-final-norm), [t, d].
    fn hidden(&self, tokens: &[u8], ov: &AttnOverride) -> Tensor {
        let mut x = self.embed_tokens(tokens);
        for layer in &self.layers {
            let xn = self.norm(&x, &layer.ln1);
            let a = self.attn(layer, &xn, ov);
            add_inplace(&mut x.data, &a.data);
            let xn = self.norm(&x, &layer.ln2);
            let (m, _) = self.mlp(layer, &xn);
            add_inplace(&mut x.data, &m.data);
        }
        x
    }

    /// Full forward: tokens -> logits [t, vocab].
    pub fn forward(&self, tokens: &[u8], ov: &AttnOverride) -> Tensor {
        let xf = self.norm(&self.hidden(tokens, ov), &self.ln_f);
        self.head.matmul(&xf)
    }

    /// Logits at the last position only: projects a single hidden row
    /// through the `[vocab, d]` head instead of materializing `[t, vocab]`
    /// logits and discarding all but the last row.
    pub fn next_logits(&self, tokens: &[u8], ov: &AttnOverride) -> Vec<f32> {
        let x = self.hidden(tokens, ov);
        let last = x.row(x.rows() - 1);
        let mut xf = vec![0.0f32; last.len()];
        rmsnorm(last, &self.ln_f, &mut xf);
        let mut scratch = Vec::new();
        self.head.matvec(&xf, &mut scratch)
    }

    /// Greedy next token.
    pub fn greedy_next(&self, tokens: &[u8]) -> u8 {
        argmax(&self.next_logits(tokens, &AttnOverride::None)) as u8
    }

    /// Per-layer calibration activations.
    pub fn capture_activations(&self, tokens: &[u8]) -> Vec<LayerActivations> {
        let mut x = self.embed_tokens(tokens);
        let mut caps = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let xn = self.norm(&x, &layer.ln1);
            let a = self.attn(layer, &xn, &AttnOverride::None);
            add_inplace(&mut x.data, &a.data);
            let x2 = self.norm(&x, &layer.ln2);
            let (m, mid) = self.mlp(layer, &x2);
            add_inplace(&mut x.data, &m.data);
            caps.push(LayerActivations { attn_in: xn, mlp_in: x2, mlp_mid: mid });
        }
        caps
    }

    /// Per-layer (Q, K, V) tensors for sparse-pattern estimation, shape
    /// [t, d] each with heads packed along d. The projections are computed
    /// once and shared with the attention mix (not recomputed inside it).
    pub fn capture_qk(&self, tokens: &[u8]) -> Vec<(Tensor, Tensor, Tensor)> {
        let mut x = self.embed_tokens(tokens);
        let mut out = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let xn = self.norm(&x, &layer.ln1);
            let (q, k, v) = self.qkv_proj(layer, &xn);
            let a = self.attn_mix(layer, &q, &k.data, &v.data, 0, &AttnOverride::None);
            add_inplace(&mut x.data, &a.data);
            let x2 = self.norm(&x, &layer.ln2);
            let (m, _) = self.mlp(layer, &x2);
            add_inplace(&mut x.data, &m.data);
            out.push((q, k, v));
        }
        out
    }

    // ------------------------------------------------------------------
    // incremental decoding (KV-cache sessions)
    // ------------------------------------------------------------------

    /// Fresh empty KV cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.cfg)
    }

    /// Fresh empty KV cache reserving only `cap_t` rows — the serving
    /// scheduler's admission-sized sessions.
    pub fn new_cache_bounded(&self, cap_t: usize) -> KvCache {
        KvCache::new_bounded(&self.cfg, cap_t)
    }

    /// Extend `cache` with `tokens` at positions `cache.len()..`,
    /// computing Q/K/V only for the new rows and attending against the
    /// cached ones. Returns logits rows for the new positions — bit-
    /// identical to the same rows of [`Transformer::forward`] over the
    /// whole sequence.
    pub fn prefill(&self, cache: &mut KvCache, tokens: &[u8]) -> Tensor {
        let start = cache.len();
        let t_new = tokens.len();
        let d = self.cfg.d_model;
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache/model layer mismatch");
        assert_eq!(cache.d_model(), d, "cache/model width mismatch");
        assert!(
            start + t_new <= self.cfg.max_t,
            "session len {} > max_t {}",
            start + t_new,
            self.cfg.max_t
        );
        if t_new == 0 {
            return Tensor::zeros(&[0, self.cfg.vocab]);
        }
        let mut x = Tensor::zeros(&[t_new, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(start + i);
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = self.norm(&x, &layer.ln1);
            let (q, k, v) = self.qkv_proj(layer, &xn);
            cache.append_layer(li, &k.data, &v.data);
            let lk = cache.layer(li);
            let a = self.attn_mix(layer, &q, &lk.k, &lk.v, start, &AttnOverride::None);
            add_inplace(&mut x.data, &a.data);
            let xn = self.norm(&x, &layer.ln2);
            let (m, _) = self.mlp(layer, &xn);
            add_inplace(&mut x.data, &m.data);
        }
        cache.advance(t_new);
        let xf = self.norm(&x, &self.ln_f);
        self.head.matmul(&xf)
    }

    /// [`Transformer::prefill`] through the STeM sparse-attention route:
    /// per layer, a [`stem`](crate::sparse_attn::stem) block mask is
    /// built from that layer's fresh Q/K/V and injected as an
    /// [`AttnOverride::Mask`], so masked query/key pairs skip their dot
    /// products entirely — genuine prefill-compute savings at `budget`
    /// density. The mask spans the whole sequence, so this route is only
    /// valid on a cold cache; a warm cache falls back to dense
    /// [`Transformer::prefill`]. Decode is untouched either way.
    pub fn prefill_sparse(
        &self,
        cache: &mut KvCache,
        tokens: &[u8],
        block: usize,
        budget: f64,
    ) -> Tensor {
        use crate::sparse_attn::{stem, StemCfg};
        let start = cache.len();
        if start != 0 || tokens.len() < 2 {
            return self.prefill(cache, tokens);
        }
        let t_new = tokens.len();
        let d = self.cfg.d_model;
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache/model layer mismatch");
        assert_eq!(cache.d_model(), d, "cache/model width mismatch");
        assert!(
            t_new <= self.cfg.max_t,
            "session len {t_new} > max_t {}",
            self.cfg.max_t
        );
        let stem_cfg = StemCfg::default();
        let mut x = Tensor::zeros(&[t_new, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(i);
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = self.norm(&x, &layer.ln1);
            let (q, k, v) = self.qkv_proj(layer, &xn);
            cache.append_layer(li, &k.data, &v.data);
            let lk = cache.layer(li);
            let mask = stem(&q, &k, &v, block, budget, &stem_cfg).to_token_mask();
            let a = self.attn_mix(layer, &q, &lk.k, &lk.v, 0, &AttnOverride::Mask(mask));
            add_inplace(&mut x.data, &a.data);
            let xn = self.norm(&x, &layer.ln2);
            let (m, _) = self.mlp(layer, &xn);
            add_inplace(&mut x.data, &m.data);
        }
        cache.advance(t_new);
        let xf = self.norm(&x, &self.ln_f);
        self.head.matmul(&xf)
    }

    /// One incremental decode step: process `token` at position
    /// `cache.len()` and return next-token logits. Scalar fast path for
    /// t=1 — matvec kernels throughout, no `[t, vocab]` materialization,
    /// O(cache.len()·d + d²) per layer. Packed weights route through the
    /// LUT GEMV kernels here, so decode reads packed bytes, not f32.
    pub fn decode_step(&self, cache: &mut KvCache, token: u8) -> Vec<f32> {
        let pos = cache.len();
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache/model layer mismatch");
        assert!(pos < self.cfg.max_t, "session len {} > max_t {}", pos + 1, self.cfg.max_t);
        let e = self.embed.row(token as usize);
        let prow = self.pos.row(pos);
        let mut x: Vec<f32> = (0..d).map(|j| e[j] + prow[j]).collect();
        let mut xn = vec![0.0f32; d];
        let mut scratch = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&x, &layer.ln1, &mut xn);
            let q = layer.wq.matvec(&xn, &mut scratch);
            let k = layer.wk.matvec(&xn, &mut scratch);
            let v = layer.wv.matvec(&xn, &mut scratch);
            cache.append_layer(li, &k, &v);
            let lk = cache.layer(li);
            let limit = pos + 1;
            let mut ctx = vec![0.0f32; d];
            let mut scores = vec![0.0f32; limit];
            for head in 0..h {
                let off = head * dh;
                let qrow = &q[off..off + dh];
                for ki in 0..limit {
                    scores[ki] = dot(qrow, &lk.k[ki * d + off..ki * d + off + dh]) * scale;
                }
                softmax_inplace(&mut scores);
                for ki in 0..limit {
                    let p = scores[ki];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &lk.v[ki * d + off..ki * d + off + dh];
                    for j in 0..dh {
                        ctx[off + j] += p * vrow[j];
                    }
                }
            }
            let a = layer.wo.matvec(&ctx, &mut scratch);
            add_inplace(&mut x, &a);
            rmsnorm(&x, &layer.ln2, &mut xn);
            let gate = layer.w_gate.matvec(&xn, &mut scratch);
            let up = layer.w_up.matvec(&xn, &mut scratch);
            let mid: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let m = layer.w_down.matvec(&mid, &mut scratch);
            add_inplace(&mut x, &m);
        }
        cache.advance(1);
        let mut xf = vec![0.0f32; d];
        rmsnorm(&x, &self.ln_f, &mut xf);
        self.head.matvec(&xf, &mut scratch)
    }

    // ------------------------------------------------------------------
    // paged decoding (block-table KV sessions)
    // ------------------------------------------------------------------

    /// Fresh unbounded [`BlockPool`] shaped for this model.
    pub fn new_block_pool(&self, block_tokens: usize) -> Arc<Mutex<BlockPool>> {
        Arc::new(Mutex::new(BlockPool::new(
            self.cfg.n_layers,
            self.cfg.d_model,
            block_tokens,
        )))
    }

    /// Fresh [`BlockPool`] capped at `budget_bytes` of pages.
    pub fn new_block_pool_bounded(
        &self,
        block_tokens: usize,
        budget_bytes: usize,
    ) -> Arc<Mutex<BlockPool>> {
        Arc::new(Mutex::new(BlockPool::new_bounded(
            self.cfg.n_layers,
            self.cfg.d_model,
            block_tokens,
            budget_bytes,
        )))
    }

    /// Fresh empty paged session drawing pages from `pool` (which must
    /// match this model's shape).
    pub fn new_paged_cache(&self, pool: &Arc<Mutex<BlockPool>>) -> PagedKvCache {
        {
            let p = pool.lock().unwrap();
            assert_eq!(p.n_layers(), self.cfg.n_layers, "pool/model layer mismatch");
            assert_eq!(p.d_model(), self.cfg.d_model, "pool/model width mismatch");
        }
        PagedKvCache::new(Arc::clone(pool))
    }

    /// Causal attention over a paged cache's block table — the same
    /// score/softmax/accumulate order as [`Self::attn_mix`], reading each
    /// K/V row through the table instead of a flat buffer, so outputs are
    /// bit-identical to the contiguous path.
    fn attn_mix_paged(
        &self,
        layer: &Layer,
        q: &Tensor,
        cache: &PagedKvCache,
        li: usize,
        start: usize,
    ) -> Tensor {
        let t_new = q.rows();
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        let pool = cache.pool().lock().unwrap();
        let bt = pool.block_tokens();
        let table = cache.table();
        let mut ctx = Tensor::zeros(&[t_new, d]);
        let mut scores = vec![0.0f32; start + t_new];
        for head in 0..h {
            let off = head * dh;
            for qi in 0..t_new {
                let qrow = &q.row(qi)[off..off + dh];
                let limit = start + qi + 1;
                for ki in 0..limit {
                    let krow = pool.k_row(table[ki / bt], li, ki % bt);
                    scores[ki] = dot(qrow, &krow[off..off + dh]) * scale;
                }
                softmax_inplace(&mut scores[..limit]);
                let crow = ctx.row_mut(qi);
                for ki in 0..limit {
                    let p = scores[ki];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &pool.v_row(table[ki / bt], li, ki % bt)[off..off + dh];
                    for j in 0..dh {
                        crow[off + j] += p * vrow[j];
                    }
                }
            }
        }
        layer.wo.matmul(&ctx)
    }

    /// [`Self::prefill`] against a paged session: identical computation,
    /// with page allocation as the only fallible step (`Err` carries
    /// [`super::kv_paged::PoolExhausted`] and leaves the cache unchanged,
    /// so the serving scheduler can preempt and retry). Rows already
    /// materialized by an attached shared prefix are recomputed but not
    /// rewritten.
    pub fn prefill_paged(&self, cache: &mut PagedKvCache, tokens: &[u8]) -> Result<Tensor> {
        let start = cache.len();
        let t_new = tokens.len();
        let d = self.cfg.d_model;
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache/model layer mismatch");
        assert_eq!(cache.d_model(), d, "cache/model width mismatch");
        assert!(
            start + t_new <= self.cfg.max_t,
            "session len {} > max_t {}",
            start + t_new,
            self.cfg.max_t
        );
        if t_new == 0 {
            return Ok(Tensor::zeros(&[0, self.cfg.vocab]));
        }
        cache.prepare_append(t_new)?;
        let mut x = Tensor::zeros(&[t_new, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(start + i);
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = self.norm(&x, &layer.ln1);
            let (q, k, v) = self.qkv_proj(layer, &xn);
            cache.append_layer(li, &k.data, &v.data);
            let a = self.attn_mix_paged(layer, &q, cache, li, start);
            add_inplace(&mut x.data, &a.data);
            let xn = self.norm(&x, &layer.ln2);
            let (m, _) = self.mlp(layer, &xn);
            add_inplace(&mut x.data, &m.data);
        }
        cache.advance(t_new);
        let xf = self.norm(&x, &self.ln_f);
        Ok(self.head.matmul(&xf))
    }

    /// [`Self::decode_step`] against a paged session — same scalar
    /// matvec path, block-table reads, fallible only at page allocation.
    pub fn decode_step_paged(&self, cache: &mut PagedKvCache, token: u8) -> Result<Vec<f32>> {
        let pos = cache.len();
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        assert_eq!(cache.n_layers(), self.cfg.n_layers, "cache/model layer mismatch");
        assert!(pos < self.cfg.max_t, "session len {} > max_t {}", pos + 1, self.cfg.max_t);
        cache.prepare_append(1)?;
        let e = self.embed.row(token as usize);
        let prow = self.pos.row(pos);
        let mut x: Vec<f32> = (0..d).map(|j| e[j] + prow[j]).collect();
        let mut xn = vec![0.0f32; d];
        let mut scratch = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&x, &layer.ln1, &mut xn);
            let q = layer.wq.matvec(&xn, &mut scratch);
            let k = layer.wk.matvec(&xn, &mut scratch);
            let v = layer.wv.matvec(&xn, &mut scratch);
            cache.append_layer(li, &k, &v);
            let limit = pos + 1;
            let mut ctx = vec![0.0f32; d];
            let mut scores = vec![0.0f32; limit];
            {
                let pool = cache.pool().lock().unwrap();
                let bt = pool.block_tokens();
                let table = cache.table();
                for head in 0..h {
                    let off = head * dh;
                    let qrow = &q[off..off + dh];
                    for ki in 0..limit {
                        let krow = pool.k_row(table[ki / bt], li, ki % bt);
                        scores[ki] = dot(qrow, &krow[off..off + dh]) * scale;
                    }
                    softmax_inplace(&mut scores);
                    for ki in 0..limit {
                        let p = scores[ki];
                        if p == 0.0 {
                            continue;
                        }
                        let vrow = &pool.v_row(table[ki / bt], li, ki % bt)[off..off + dh];
                        for j in 0..dh {
                            ctx[off + j] += p * vrow[j];
                        }
                    }
                }
            }
            let a = layer.wo.matvec(&ctx, &mut scratch);
            add_inplace(&mut x, &a);
            rmsnorm(&x, &layer.ln2, &mut xn);
            let gate = layer.w_gate.matvec(&xn, &mut scratch);
            let up = layer.w_up.matvec(&xn, &mut scratch);
            let mid: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let m = layer.w_down.matvec(&mid, &mut scratch);
            add_inplace(&mut x, &m);
        }
        cache.advance(1);
        let mut xf = vec![0.0f32; d];
        rmsnorm(&x, &self.ln_f, &mut xf);
        Ok(self.head.matvec(&xf, &mut scratch))
    }

    /// Total linear-weight parameter count (size accounting).
    pub fn linear_params(&self) -> usize {
        let mut n = self.head.numel();
        for l in &self.layers {
            n += l.wq.numel()
                + l.wk.numel()
                + l.wv.numel()
                + l.wo.numel()
                + l.w_gate.numel()
                + l.w_up.numel()
                + l.w_down.numel();
        }
        n
    }

    // ------------------------------------------------------------------
    // packed execution (quantized serving)
    // ------------------------------------------------------------------

    /// Every linear weight with its canonical name (`layer{i}.wq` …
    /// `layer{i}.w_down`, then `head`) — the namespace pattern selectors
    /// and the packed artifact format address weights by.
    pub fn named_weights(&self) -> Vec<(String, &PackedLinear)> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layer{i}.wq"), &l.wq));
            out.push((format!("layer{i}.wk"), &l.wk));
            out.push((format!("layer{i}.wv"), &l.wv));
            out.push((format!("layer{i}.wo"), &l.wo));
            out.push((format!("layer{i}.w_gate"), &l.w_gate));
            out.push((format!("layer{i}.w_up"), &l.w_up));
            out.push((format!("layer{i}.w_down"), &l.w_down));
        }
        out.push(("head".to_string(), &self.head));
        out
    }

    /// Mutable variant of [`Transformer::named_weights`], same order.
    pub fn named_weights_mut(&mut self) -> Vec<(String, &mut PackedLinear)> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter_mut().enumerate() {
            out.push((format!("layer{i}.wq"), &mut l.wq));
            out.push((format!("layer{i}.wk"), &mut l.wk));
            out.push((format!("layer{i}.wv"), &mut l.wv));
            out.push((format!("layer{i}.wo"), &mut l.wo));
            out.push((format!("layer{i}.w_gate"), &mut l.w_gate));
            out.push((format!("layer{i}.w_up"), &mut l.w_up));
            out.push((format!("layer{i}.w_down"), &mut l.w_down));
        }
        out.push(("head".to_string(), &mut self.head));
        out
    }

    /// Pattern-based per-layer packing: quantize + pack every f32 linear
    /// whose name matches `sel` into `fmt` storage (`group` is the int4
    /// group size). Mixed precision falls out of calling this repeatedly
    /// with disjoint selectors. Returns the number of weights packed;
    /// re-packing an already-packed weight is an error (requantizing
    /// quantized data silently compounds error).
    pub fn pack_weights(&mut self, sel: &Selector, fmt: PackFormat, group: usize) -> Result<usize> {
        let mut count = 0;
        for (name, w) in self.named_weights_mut() {
            if !sel.matches(&name) {
                continue;
            }
            if w.is_packed() {
                bail!("weight {name} is already {}-packed", w.format().name());
            }
            let packed = PackedLinear::from_tensor(w.f32(), fmt, group)
                .with_context(|| format!("packing weight {name}"))?;
            *w = packed;
            count += 1;
        }
        Ok(count)
    }

    /// A dense-f32 twin of this model: every packed linear replaced by its
    /// exact dequantized image — the reference model the bit-identity
    /// contract compares packed serving against.
    pub fn dequantized(&self) -> Transformer {
        let mut m = self.clone();
        for (_, w) in m.named_weights_mut() {
            if w.is_packed() {
                let deq = w.dequantize();
                *w = PackedLinear::F32(deq);
            }
        }
        m
    }

    /// Bytes the linear weights occupy in their current storage formats —
    /// the honest numerator of the packed size ratio.
    pub fn stored_weight_bytes(&self) -> usize {
        self.named_weights().iter().map(|(_, w)| w.stored_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::AffineQuantizer;
    use crate::util::fixtures::fixture_target;

    // Structural invariants run hermetically on the in-memory fixture
    // model (d_model 32, vocab 256); only the trained-artifact check below
    // is `#[ignore]`d behind `make artifacts`.
    fn model() -> Transformer {
        fixture_target(0)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = model();
        let toks = [1u8, 5, 9, 60, 2];
        let logits = m.forward(&toks, &AttnOverride::None);
        assert_eq!(logits.dims(), &[5, 256]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_holds() {
        let m = model();
        let a = m.forward(&[3, 7, 11, 13], &AttnOverride::None);
        let b = m.forward(&[3, 7, 11, 99], &AttnOverride::None);
        // positions 0..3 unaffected by the change at position 3
        for p in 0..3 {
            crate::util::testing::assert_allclose(a.row(p), b.row(p), 1e-5, 1e-5);
        }
        assert_ne!(a.row(3), b.row(3));
    }

    #[test]
    fn dense_mask_override_matches_no_override() {
        let m = model();
        let toks = [2u8, 4, 8, 16, 32, 48];
        let t = toks.len();
        let mask = vec![true; t * t];
        let a = m.forward(&toks, &AttnOverride::None);
        let b = m.forward(&toks, &AttnOverride::Mask(mask));
        crate::util::testing::assert_allclose(&a.data, &b.data, 1e-5, 1e-5);
    }

    #[test]
    #[ignore = "needs trained artifacts/ on disk — run `make artifacts`, then `cargo test -- --ignored`"]
    fn trained_model_predicts_template() {
        // the corpus templates ("Angel", "quant", ...) should be learned:
        // given "Ange", 'l' should rank highly
        let ws = WeightStore::load("artifacts")
            .expect("artifacts missing — run `make artifacts` first");
        let m = Transformer::from_store(&ws, "target").unwrap();
        let prompt = b"Ange";
        let logits = m.next_logits(prompt, &AttnOverride::None);
        let mut ranked: Vec<usize> = (0..256).collect();
        ranked.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let rank_l = ranked.iter().position(|&c| c == b'l' as usize).unwrap();
        assert!(rank_l < 5, "'l' ranked {rank_l}");
    }

    #[test]
    fn quantizer_changes_weights_but_model_runs() {
        let mut m = model();
        let before = m.next_logits(&[1, 6, 11], &AttnOverride::None);
        m.apply_quantizer(&AffineQuantizer::int4_group32());
        let after = m.next_logits(&[1, 6, 11], &AttnOverride::None);
        assert_ne!(before, after);
        // int4 keeps the logits finite
        assert!(after.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn next_logits_matches_forward_last_row_exactly() {
        let m = model();
        let toks = [2u8, 9, 31, 7, 14];
        let full = m.forward(&toks, &AttnOverride::None);
        let fast = m.next_logits(&toks, &AttnOverride::None);
        assert_eq!(full.row(toks.len() - 1), &fast[..]);
    }

    #[test]
    fn prefill_then_decode_matches_forward_exactly() {
        let m = model();
        let toks = [1u8, 5, 9, 60, 2, 17];
        let mut cache = m.new_cache();
        let pre = m.prefill(&mut cache, &toks[..4]);
        let full = m.forward(&toks, &AttnOverride::None);
        assert_eq!(cache.len(), 4);
        for i in 0..4 {
            assert_eq!(pre.row(i), full.row(i), "prefill row {i}");
        }
        for (i, &tok) in toks.iter().enumerate().skip(4) {
            let step = m.decode_step(&mut cache, tok);
            assert_eq!(&step[..], full.row(i), "decode step at {i}");
        }
        assert_eq!(cache.len(), toks.len());
    }

    #[test]
    fn paged_prefill_then_decode_matches_contiguous_bitwise() {
        let m = model();
        let toks = [1u8, 5, 9, 60, 2, 17, 33, 4, 250, 7];
        // block size 3 forces rows to straddle page boundaries
        let pool = m.new_block_pool(3);
        let mut paged = m.new_paged_cache(&pool);
        let mut flat = m.new_cache();
        let pre_p = m.prefill_paged(&mut paged, &toks[..7]).unwrap();
        let pre_f = m.prefill(&mut flat, &toks[..7]);
        assert_eq!(pre_p.data, pre_f.data, "paged prefill drifted");
        for &tok in &toks[7..] {
            let a = m.decode_step_paged(&mut paged, tok).unwrap();
            let b = m.decode_step(&mut flat, tok);
            assert_eq!(a, b, "paged decode step drifted");
        }
        assert_eq!(paged.len(), toks.len());
        assert_eq!(paged.table().len(), toks.len().div_ceil(3));
    }

    #[test]
    fn paged_shared_prefix_is_bitwise_equal_and_saves_pages() {
        let m = model();
        let prompt = [9u8, 8, 7, 6, 5, 4, 3, 2];
        let pool = m.new_block_pool(4);
        // first session materializes the prompt and seals it
        let mut a = m.new_paged_cache(&pool);
        assert_eq!(a.attach_prefix(&prompt), 0);
        let ra = m.prefill_paged(&mut a, &prompt).unwrap();
        a.seal_prefix(&prompt);
        let pages_after_one = pool.lock().unwrap().total_blocks();
        // second session attaches the sealed pages instead of allocating
        let mut b = m.new_paged_cache(&pool);
        assert_eq!(b.attach_prefix(&prompt), prompt.len());
        let rb = m.prefill_paged(&mut b, &prompt).unwrap();
        assert_eq!(ra.data, rb.data, "shared-prefix prefill drifted");
        assert_eq!(
            pool.lock().unwrap().total_blocks(),
            pages_after_one,
            "second session must not materialize new prompt pages"
        );
        // divergent decode after the shared prompt stays bit-identical
        let mut flat = m.new_cache();
        m.prefill(&mut flat, &prompt);
        let pa = m.decode_step_paged(&mut a, 11).unwrap();
        let pb = m.decode_step_paged(&mut b, 77).unwrap();
        assert_eq!(pa, m.decode_step(&mut flat, 11));
        flat.truncate(prompt.len());
        assert_eq!(pb, m.decode_step(&mut flat, 77));
    }

    #[test]
    fn paged_rollback_then_redecode_matches_contiguous() {
        // the spec-decode shape: prefill, speculate, roll back mid-page,
        // decode a different token — pages must fork/unseal, not corrupt
        let m = model();
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6];
        let pool = m.new_block_pool(4);
        let mut paged = m.new_paged_cache(&pool);
        m.prefill_paged(&mut paged, &toks).unwrap();
        paged.seal_prefix(&toks);
        paged.truncate(6);
        let mut flat = m.new_cache();
        m.prefill(&mut flat, &toks[..6]);
        let a = m.decode_step_paged(&mut paged, 200).unwrap();
        let b = m.decode_step(&mut flat, 200);
        assert_eq!(a, b, "post-rollback paged decode drifted");
    }

    #[test]
    fn paged_pool_exhaustion_fails_cleanly_and_recovers() {
        let m = model();
        let bt = 4;
        let pool = m.new_block_pool_bounded(bt, {
            // room for exactly two pages
            let bb = m.cfg.n_layers * 2 * bt * m.cfg.d_model * 4;
            2 * bb
        });
        let mut a = m.new_paged_cache(&pool);
        m.prefill_paged(&mut a, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let mut b = m.new_paged_cache(&pool);
        let err = m.prefill_paged(&mut b, &[9, 9, 9]).unwrap_err();
        assert!(crate::models::is_pool_exhausted(&err), "unexpected error: {err:#}");
        assert!(b.is_empty(), "failed prefill must leave the session empty");
        // freeing the hog lets the same prefill succeed, bit-identically
        a.clear();
        let rows = m.prefill_paged(&mut b, &[9, 9, 9]).unwrap();
        let mut flat = m.new_cache();
        let want = m.prefill(&mut flat, &[9, 9, 9]);
        assert_eq!(rows.data, want.data);
    }

    #[test]
    fn packed_forward_bit_identical_to_dequantized() {
        let toks = [1u8, 5, 9, 60, 2];
        for fmt in [
            PackFormat::Int4,
            PackFormat::TwoBit,
            PackFormat::Ternary167,
            PackFormat::Sherry125,
        ] {
            let mut m = model();
            let packed = m.pack_weights(&Selector::all(), fmt, 16).unwrap();
            assert_eq!(packed, m.cfg.n_layers * 7 + 1);
            let deq = m.dequantized();
            assert!(m.stored_weight_bytes() < deq.stored_weight_bytes());
            let a = m.forward(&toks, &AttnOverride::None);
            let b = deq.forward(&toks, &AttnOverride::None);
            assert_eq!(a.data, b.data, "{} prefill path drifted", fmt.name());
        }
    }

    #[test]
    fn pack_weights_respects_selector_and_rejects_repack() {
        let mut m = model();
        let sel = Selector::new(&["w_gate".into()], &[]).unwrap();
        let packed = m.pack_weights(&sel, PackFormat::TwoBit, 0).unwrap();
        assert_eq!(packed, m.cfg.n_layers);
        assert!(m.layers[0].w_gate.is_packed());
        assert!(!m.layers[0].wq.is_packed());
        assert!(!m.head.is_packed());
        // packing the remainder with a different format = mixed precision
        let rest = Selector::new(&[], &["w_gate".into()]).unwrap();
        m.pack_weights(&rest, PackFormat::Int4, 16).unwrap();
        assert!(m.layers[0].wq.is_packed());
        // second pass over an already-packed weight fails loudly
        assert!(m.pack_weights(&sel, PackFormat::Int4, 16).is_err());
    }

    #[test]
    fn capture_shapes() {
        let m = model();
        let (d, d_ff) = (m.cfg.d_model, m.cfg.d_ff);
        let caps = m.capture_activations(&[1, 2, 3, 4]);
        assert_eq!(caps.len(), m.cfg.n_layers);
        assert_eq!(caps[0].attn_in.dims(), &[4, d]);
        assert_eq!(caps[0].mlp_mid.dims(), &[4, d_ff]);
        let qk = m.capture_qk(&[1, 2, 3, 4]);
        assert_eq!(qk.len(), m.cfg.n_layers);
        assert_eq!(qk[0].0.dims(), &[4, d]);
        assert_eq!(qk[0].2.dims(), &[4, d]);
    }
}
