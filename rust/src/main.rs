//! AngelSlim-RS CLI — the leader entrypoint.
//!
//!   angelslim compress <config.yaml>     run a compression job
//!   angelslim serve [--spec] [-n N]      serve synthetic requests
//!   angelslim eval-quant                 PPL across all model artifacts
//!   angelslim list                       registered models/algos/artifacts

use angelslim::config::SlimConfig;
use angelslim::coordinator::{CompressEngine, SlimFactory};
use angelslim::data::RequestGen;
use angelslim::eval;
use angelslim::runtime::ArtifactRegistry;
use angelslim::server::{BatcherCfg, ServingEngine};
use angelslim::util::table::{f2, Table};
use anyhow::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("compress") => {
            let path = args.get(1).map(String::as_str).unwrap_or("configs/quant_fp8.yaml");
            cmd_compress(path)
        }
        Some("serve") => {
            let spec = args.iter().any(|a| a == "--spec");
            let n = args
                .iter()
                .position(|a| a == "-n")
                .and_then(|i| args.get(i + 1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(16);
            cmd_serve(spec, n)
        }
        Some("eval-quant") => cmd_eval_quant(),
        Some("list") => cmd_list(),
        _ => {
            println!(
                "AngelSlim-RS — unified model compression toolkit (paper reproduction)\n\
                 \n\
                 usage:\n\
                 \x20 angelslim compress <config.yaml>   run a YAML-configured job\n\
                 \x20 angelslim serve [--spec] [-n N]    serve N synthetic requests\n\
                 \x20 angelslim eval-quant               PPL across quantized artifacts\n\
                 \x20 angelslim list                     registered components"
            );
            Ok(())
        }
    }
}

fn cmd_compress(path: &str) -> Result<()> {
    println!("loading config {path}");
    let engine = CompressEngine::from_file(path)?;
    let r = engine.run()?;
    let mut t = Table::new(
        &format!("compress job: {} / {}", r.method, r.algo),
        &["metric", "value"],
    );
    t.row_strs(&["before", &f2(r.metric_before)]);
    t.row_strs(&["after", &f2(r.metric_after)]);
    t.row_strs(&["compression", &f2(r.compression)]);
    if r.peak_calib_bytes > 0 {
        t.row_strs(&["peak calib bytes", &r.peak_calib_bytes.to_string()]);
    }
    t.print();
    for n in &r.notes {
        println!("  note: {n}");
    }
    Ok(())
}

fn cmd_serve(spec: bool, n: usize) -> Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    println!("platform: {}", reg.rt.platform());
    let target = reg.model("model_target_fp32_b1")?;
    let corpus = std::fs::read("artifacts/eval_corpus.bin")?;
    let mut gen = RequestGen::new(corpus, 42);
    let requests = gen.take(n);
    let report = if spec {
        let draft = reg.model("model_draft_fp32_b1")?;
        ServingEngine::serve(requests, &target, Some((&draft, 3)), BatcherCfg::default(), 0)?
    } else {
        ServingEngine::serve::<std::rc::Rc<angelslim::runtime::ModelExecutable>, _>(
            requests,
            &target,
            None,
            BatcherCfg::default(),
            0,
        )?
    };
    let mut t = Table::new(
        if spec { "serve (Eagle3-style speculative)" } else { "serve (vanilla)" },
        &["metric", "value"],
    );
    t.row_strs(&["requests", &report.completed.len().to_string()]);
    t.row_strs(&["tokens", &report.total_tokens.to_string()]);
    t.row_strs(&["TPS", &f2(report.tps())]);
    t.row_strs(&["AL", &f2(report.mean_al)]);
    t.row_strs(&["TTFT p50 (ms)", &f2(report.ttft_summary().p50)]);
    t.row_strs(&["latency p90 (ms)", &f2(report.latency_summary().p90)]);
    t.print();
    Ok(())
}

fn cmd_eval_quant() -> Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    let eval_corpus = std::fs::read("artifacts/eval_corpus.bin")?;
    let mut t = Table::new(
        "quantized model artifacts (PPL on held-out stream)",
        &["artifact", "NLL", "PPL"],
    );
    for name in [
        "model_target_fp32_b1",
        "model_target_fp8_b1",
        "model_target_int4_b1",
        "model_target_seq2qat_b1",
        "model_target_seq2_b1",
        "model_target_ternary_b1",
        "model_small_fp32_b1",
    ] {
        let exe = reg.model(name)?;
        let nll = eval::corpus_nll(&exe, &eval_corpus, 48, 8)?;
        t.row_strs(&[name, &f2(nll), &f2(nll.exp())]);
    }
    t.print();
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("methods and registered algorithms:");
    for (method, algos) in SlimFactory::registered() {
        println!("  {method}: {algos:?}");
    }
    if let Ok(reg) = ArtifactRegistry::open("artifacts") {
        println!("artifacts present: {:?}", reg.available());
    }
    // validate the shipped configs parse
    if let Ok(entries) = std::fs::read_dir("configs") {
        for e in entries.flatten() {
            let p = e.path();
            if p.extension().map(|x| x == "yaml").unwrap_or(false) {
                let ok = SlimConfig::from_file(p.to_str().unwrap()).is_ok();
                println!(
                    "config {:?}: {}",
                    p.file_name().unwrap(),
                    if ok { "ok" } else { "INVALID" }
                );
            }
        }
    }
    Ok(())
}
