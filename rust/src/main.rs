//! AngelSlim-RS CLI — the leader entrypoint.
//!
//!   angelslim compress [--json] <config.yaml>  run a compression pipeline
//!                                        (--json also emits the BENCH_JSON
//!                                        machine-readable PipelineReport)
//!   angelslim serve [--spec] [-n N]      serve synthetic requests (artifacts)
//!   angelslim serve --config <yaml> [-n N]  continuous-batching serve on the
//!                                        configured model (hermetic fixtures OK)
//!   angelslim eval-quant                 PPL across all model artifacts
//!   angelslim list                       registered passes/models/artifacts

use angelslim::config::SlimConfig;
use angelslim::coordinator::{
    CompressEngine, DataFactory, PassRegistry, ServeFactory, SlimFactory,
};
use angelslim::data::RequestGen;
use angelslim::eval;
use angelslim::models::Transformer;
use angelslim::runtime::ArtifactRegistry;
use angelslim::server::{
    ClassPolicy, GreedyExecutor, PagedGreedyExecutor, PagedSpecExecutor, ServingEngine,
    SpecExecutor,
};
use angelslim::util::table::{f2, Table};
use anyhow::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("compress") => {
            if let Some(bad) = args.iter().skip(1).find(|a| a.starts_with("--") && *a != "--json")
            {
                anyhow::bail!("unknown flag `{bad}` for compress (supported: --json)");
            }
            let json = args.iter().any(|a| a == "--json");
            let path = args
                .iter()
                .skip(1)
                .find(|a| !a.starts_with("--"))
                .map(String::as_str)
                .unwrap_or("configs/quant_fp8.yaml");
            cmd_compress(path, json)
        }
        Some("serve") => {
            let spec = args.iter().any(|a| a == "--spec");
            let n = args
                .iter()
                .position(|a| a == "-n")
                .and_then(|i| args.get(i + 1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(16);
            match args.iter().position(|a| a == "--config") {
                Some(i) => {
                    let Some(path) = args.get(i + 1) else {
                        anyhow::bail!("--config requires a path argument");
                    };
                    cmd_serve_config(path, n)
                }
                None => cmd_serve(spec, n),
            }
        }
        Some("eval-quant") => cmd_eval_quant(),
        Some("list") => cmd_list(),
        _ => {
            println!(
                "AngelSlim-RS — unified model compression toolkit (paper reproduction)\n\
                 \n\
                 usage:\n\
                 \x20 angelslim compress [--json] <config.yaml>  run a YAML pipeline job\n\
                 \x20                                          (--json: BENCH_JSON report)\n\
                 \x20 angelslim serve [--spec] [-n N]         serve N synthetic requests\n\
                 \x20 angelslim serve --config <yaml> [-n N]  continuous-batching serve\n\
                 \x20 angelslim eval-quant                    PPL across quantized artifacts\n\
                 \x20 angelslim list                          registered passes + components"
            );
            Ok(())
        }
    }
}

fn cmd_compress(path: &str, json: bool) -> Result<()> {
    println!("loading config {path}");
    let engine = CompressEngine::from_file(path)?;
    let r = engine.run()?;
    let mut t = Table::new(
        &format!("compress pipeline: {} stage(s)", r.stages.len()),
        &["stage", "pass", "kind", "before", "after", "compression", "size", "wall ms"],
    );
    for (i, s) in r.stages.iter().enumerate() {
        t.row_strs(&[
            &i.to_string(),
            &s.pass,
            &s.kind,
            &f2(s.metric_before),
            &f2(s.metric_after),
            &f2(s.compression),
            &f2(s.size_ratio),
            &f2(s.wall_ms),
        ]);
    }
    t.print();
    println!(
        "overall size ratio {:.4} | total wall {:.1} ms",
        r.overall_size_ratio(),
        r.total_wall_ms()
    );
    for s in &r.stages {
        if s.peak_calib_bytes > 0 {
            println!("  [{}] peak calib bytes: {}", s.pass, s.peak_calib_bytes);
        }
        for n in &s.notes {
            println!("  [{}] note: {n}", s.pass);
        }
    }
    if json {
        // same convention as the benches: one machine-readable line CI
        // gates on with `python -m json.tool`
        println!("BENCH_JSON {}", r.to_json(path));
    }
    Ok(())
}

fn cmd_serve(spec: bool, n: usize) -> Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    println!("platform: {}", reg.rt.platform());
    let target = reg.model("model_target_fp32_b1")?;
    let corpus = std::fs::read("artifacts/eval_corpus.bin")?;
    let mut gen = RequestGen::new(corpus, 42);
    let requests = gen.take(n);
    let report = if spec {
        let draft = reg.model("model_draft_fp32_b1")?;
        ServingEngine::serve(requests, &target, Some((&draft, 3)), 0)?
    } else {
        ServingEngine::serve::<std::sync::Arc<angelslim::runtime::ModelExecutable>, _>(
            requests, &target, None, 0,
        )?
    };
    print_serve_report(
        if spec { "serve (Eagle3-style speculative)" } else { "serve (vanilla)" },
        &report,
        None,
    );
    Ok(())
}

/// Config-driven serving: load the configured model (hermetic fixtures
/// included), build a request stream from the configured dataset, and run
/// the continuous-batching scheduler with the config's `serve:` knobs.
fn cmd_serve_config(path: &str, n: usize) -> Result<()> {
    let cfg = SlimConfig::from_file(path)?;
    let serve_cfg = ServeFactory::serve_cfg(&cfg);
    let (target, draft) = ServeFactory::load_models(&cfg)?;
    let datasets = DataFactory::load(&cfg)?;
    let mut gen = RequestGen::new(datasets.eval, cfg.global.seed ^ 0x5E7E);
    gen.prompt_len = 8;
    gen.max_new_tokens = 24;
    // With a class policy configured, serve a mixed-class trace so the
    // SLO-aware path (priority admission, sparse prefill, admission-time
    // pruning) is actually exercised; otherwise the historical untagged
    // stream keeps the CLI output byte-stable.
    let requests = if serve_cfg.classes.is_some() {
        let mut reqs = gen.take_mixed_classes(n.div_ceil(5), 5, 20.0, 24, 8, 4);
        reqs.truncate(n);
        reqs
    } else {
        gen.take(n)
    };
    println!(
        "serving {n} requests | policy={} workers={} max_in_flight={} kv_budget_bytes={}{}{} \
         mode={}",
        serve_cfg.policy.name(),
        serve_cfg.workers,
        serve_cfg.max_in_flight,
        serve_cfg.kv_budget_bytes,
        match serve_cfg.kv_block_tokens {
            Some(bt) => format!(" kv_block_tokens={bt}"),
            None => String::new(),
        },
        if serve_cfg.classes.is_some() {
            " classes=slo-aware"
        } else {
            ""
        },
        if serve_cfg.threads {
            "os-threads"
        } else {
            "virtual-clock"
        }
    );
    let gamma = cfg.compression.num_speculative_tokens.max(1);
    // loud misconfiguration guard: a budget share no request fits would
    // silently collapse the pool onto the oversized-request safety valve.
    // The guard executor must match the serving path: paged admission only
    // needs the prompt's pages, not the projected peak.
    match (&draft, serve_cfg.kv_block_tokens) {
        (Some(d), Some(bt)) => serve_cfg
            .ensure_requests_fit(&PagedSpecExecutor::new(d, &target, gamma, bt, 0), &requests)?,
        (None, Some(bt)) => serve_cfg
            .ensure_requests_fit(&PagedGreedyExecutor::new(&target, bt, 0), &requests)?,
        (Some(d), None) => {
            serve_cfg.ensure_requests_fit(&SpecExecutor::new(d, &target, gamma), &requests)?
        }
        (None, None) => serve_cfg.ensure_requests_fit(&GreedyExecutor::new(&target), &requests)?,
    }
    let report = if serve_cfg.kv_block_tokens.is_some() {
        ServingEngine::serve_paged(
            requests,
            &target,
            draft.as_ref().map(|d| (d, gamma)),
            &serve_cfg,
            cfg.global.seed,
        )?
    } else {
        match &draft {
            Some(d) => ServingEngine::serve_scheduled(
                requests,
                &target,
                Some((d, gamma)),
                &serve_cfg,
                cfg.global.seed,
            )?,
            None => ServingEngine::serve_scheduled::<Transformer, _>(
                requests,
                &target,
                None,
                &serve_cfg,
                cfg.global.seed,
            )?,
        }
    };
    let title = match serve_cfg.kv_block_tokens {
        Some(_) => format!("serve ({} scheduler, paged KV)", serve_cfg.policy.name()),
        None => format!("serve ({} scheduler)", serve_cfg.policy.name()),
    };
    print_serve_report(&title, &report, serve_cfg.classes.as_ref());
    Ok(())
}

fn print_serve_report(
    title: &str,
    report: &angelslim::server::ServeReport,
    classes: Option<&ClassPolicy>,
) {
    let mut t = Table::new(title, &["metric", "value"]);
    t.row_strs(&["requests", &report.completed.len().to_string()]);
    t.row_strs(&["tokens", &report.total_tokens.to_string()]);
    t.row_strs(&["workers", &report.workers().to_string()]);
    t.row_strs(&["TPS", &f2(report.tps())]);
    t.row_strs(&["TPS (virtual clock)", &f2(report.virtual_tps())]);
    t.row_strs(&["AL", &f2(report.mean_al)]);
    if report.proposed > 0 {
        t.row_strs(&["acceptance", &f2(report.acceptance_rate())]);
    }
    t.row_strs(&["TTFT p50 (ms)", &f2(report.ttft_summary().p50)]);
    t.row_strs(&["TTFT p99 (ms)", &f2(report.ttft_summary().p99)]);
    t.row_strs(&["latency p90 (ms)", &f2(report.latency_summary().p90)]);
    t.row_strs(&["peak KV bytes", &report.peak_kv_bytes.to_string()]);
    t.row_strs(&["peak in-flight", &report.peak_in_flight.to_string()]);
    t.row_strs(&["mean in-flight", &f2(report.mean_in_flight)]);
    // fault-tolerance accounting, only when something actually went wrong
    // (fault-free output stays byte-identical to the pre-fault CLI)
    let counts = report.outcome_counts();
    let faulted = counts.failed + counts.deadline_exceeded + counts.shed;
    if faulted > 0 || !report.crashed_workers.is_empty() {
        t.row_strs(&["goodput (completed)", &report.goodput().to_string()]);
        t.row_strs(&["failed", &counts.failed.to_string()]);
        t.row_strs(&["deadline exceeded", &counts.deadline_exceeded.to_string()]);
        t.row_strs(&["shed", &counts.shed.to_string()]);
        t.row_strs(&["retried requests", &report.retried().to_string()]);
        t.row_strs(&["crashed workers", &report.crashed_workers.len().to_string()]);
    }
    t.print();
    for (w, why) in &report.crashed_workers {
        println!("  worker {w} crashed: {why}");
    }
    // per-class SLO rows, only when a `serve.classes:` policy is
    // configured (class-blind runs keep the historical output verbatim)
    if let Some(policy) = classes {
        let mut ct = Table::new(
            "per-class SLO attainment",
            &["class", "done", "failed", "ddl", "shed", "ttft p50", "ttft p99", "ttft SLO", "lat SLO"],
        );
        for s in report.class_breakdown(policy) {
            if s.total() == 0 {
                continue;
            }
            ct.row_strs(&[
                s.name,
                &s.counts.completed.to_string(),
                &s.counts.failed.to_string(),
                &s.counts.deadline_exceeded.to_string(),
                &s.counts.shed.to_string(),
                &f2(s.ttft.p50),
                &f2(s.ttft.p99),
                &format!("{:.0}%", s.ttft_attainment() * 100.0),
                &format!("{:.0}%", s.latency_attainment() * 100.0),
            ]);
        }
        ct.print();
        if report.pruned_prompt_tokens > 0 {
            println!("  multimodal admission pruning dropped {} prompt tokens", report.pruned_prompt_tokens);
        }
        if report.sparse_prefills > 0 {
            println!("  long-context sparse prefills: {}", report.sparse_prefills);
        }
    }
}

fn cmd_eval_quant() -> Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    let eval_corpus = std::fs::read("artifacts/eval_corpus.bin")?;
    let mut t = Table::new(
        "quantized model artifacts (PPL on held-out stream)",
        &["artifact", "NLL", "PPL"],
    );
    for name in [
        "model_target_fp32_b1",
        "model_target_fp8_b1",
        "model_target_int4_b1",
        "model_target_seq2qat_b1",
        "model_target_seq2_b1",
        "model_target_ternary_b1",
        "model_small_fp32_b1",
    ] {
        let exe = reg.model(name)?;
        let nll = eval::corpus_nll(&exe, &eval_corpus, 48, 8)?;
        t.row_strs(&[name, &f2(nll), &f2(nll.exp())]);
    }
    t.print();
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("methods and registered passes (from the PassRegistry):");
    for (method, algos) in SlimFactory::registered() {
        println!("  {method}: {algos:?}");
    }
    println!("pass details:");
    for pass in PassRegistry::all() {
        println!("  {:14} {:12} {}", pass.name(), pass.kind().method(), pass.describe());
    }
    if let Ok(reg) = ArtifactRegistry::open("artifacts") {
        println!("artifacts present: {:?}", reg.available());
    }
    // validate the shipped configs parse
    if let Ok(entries) = std::fs::read_dir("configs") {
        for e in entries.flatten() {
            let p = e.path();
            if p.extension().map(|x| x == "yaml").unwrap_or(false) {
                let ok = SlimConfig::from_file(p.to_str().unwrap()).is_ok();
                println!(
                    "config {:?}: {}",
                    p.file_name().unwrap(),
                    if ok { "ok" } else { "INVALID" }
                );
            }
        }
    }
    Ok(())
}
