//! SpecExit — speculative early exit (paper §3.2, Yang et al. 2025).
//!
//! The draft model's hidden states already encode reasoning progress; the
//! paper augments the MTP layer with lightweight heads that emit
//! (confidence, progress, remaining-length) signals in the same forward
//! pass that proposes tokens — zero probe overhead. Here the signals are
//! derived from the draft's output distribution (max-prob confidence and
//! entropy trend), which is exactly the information those heads are trained
//! to distill; the controller halts generation when the sustained signals
//! say the remaining continuation is redundant.

use crate::models::Sampler;
use crate::tensor::ops::{argmax, log_softmax};
use crate::util::Rng;
use anyhow::Result;

use super::engine::{GenStats, SessionModel};

/// Per-step exit signals (the paper's auxiliary head outputs).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExitSignals {
    /// max softmax probability of the draft's next-token distribution
    pub confidence: f32,
    /// EMA of confidence — the "reasoning progress" proxy
    pub progress: f32,
    /// entropy of the distribution (low = little left to decide)
    pub entropy: f32,
}

#[derive(Clone, Debug)]
pub struct SpecExitController {
    /// exit when progress EMA exceeds this
    pub threshold: f32,
    /// minimum tokens before exit is allowed (don't cut the answer)
    pub min_tokens: usize,
    /// consecutive high-confidence steps required
    pub patience: usize,
    ema: f32,
    streak: usize,
    started: bool,
}

impl SpecExitController {
    pub fn new(threshold: f32, min_tokens: usize, patience: usize) -> Self {
        SpecExitController {
            threshold,
            min_tokens,
            patience,
            ema: 0.0,
            streak: 0,
            started: false,
        }
    }

    pub fn signals_from_logits(&self, logits: &[f32]) -> ExitSignals {
        let lp = log_softmax(logits);
        let conf = lp[argmax(logits)].exp();
        let entropy: f32 = -lp.iter().map(|&l| l.exp() * l).sum::<f32>();
        ExitSignals { confidence: conf, progress: self.ema, entropy }
    }

    /// Feed one step's draft logits; returns true when generation should
    /// exit early.
    pub fn observe(&mut self, logits: &[f32], tokens_so_far: usize) -> bool {
        let s = self.signals_from_logits(logits);
        if self.started {
            self.ema = 0.7 * self.ema + 0.3 * s.confidence;
        } else {
            self.ema = s.confidence; // warm start at the first observation
            self.started = true;
        }
        if s.confidence >= self.threshold {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        tokens_so_far >= self.min_tokens
            && self.streak >= self.patience
            && self.ema >= self.threshold * 0.9
    }

    pub fn reset(&mut self) {
        self.ema = 0.0;
        self.streak = 0;
        self.started = false;
    }
}

/// Speculative decoding with embedded early exit: identical to
/// SpecDecoder::generate (including its KV-session bookkeeping), but the
/// controller watches the draft's signals (no extra forward passes — the
/// paper's key efficiency property).
pub struct SpecExitDecoder<'a, D: SessionModel, T: SessionModel> {
    pub draft: &'a D,
    pub target: &'a T,
    pub gamma: usize,
    pub controller: SpecExitController,
}

impl<'a, D: SessionModel, T: SessionModel> SpecExitDecoder<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize, controller: SpecExitController) -> Self {
        SpecExitDecoder { draft, target, gamma, controller }
    }

    pub fn generate(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        rng: &mut Rng,
    ) -> Result<(Vec<u8>, GenStats, bool)> {
        let t0 = std::time::Instant::now();
        self.controller.reset();
        let sampler = Sampler::Greedy;
        let mut seq = prompt.to_vec();
        let mut stats = GenStats::default();
        let limit = self.target.max_t().min(self.draft.max_t());
        let budget = max_new.min(limit.saturating_sub(prompt.len()));
        let mut exited = false;
        if budget == 0 {
            stats.wall_s = t0.elapsed().as_secs_f64();
            return Ok((seq, stats, exited));
        }

        let mut dsess = self.draft.new_session();
        let mut tsess = self.target.new_session();

        'outer: while stats.generated < budget {
            let room = (limit - seq.len()).min(self.gamma).min(budget - stats.generated);
            if room == 0 {
                break;
            }
            let mut proposal = Vec::with_capacity(room);
            let mut exit_after: Option<usize> = None;
            let mut dlast = dsess
                .extend(self.draft, &seq[dsess.len()..])?
                .pop()
                .expect("draft catch-up covers at least one token");
            for i in 0..room {
                // exit signals ride along with the proposal — same pass
                if exit_after.is_none() && self.controller.observe(&dlast, stats.generated + i) {
                    exit_after = Some(i);
                }
                let tok = sampler.sample(&dlast, rng);
                proposal.push(tok);
                if i + 1 < room {
                    dlast = dsess.extend(self.draft, &[tok])?.pop().unwrap();
                }
            }
            stats.proposed += proposal.len();

            let mut feed: Vec<u8> = seq[tsess.len()..].to_vec();
            feed.extend_from_slice(&proposal);
            let rows = tsess.extend(self.target, &feed)?;
            let tl = &rows[rows.len() - (room + 1)..];
            let mut n_acc = 0;
            for (i, &tok) in proposal.iter().enumerate() {
                if argmax(&tl[i]) as u8 == tok {
                    n_acc += 1;
                } else {
                    break;
                }
            }
            stats.accepted_draft += n_acc;
            for (i, &tok) in proposal.iter().take(n_acc).enumerate() {
                seq.push(tok);
                stats.generated += 1;
                if exit_after == Some(i) {
                    exited = true;
                    stats.steps += 1;
                    break 'outer;
                }
            }
            if stats.generated < budget && seq.len() < limit {
                let bonus = argmax(&tl[n_acc]) as u8;
                seq.push(bonus);
                stats.generated += 1;
            }
            stats.steps += 1;
            if exit_after.map(|e| e < n_acc.max(1)).unwrap_or(false) {
                exited = true;
                break;
            }
            tsess.rollback(seq.len() - 1);
            dsess.rollback(seq.len() - 1);
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((seq, stats, exited))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signals_reflect_peakiness() {
        let c = SpecExitController::new(0.9, 4, 2);
        let mut peaky = vec![0.0f32; 16];
        peaky[3] = 12.0;
        let flat = vec![0.0f32; 16];
        let sp = c.signals_from_logits(&peaky);
        let sf = c.signals_from_logits(&flat);
        assert!(sp.confidence > 0.99);
        assert!(sf.confidence < 0.1);
        assert!(sp.entropy < sf.entropy);
    }

    #[test]
    fn controller_requires_patience_and_min_tokens() {
        let mut c = SpecExitController::new(0.9, 5, 3);
        let mut peaky = vec![0.0f32; 16];
        peaky[0] = 12.0;
        // high confidence but before min_tokens
        assert!(!c.observe(&peaky, 0));
        assert!(!c.observe(&peaky, 1));
        // at min_tokens, needs streak >= 3 (already has 2)
        assert!(c.observe(&peaky, 6));
    }

    #[test]
    fn flat_logits_never_exit() {
        let mut c = SpecExitController::new(0.9, 0, 1);
        let flat = vec![0.0f32; 16];
        for i in 0..50 {
            assert!(!c.observe(&flat, i));
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut c = SpecExitController::new(0.5, 0, 1);
        let mut peaky = vec![0.0f32; 8];
        peaky[0] = 10.0;
        assert!(c.observe(&peaky, 10));
        c.reset();
        assert_eq!(c.streak, 0);
        assert_eq!(c.ema, 0.0);
    }
}
