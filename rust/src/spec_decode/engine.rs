//! Draft-propose / target-verify generation loop.

use crate::models::{AttnOverride, Sampler, Transformer};
use crate::runtime::ModelExecutable;
use crate::tensor::ops::argmax;
use crate::util::Rng;
use anyhow::Result;
use std::rc::Rc;

/// Anything that can produce per-position logits for a token sequence.
/// Implemented by the PJRT executables (serving path) and the pure-Rust
/// transformer (experimentation path).
pub trait LogitsModel {
    /// Logits at every position of `tokens` ([t][vocab]).
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>>;
    fn max_t(&self) -> usize;
}

impl LogitsModel for Rc<ModelExecutable> {
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        self.run_padded(tokens)
    }

    fn max_t(&self) -> usize {
        self.seq_t
    }
}

impl LogitsModel for Transformer {
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        let l = self.forward(tokens, &AttnOverride::None);
        Ok((0..l.rows()).map(|i| l.row(i).to_vec()).collect())
    }

    fn max_t(&self) -> usize {
        self.cfg.max_t
    }
}

/// Generation statistics (the TPS / AL columns of Tables 7-9).
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub generated: usize,
    /// verify steps (target forwards)
    pub steps: usize,
    /// accepted speculative tokens (not counting the bonus token)
    pub accepted_draft: usize,
    /// proposed speculative tokens
    pub proposed: usize,
    pub wall_s: f64,
}

impl GenStats {
    /// Average tokens committed per target step (the paper's AL: accepted
    /// speculative tokens + the verified bonus token per decoding step).
    pub fn al(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.generated as f64 / self.steps as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted_draft as f64 / self.proposed as f64
    }

    pub fn tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.generated as f64 / self.wall_s
    }
}

/// Vanilla autoregressive decoding (the baseline rows of Tables 7-9).
pub struct VanillaDecoder<'a, M: LogitsModel> {
    pub target: &'a M,
    pub sampler: Sampler,
}

impl<'a, M: LogitsModel> VanillaDecoder<'a, M> {
    pub fn new(target: &'a M) -> Self {
        VanillaDecoder { target, sampler: Sampler::Greedy }
    }

    pub fn generate(&self, prompt: &[u8], max_new: usize, rng: &mut Rng) -> Result<(Vec<u8>, GenStats)> {
        let t0 = std::time::Instant::now();
        let mut seq = prompt.to_vec();
        let mut stats = GenStats::default();
        let budget = max_new.min(self.target.max_t().saturating_sub(prompt.len()));
        for _ in 0..budget {
            let logits = self.target.seq_logits(&seq)?;
            let next = self.sampler.sample(logits.last().unwrap(), rng);
            seq.push(next);
            stats.generated += 1;
            stats.steps += 1;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((seq, stats))
    }
}

/// Speculative decoder: draft proposes, target verifies.
pub struct SpecDecoder<'a, D: LogitsModel, T: LogitsModel> {
    pub draft: &'a D,
    pub target: &'a T,
    /// number of speculative tokens per step (num_speculative_tokens)
    pub gamma: usize,
    pub sampler: Sampler,
}

impl<'a, D: LogitsModel, T: LogitsModel> SpecDecoder<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize) -> Self {
        SpecDecoder { draft, target, gamma, sampler: Sampler::Greedy }
    }

    /// Greedy speculative decoding: accept while draft token == target
    /// argmax; then commit the target's bonus token. Output-identical to
    /// vanilla greedy decoding (verified in tests).
    pub fn generate(&self, prompt: &[u8], max_new: usize, rng: &mut Rng) -> Result<(Vec<u8>, GenStats)> {
        let t0 = std::time::Instant::now();
        let mut seq = prompt.to_vec();
        let mut stats = GenStats::default();
        let limit = self.target.max_t().min(self.draft.max_t());
        let budget = max_new.min(limit.saturating_sub(prompt.len()));

        while stats.generated < budget {
            // draft proposes up to gamma tokens autoregressively
            let room = (limit - seq.len()).min(self.gamma).min(budget - stats.generated);
            if room == 0 {
                break;
            }
            let mut proposal = Vec::with_capacity(room);
            {
                let mut dseq = seq.clone();
                for _ in 0..room {
                    let dl = self.draft.seq_logits(&dseq)?;
                    let tok = self.sampler.sample(dl.last().unwrap(), rng);
                    dseq.push(tok);
                    proposal.push(tok);
                }
            }
            stats.proposed += proposal.len();

            // single target forward over seq + proposal
            let mut ext = seq.clone();
            ext.extend_from_slice(&proposal);
            let tl = self.target.seq_logits(&ext)?;

            // verify: target logits at position seq.len()-1+i predict token
            // seq.len()+i
            let base = seq.len() - 1;
            let mut n_acc = 0;
            for (i, &tok) in proposal.iter().enumerate() {
                let target_tok = argmax(&tl[base + i]) as u8;
                if target_tok == tok {
                    n_acc += 1;
                } else {
                    break;
                }
            }
            stats.accepted_draft += n_acc;
            for &tok in proposal.iter().take(n_acc) {
                seq.push(tok);
                stats.generated += 1;
            }
            // bonus token from the target at the first unverified position
            if stats.generated < budget && seq.len() < limit {
                let bonus = argmax(&tl[base + n_acc]) as u8;
                seq.push(bonus);
                stats.generated += 1;
            }
            stats.steps += 1;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((seq, stats))
    }
}

/// Deterministic toy models for tests across the crate.
#[cfg(test)]
pub mod tests_support {
    use super::*;

    /// next token = (last + step) % 7; tokens >= 100 force next = 0 (so
    /// drafts with different steps disagree with the target).
    pub struct ToyModel {
        pub step: u8,
        pub vocab: usize,
    }

    impl ToyModel {
        pub fn new(step: u8) -> Self {
            ToyModel { step, vocab: 256 }
        }
    }

    impl LogitsModel for ToyModel {
        fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
            Ok(tokens
                .iter()
                .map(|&t| {
                    let next = if t >= 100 { 0 } else { (t + self.step) % 7 };
                    let mut l = vec![0.0f32; self.vocab];
                    l[next as usize] = 10.0;
                    l
                })
                .collect())
        }

        fn max_t(&self) -> usize {
            64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::ToyModel;
    use super::*;

    #[test]
    fn spec_equals_vanilla_when_models_agree() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(3);
        let mut rng = Rng::new(0);
        let (vseq, vstats) = VanillaDecoder::new(&target)
            .generate(&[1, 4], 20, &mut rng)
            .unwrap();
        let (sseq, sstats) = SpecDecoder::new(&draft, &target, 4)
            .generate(&[1, 4], 20, &mut rng)
            .unwrap();
        assert_eq!(vseq, sseq, "greedy spec decoding must be output-identical");
        assert_eq!(vstats.generated, sstats.generated);
        // perfect agreement: AL ≈ gamma + 1
        assert!(sstats.al() > 4.0, "AL {}", sstats.al());
        assert!(sstats.steps < vstats.steps / 3);
    }

    #[test]
    fn spec_equals_vanilla_when_models_disagree() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(5); // always wrong
        let mut rng = Rng::new(0);
        let (vseq, _) = VanillaDecoder::new(&target)
            .generate(&[2], 15, &mut rng)
            .unwrap();
        let (sseq, sstats) = SpecDecoder::new(&draft, &target, 3)
            .generate(&[2], 15, &mut rng)
            .unwrap();
        assert_eq!(vseq, sseq, "correctness must not depend on draft quality");
        assert!(sstats.acceptance_rate() < 0.5);
        // worst case AL -> 1 (bonus token only)
        assert!(sstats.al() >= 1.0);
    }

    #[test]
    fn stats_al_counts_bonus() {
        let s = GenStats { generated: 30, steps: 10, ..Default::default() };
        assert_eq!(s.al(), 3.0);
    }

    #[test]
    fn respects_max_t() {
        let target = ToyModel::new(1);
        let draft = ToyModel::new(1);
        let mut rng = Rng::new(0);
        let prompt = vec![1u8; 60];
        let (seq, _) = SpecDecoder::new(&draft, &target, 4)
            .generate(&prompt, 100, &mut rng)
            .unwrap();
        assert!(seq.len() <= 64);
    }
}
