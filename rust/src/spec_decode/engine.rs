//! Draft-propose / target-verify generation loop over KV-cached
//! decoding sessions.
//!
//! [`LogitsModel`] is the stateless "logits for a whole sequence"
//! surface; [`SessionModel`] adds per-request incremental state (a
//! [`DecodeSession`]) so generation costs one decode step per token
//! instead of one full forward. The pure-Rust [`Transformer`] backs its
//! sessions with a real [`KvCache`] (with rollback on speculative
//! rejection); models without native caching fall back to
//! [`ReplaySession`], which reproduces the old re-forward behavior
//! byte-for-byte.

use crate::models::{AttnOverride, KvCache, Sampler, Transformer};
use crate::runtime::ModelExecutable;
use crate::tensor::ops::argmax;
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Anything that can produce per-position logits for a token sequence.
/// Implemented by the PJRT executables (serving path) and the pure-Rust
/// transformer (experimentation path).
pub trait LogitsModel {
    /// Logits at every position of `tokens` ([t][vocab]).
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>>;
    fn max_t(&self) -> usize;
    /// Resident K/V bytes one cached token costs in this model's decode
    /// sessions — the unit of the serving scheduler's KV-memory admission
    /// control. 0 for models without native caching (replay sessions hold
    /// no per-token state).
    fn kv_bytes_per_token(&self) -> usize {
        0
    }
}

impl LogitsModel for Arc<ModelExecutable> {
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        self.run_padded(tokens)
    }

    fn max_t(&self) -> usize {
        self.seq_t
    }
}

impl LogitsModel for Transformer {
    fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        let l = self.forward(tokens, &AttnOverride::None);
        Ok((0..l.rows()).map(|i| l.row(i).to_vec()).collect())
    }

    fn max_t(&self) -> usize {
        self.cfg.max_t
    }

    fn kv_bytes_per_token(&self) -> usize {
        self.cfg.kv_bytes_per_token()
    }
}

/// Incremental decoding state for one request. `extend` feeds new tokens
/// and returns the logits row at every fed position — exactly the rows
/// `seq_logits` over the full sequence would return — and `rollback`
/// rewinds to an accepted prefix (the speculative rejection path).
pub trait DecodeSession<M: ?Sized> {
    /// Feed `tokens` at positions `self.len()..`, returning one logits
    /// row per fed position.
    fn extend(&mut self, model: &M, tokens: &[u8]) -> Result<Vec<Vec<f32>>>;
    /// Tokens fed so far.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Keep only the first `keep` tokens (no-op if already shorter).
    fn rollback(&mut self, keep: usize);
    /// Resident KV bytes this session currently holds (0 for sessions
    /// without native caching).
    fn kv_bytes(&self) -> usize {
        0
    }
    /// Feed `tokens` through the sparse-attention prefill route when the
    /// session supports it (the serving scheduler's LongContext
    /// compression routing). `block` and `budget` are the STeM mask
    /// knobs. Default: plain dense `extend` — sessions without a sparse
    /// path stay correct, just uncompressed.
    fn extend_sparse(
        &mut self,
        model: &M,
        tokens: &[u8],
        _block: usize,
        _budget: f64,
    ) -> Result<Vec<Vec<f32>>> {
        self.extend(model, tokens)
    }
    /// Whether `extend_sparse` actually routes through a sparse kernel
    /// (lets the scheduler count genuine sparse prefills, not fallbacks).
    fn sparse_prefill_capable(&self) -> bool {
        false
    }
}

/// Models that decode incrementally through per-request sessions.
///
/// `Sync` on the model and `Send` on its sessions are what let the
/// serving executors move onto real OS threads (`serve.threads`): every
/// worker borrows the same immutable model while owning its sessions.
pub trait SessionModel: LogitsModel + Sized + Sync {
    type Session: DecodeSession<Self> + Send;
    fn new_session(&self) -> Self::Session;
    /// Session expected to hold at most `cap_t` tokens — an admission-time
    /// sizing hint so serving sessions allocate only their projected peak
    /// (keeping resident memory within the scheduler's KV budget). The
    /// default ignores the hint.
    fn new_session_bounded(&self, _cap_t: usize) -> Self::Session {
        self.new_session()
    }
}

/// Fallback session for models without native KV caching: replays the
/// whole history through `seq_logits` on every extension — the
/// pre-KV-cache O(T³) behavior, byte-identical outputs.
#[derive(Clone, Debug, Default)]
pub struct ReplaySession {
    history: Vec<u8>,
}

impl<M: LogitsModel> DecodeSession<M> for ReplaySession {
    fn extend(&mut self, model: &M, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        self.history.extend_from_slice(tokens);
        let rows = model.seq_logits(&self.history)?;
        Ok(rows[self.history.len() - tokens.len()..].to_vec())
    }

    fn len(&self) -> usize {
        self.history.len()
    }

    fn rollback(&mut self, keep: usize) {
        self.history.truncate(keep);
    }
}

/// KV-cached session for the pure-Rust transformer: multi-token
/// extensions go through `prefill`, single tokens through the
/// `decode_step` matvec fast path.
pub struct KvSession {
    cache: KvCache,
}

impl KvSession {
    /// Resident K/V bytes held for this request.
    pub fn cache_bytes(&self) -> usize {
        self.cache.bytes()
    }
}

impl DecodeSession<Transformer> for KvSession {
    fn extend(&mut self, model: &Transformer, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
        match tokens.len() {
            0 => Ok(Vec::new()),
            1 => Ok(vec![model.decode_step(&mut self.cache, tokens[0])]),
            _ => {
                let rows = model.prefill(&mut self.cache, tokens);
                Ok((0..rows.rows()).map(|i| rows.row(i).to_vec()).collect())
            }
        }
    }

    fn len(&self) -> usize {
        self.cache.len()
    }

    fn rollback(&mut self, keep: usize) {
        self.cache.truncate(keep);
    }

    fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    fn extend_sparse(
        &mut self,
        model: &Transformer,
        tokens: &[u8],
        block: usize,
        budget: f64,
    ) -> Result<Vec<Vec<f32>>> {
        // The STeM mask spans the whole sequence, so only a cold-cache
        // multi-token prefill takes the sparse route; warm extensions
        // (speculative verify, decode) stay dense.
        if self.cache.len() == 0 && tokens.len() > 1 {
            let rows = model.prefill_sparse(&mut self.cache, tokens, block, budget);
            Ok((0..rows.rows()).map(|i| rows.row(i).to_vec()).collect())
        } else {
            self.extend(model, tokens)
        }
    }

    fn sparse_prefill_capable(&self) -> bool {
        true
    }
}

impl SessionModel for Transformer {
    type Session = KvSession;

    fn new_session(&self) -> KvSession {
        KvSession { cache: self.new_cache() }
    }

    fn new_session_bounded(&self, cap_t: usize) -> KvSession {
        KvSession { cache: self.new_cache_bounded(cap_t) }
    }
}

impl SessionModel for Arc<ModelExecutable> {
    type Session = ReplaySession;

    fn new_session(&self) -> ReplaySession {
        ReplaySession::default()
    }
}

/// Generation statistics (the TPS / AL columns of Tables 7-9).
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub generated: usize,
    /// verify steps (target forwards)
    pub steps: usize,
    /// accepted speculative tokens (not counting the bonus token)
    pub accepted_draft: usize,
    /// proposed speculative tokens
    pub proposed: usize,
    pub wall_s: f64,
}

impl GenStats {
    /// Average tokens committed per target step (the paper's AL: accepted
    /// speculative tokens + the verified bonus token per decoding step).
    pub fn al(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.generated as f64 / self.steps as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted_draft as f64 / self.proposed as f64
    }

    pub fn tps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.generated as f64 / self.wall_s
    }
}

/// Vanilla autoregressive decoding (the baseline rows of Tables 7-9):
/// one prefill over the prompt, then one cached decode step per token.
pub struct VanillaDecoder<'a, M: SessionModel> {
    pub target: &'a M,
    pub sampler: Sampler,
}

impl<'a, M: SessionModel> VanillaDecoder<'a, M> {
    pub fn new(target: &'a M) -> Self {
        VanillaDecoder { target, sampler: Sampler::Greedy }
    }

    pub fn generate(&self, prompt: &[u8], max_new: usize, rng: &mut Rng) -> Result<(Vec<u8>, GenStats)> {
        let t0 = std::time::Instant::now();
        let mut seq = prompt.to_vec();
        let mut stats = GenStats::default();
        let budget = max_new.min(self.target.max_t().saturating_sub(prompt.len()));
        if budget > 0 {
            let mut sess = self.target.new_session();
            let mut last = sess
                .extend(self.target, prompt)?
                .pop()
                .expect("prompt must be non-empty");
            for step in 0..budget {
                let next = self.sampler.sample(&last, rng);
                seq.push(next);
                stats.generated += 1;
                stats.steps += 1;
                if step + 1 < budget {
                    last = sess.extend(self.target, &[next])?.pop().unwrap();
                }
            }
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((seq, stats))
    }
}

/// One greedy speculative verify step over persistent sessions — the
/// shared core of [`SpecDecoder::generate`] and the serving scheduler's
/// `SpecExecutor` (one call per decode round), so the two paths cannot
/// drift apart.
///
/// Draft catch-up + `room` proposals (one cached step each), a single
/// target pass over catch-up + proposal, greedy acceptance, the target's
/// bonus token (while `budget_left`/`limit` allow), then both caches
/// rewind to the accepted prefix minus the trailing token the next
/// catch-up re-feeds. Commits onto `seq`; returns
/// `(committed tokens, proposed count, accepted count)`.
#[allow(clippy::too_many_arguments)]
pub fn spec_verify_step<D: SessionModel, T: SessionModel>(
    draft: &D,
    target: &T,
    dsess: &mut D::Session,
    tsess: &mut T::Session,
    seq: &mut Vec<u8>,
    room: usize,
    budget_left: usize,
    limit: usize,
    sampler: &Sampler,
    rng: &mut Rng,
) -> Result<(Vec<u8>, usize, usize)> {
    // draft proposes up to `room` tokens, one cached decode step each
    // (the catch-up covers tokens committed last round)
    let mut proposal = Vec::with_capacity(room);
    let mut dlast = dsess.extend(draft, &seq[dsess.len()..])?.pop().ok_or_else(|| {
        anyhow::anyhow!(
            "speculative verify: draft catch-up returned no logits (draft cache \
             at {} of a {}-token sequence)",
            dsess.len(),
            seq.len()
        )
    })?;
    for i in 0..room {
        let tok = sampler.sample(&dlast, rng);
        proposal.push(tok);
        if i + 1 < room {
            dlast = dsess.extend(draft, &[tok])?.pop().ok_or_else(|| {
                anyhow::anyhow!("speculative verify: draft step {i} returned no logits")
            })?;
        }
    }

    // single target pass over catch-up + proposal; tl[i] is the logits
    // row at position seq.len()-1+i, predicting seq.len()+i
    let mut feed: Vec<u8> = seq[tsess.len()..].to_vec();
    feed.extend_from_slice(&proposal);
    let rows = tsess.extend(target, &feed)?;
    if rows.len() < room + 1 {
        anyhow::bail!(
            "speculative verify: target pass returned {} logit rows for a \
             {}-token feed, need at least {}",
            rows.len(),
            feed.len(),
            room + 1
        );
    }
    let tl = &rows[rows.len() - (room + 1)..];

    let mut n_acc = 0;
    for (i, &tok) in proposal.iter().enumerate() {
        if argmax(&tl[i]) as u8 == tok {
            n_acc += 1;
        } else {
            break;
        }
    }
    let mut committed = Vec::with_capacity(n_acc + 1);
    for &tok in proposal.iter().take(n_acc) {
        seq.push(tok);
        committed.push(tok);
    }
    // bonus token from the target at the first unverified position
    if committed.len() < budget_left && seq.len() < limit {
        let bonus = argmax(&tl[n_acc]) as u8;
        seq.push(bonus);
        committed.push(bonus);
    }

    // rewind both caches to the accepted prefix (minus the trailing token
    // the next catch-up re-feeds)
    tsess.rollback(seq.len() - 1);
    dsess.rollback(seq.len() - 1);
    Ok((committed, proposal.len(), n_acc))
}

/// Speculative decoder: draft proposes, target verifies. Both models
/// keep a KV session across steps; on rejection the caches roll back to
/// the accepted prefix instead of re-forwarding the whole sequence.
pub struct SpecDecoder<'a, D: SessionModel, T: SessionModel> {
    pub draft: &'a D,
    pub target: &'a T,
    /// number of speculative tokens per step (num_speculative_tokens)
    pub gamma: usize,
    pub sampler: Sampler,
}

impl<'a, D: SessionModel, T: SessionModel> SpecDecoder<'a, D, T> {
    pub fn new(draft: &'a D, target: &'a T, gamma: usize) -> Self {
        SpecDecoder { draft, target, gamma, sampler: Sampler::Greedy }
    }

    /// Greedy speculative decoding: accept while draft token == target
    /// argmax; then commit the target's bonus token. Output-identical to
    /// vanilla greedy decoding (verified in tests).
    ///
    /// Session bookkeeping: both sessions trail the committed sequence by
    /// at least one token between steps, so the next extension always
    /// yields the logits row that predicts the first new token. After
    /// each verify the caches rewind to `seq.len() - 1` — keeping the
    /// accepted prefix, discarding rejected speculative rows.
    pub fn generate(&self, prompt: &[u8], max_new: usize, rng: &mut Rng) -> Result<(Vec<u8>, GenStats)> {
        let t0 = std::time::Instant::now();
        let mut seq = prompt.to_vec();
        let mut stats = GenStats::default();
        let limit = self.target.max_t().min(self.draft.max_t());
        // an empty prompt gives the draft no row to propose from
        let budget = if prompt.is_empty() {
            0
        } else {
            max_new.min(limit.saturating_sub(prompt.len()))
        };
        if budget == 0 {
            stats.wall_s = t0.elapsed().as_secs_f64();
            return Ok((seq, stats));
        }

        // Sessions start empty: the first verify pass feeds the whole
        // prompt plus the proposal in one extension (exactly the old
        // full-forward call for replay-backed models), and later passes
        // feed only what the rollback left uncached.
        let mut dsess = self.draft.new_session();
        let mut tsess = self.target.new_session();

        while stats.generated < budget {
            let room = (limit - seq.len()).min(self.gamma).min(budget - stats.generated);
            if room == 0 {
                break;
            }
            let (committed, proposed, accepted) = spec_verify_step(
                self.draft,
                self.target,
                &mut dsess,
                &mut tsess,
                &mut seq,
                room,
                budget - stats.generated,
                limit,
                &self.sampler,
                rng,
            )?;
            stats.proposed += proposed;
            stats.accepted_draft += accepted;
            stats.generated += committed.len();
            stats.steps += 1;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok((seq, stats))
    }
}

/// Deterministic toy models for tests across the crate.
#[cfg(test)]
pub mod tests_support {
    use super::*;

    /// next token = (last + step) % 7; tokens >= 100 force next = 0 (so
    /// drafts with different steps disagree with the target).
    pub struct ToyModel {
        pub step: u8,
        pub vocab: usize,
    }

    impl ToyModel {
        pub fn new(step: u8) -> Self {
            ToyModel { step, vocab: 256 }
        }
    }

    impl LogitsModel for ToyModel {
        fn seq_logits(&self, tokens: &[u8]) -> Result<Vec<Vec<f32>>> {
            Ok(tokens
                .iter()
                .map(|&t| {
                    let next = if t >= 100 { 0 } else { (t + self.step) % 7 };
                    let mut l = vec![0.0f32; self.vocab];
                    l[next as usize] = 10.0;
                    l
                })
                .collect())
        }

        fn max_t(&self) -> usize {
            64
        }
    }

    impl SessionModel for ToyModel {
        type Session = ReplaySession;

        fn new_session(&self) -> ReplaySession {
            ReplaySession::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::ToyModel;
    use super::*;

    #[test]
    fn spec_equals_vanilla_when_models_agree() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(3);
        let mut rng = Rng::new(0);
        let (vseq, vstats) = VanillaDecoder::new(&target)
            .generate(&[1, 4], 20, &mut rng)
            .unwrap();
        let (sseq, sstats) = SpecDecoder::new(&draft, &target, 4)
            .generate(&[1, 4], 20, &mut rng)
            .unwrap();
        assert_eq!(vseq, sseq, "greedy spec decoding must be output-identical");
        assert_eq!(vstats.generated, sstats.generated);
        // perfect agreement: AL ≈ gamma + 1
        assert!(sstats.al() > 4.0, "AL {}", sstats.al());
        assert!(sstats.steps < vstats.steps / 3);
    }

    #[test]
    fn spec_equals_vanilla_when_models_disagree() {
        let target = ToyModel::new(3);
        let draft = ToyModel::new(5); // always wrong
        let mut rng = Rng::new(0);
        let (vseq, _) = VanillaDecoder::new(&target)
            .generate(&[2], 15, &mut rng)
            .unwrap();
        let (sseq, sstats) = SpecDecoder::new(&draft, &target, 3)
            .generate(&[2], 15, &mut rng)
            .unwrap();
        assert_eq!(vseq, sseq, "correctness must not depend on draft quality");
        assert!(sstats.acceptance_rate() < 0.5);
        // worst case AL -> 1 (bonus token only)
        assert!(sstats.al() >= 1.0);
    }

    #[test]
    fn stats_al_counts_bonus() {
        let s = GenStats { generated: 30, steps: 10, ..Default::default() };
        assert_eq!(s.al(), 3.0);
    }

    #[test]
    fn empty_prompt_generates_nothing() {
        let target = ToyModel::new(1);
        let draft = ToyModel::new(1);
        let mut rng = Rng::new(0);
        let (seq, stats) = SpecDecoder::new(&draft, &target, 3)
            .generate(&[], 10, &mut rng)
            .unwrap();
        assert!(seq.is_empty());
        assert_eq!(stats.generated, 0);
    }

    #[test]
    fn respects_max_t() {
        let target = ToyModel::new(1);
        let draft = ToyModel::new(1);
        let mut rng = Rng::new(0);
        let prompt = vec![1u8; 60];
        let (seq, _) = SpecDecoder::new(&draft, &target, 4)
            .generate(&prompt, 100, &mut rng)
            .unwrap();
        assert!(seq.len() <= 64);
    }
}
