//! Speculative decoding — pillar 2 of the paper (§3).
//!
//! The draft model (distilled at build time by python/compile/train.py with
//! Eagle3-style target alignment) proposes γ tokens; the target verifies
//! them in a single forward pass over a persistent KV session, rolling the
//! cache back to the accepted prefix on rejection. Greedy and stochastic
//! acceptance rules, AL / TPS metrics matching Tables 7-9, and the
//! SpecExit early-exit controller (§3.2).

pub mod engine;
pub mod spec_exit;

pub use engine::{
    spec_verify_step, DecodeSession, GenStats, KvSession, LogitsModel, ReplaySession,
    SessionModel, SpecDecoder, VanillaDecoder,
};
pub use spec_exit::{ExitSignals, SpecExitController};
