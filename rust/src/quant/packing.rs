//! Bit-exact storage codecs + packed GEMV kernels — the edge-inference
//! hot path behind Figure 2 (TTFT / generation throughput) and Table 3
//! (ternary packing strategies).
//!
//! The paper's Figure 4 comparison, reproduced here:
//!   * 2-bit      : 1 weight / 2 bits, 4 per byte — aligned but wasteful
//!                  for ternary content (BitNet I2_S analogue).
//!   * 1.67-bit   : 3 ternary digits packed base-3 into 5 bits — dense but
//!                  3-way patterns are SIMD-unfriendly (slow unpack).
//!   * Sherry 1.25: 4 weights (3:4 sparse) into one 5-bit code — dense AND
//!                  4-way aligned.
//!
//! GEMV kernels consume the packed bytes directly (no materialized f32
//! weight matrix), so throughput reflects real memory-bandwidth-bound
//! decode — the regime the paper's edge numbers live in.

use super::sherry::SherryBlock;

// --------------------------------------------------------------------------
// codecs
// --------------------------------------------------------------------------

/// Pack 2-bit codes (values 0..=3), 4 per byte, little-endian fields.
pub fn pack_2bit(codes: &[u8]) -> Vec<u8> {
    assert!(codes.len() % 4 == 0);
    codes
        .chunks_exact(4)
        .map(|c| c[0] | (c[1] << 2) | (c[2] << 4) | (c[3] << 6))
        .collect()
}

pub fn unpack_2bit(packed: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed.len() * 4);
    for &b in packed {
        out.push(b & 3);
        out.push((b >> 2) & 3);
        out.push((b >> 4) & 3);
        out.push((b >> 6) & 3);
    }
    out
}

/// Pack int4 codes (0..=15), 2 per byte (low nibble first).
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    assert!(codes.len() % 2 == 0);
    codes.chunks_exact(2).map(|c| c[0] | (c[1] << 4)).collect()
}

pub fn unpack_nibbles(packed: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed.len() * 2);
    for &b in packed {
        out.push(b & 0xF);
        out.push(b >> 4);
    }
    out
}

/// 1.67-bit ternary: 3 digits (0..=2) base-3 into a 5-bit field (0..=26),
/// fields packed contiguously into a bitstream. codes.len() % 3 == 0.
pub fn pack_ternary_1_67(codes: &[u8]) -> Vec<u8> {
    assert!(codes.len() % 3 == 0);
    let mut bits = BitWriter::new();
    for c in codes.chunks_exact(3) {
        let v = c[0] as u32 + 3 * c[1] as u32 + 9 * c[2] as u32;
        bits.write(v, 5);
    }
    bits.finish()
}

pub fn unpack_ternary_1_67(packed: &[u8], n_codes: usize) -> Vec<u8> {
    assert!(n_codes % 3 == 0);
    let mut r = BitReader::new(packed);
    let mut out = Vec::with_capacity(n_codes);
    for _ in 0..n_codes / 3 {
        let v = r.read(5);
        out.push((v % 3) as u8);
        out.push(((v / 3) % 3) as u8);
        out.push(((v / 9) % 3) as u8);
    }
    out
}

/// Sherry 1.25-bit: one 5-bit block code per 4 weights, bitstream-packed.
pub fn pack_sherry(block_codes: &[u8]) -> Vec<u8> {
    let mut bits = BitWriter::new();
    for &c in block_codes {
        bits.write(c as u32, 5);
    }
    bits.finish()
}

pub fn unpack_sherry(packed: &[u8], n_blocks: usize) -> Vec<u8> {
    let mut r = BitReader::new(packed);
    (0..n_blocks).map(|_| r.read(5) as u8).collect()
}

struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { out: Vec::new(), acc: 0, nbits: 0 }
    }

    fn write(&mut self, v: u32, bits: u32) {
        self.acc |= (v as u64) << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
        }
        self.out
    }
}

struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Reader positioned at an arbitrary bit offset — lets per-row
    /// dequantization seek into the 5-bit-field streams of the 1.67-bit
    /// and Sherry codecs, whose rows are not byte-aligned.
    fn at_bit(data: &'a [u8], bit: usize) -> Self {
        let mut r = BitReader { data, pos: bit / 8, acc: 0, nbits: 0 };
        let rem = (bit % 8) as u32;
        if rem > 0 {
            r.read(rem);
        }
        r
    }

    fn read(&mut self, bits: u32) -> u32 {
        while self.nbits < bits {
            let b = if self.pos < self.data.len() { self.data[self.pos] } else { 0 };
            self.pos += 1;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
        }
        let v = (self.acc & ((1 << bits) - 1)) as u32;
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

// --------------------------------------------------------------------------
// packed weight matrices + GEMV kernels
// --------------------------------------------------------------------------

/// Storage format tag for size accounting (model-size columns of Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackFormat {
    F32,
    F16, // accounted only (we compute in f32)
    Int4,
    TwoBit,
    Ternary167,
    Sherry125,
}

/// Group size the int4 packers default to, matching
/// [`crate::quant::AffineQuantizer::int4_group32`].
pub const INT4_DEFAULT_GROUP: usize = 32;

impl PackFormat {
    /// Parse the config-file spelling of a format.
    pub fn parse(s: &str) -> Option<PackFormat> {
        match s {
            "f32" => Some(PackFormat::F32),
            "f16" => Some(PackFormat::F16),
            "int4" => Some(PackFormat::Int4),
            "2bit" => Some(PackFormat::TwoBit),
            "ternary167" => Some(PackFormat::Ternary167),
            "sherry125" => Some(PackFormat::Sherry125),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackFormat::F32 => "f32",
            PackFormat::F16 => "f16",
            PackFormat::Int4 => "int4",
            PackFormat::TwoBit => "2bit",
            PackFormat::Ternary167 => "ternary167",
            PackFormat::Sherry125 => "sherry125",
        }
    }

    pub fn bits_per_weight(&self) -> f64 {
        match self {
            PackFormat::F32 => 32.0,
            PackFormat::F16 => 16.0,
            PackFormat::Int4 => 4.0,
            PackFormat::TwoBit => 2.0,
            PackFormat::Ternary167 => 5.0 / 3.0,
            PackFormat::Sherry125 => 1.25,
        }
    }

    /// bytes for an [n, k] weight matrix incl. scale overhead: the ternary
    /// family stores one f32 alpha per row, while int4 stores one f32 scale
    /// per `INT4_DEFAULT_GROUP` weights (`n * k/32` scales, not `n`) —
    /// charging int4 a flat `n * 4` would flatter its size_ratio ~9x at
    /// serving widths.
    pub fn matrix_bytes(&self, n: usize, k: usize) -> usize {
        let w = (self.bits_per_weight() * (n * k) as f64 / 8.0).ceil() as usize;
        let scales = match self {
            PackFormat::F32 | PackFormat::F16 => 0,
            PackFormat::Int4 => n * k.div_ceil(INT4_DEFAULT_GROUP) * 4,
            _ => n * 4,
        };
        w + scales
    }
}

/// A ternary matrix packed at 2 bits/weight (BitNet I2_S analogue).
#[derive(Clone, Debug)]
pub struct Packed2Bit {
    pub n: usize,
    pub k: usize,
    pub bytes: Vec<u8>,
    pub alphas: Vec<f32>,
}

impl Packed2Bit {
    pub fn from_codes(codes: &[u8], alphas: &[f32], n: usize, k: usize) -> Self {
        assert_eq!(codes.len(), n * k);
        assert!(k % 4 == 0, "2-bit rows pack 4 codes/byte: k={k} not divisible by 4");
        assert_eq!(alphas.len(), n, "one alpha per output row");
        Packed2Bit { n, k, bytes: pack_2bit(codes), alphas: alphas.to_vec() }
    }

    /// Dequantize one row into `out` — bit-identical to
    /// `TernaryQuantizer::dequantize_codes` on the same codes, so fused
    /// packed kernels and the dequantized-f32 model agree exactly.
    pub fn dequant_row(&self, row: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        let bpr = self.k / 4;
        let a = self.alphas[row];
        let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
        for (bi, &b) in bytes.iter().enumerate() {
            let o = &mut out[bi * 4..bi * 4 + 4];
            o[0] = ((b & 3) as f32 - 1.0) * a;
            o[1] = (((b >> 2) & 3) as f32 - 1.0) * a;
            o[2] = (((b >> 4) & 3) as f32 - 1.0) * a;
            o[3] = (((b >> 6) & 3) as f32 - 1.0) * a;
        }
    }

    /// y = W x with inline 2-bit unpack (4 weights per byte).
    /// Baseline implementation — see `gemv_lut` for the optimized path
    /// (before/after recorded in EXPERIMENTS.md §Perf).
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let bpr = self.k / 4;
        for row in 0..self.n {
            let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
            let mut acc = 0.0f32;
            for (bi, &b) in bytes.iter().enumerate() {
                let xb = &x[bi * 4..bi * 4 + 4];
                acc += ((b & 3) as f32 - 1.0) * xb[0];
                acc += (((b >> 2) & 3) as f32 - 1.0) * xb[1];
                acc += (((b >> 4) & 3) as f32 - 1.0) * xb[2];
                acc += (((b >> 6) & 3) as f32 - 1.0) * xb[3];
            }
            y[row] = acc * self.alphas[row];
        }
    }

    /// T-MAC-style lookup-table GEMV (Wei et al. 2025, the engine the
    /// paper's ternary deployment targets): for each 4-weight segment of x,
    /// precompute the dot contribution of all 256 possible code bytes once
    /// (k/4 × 256 table), then each of the n rows is just k/4 table
    /// lookups + adds instead of 4·k/4 unpack-multiply-adds. The table is
    /// reused across all n rows, so the per-row cost drops ~4x and the
    /// inner loop becomes pure loads — the memory-bandwidth-bound profile
    /// edge decoding actually has.
    pub fn gemv_lut(&self, x: &[f32], y: &mut [f32], lut: &mut Vec<f32>) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let segs = self.k / 4;
        lut.clear();
        lut.resize(segs * 256, 0.0);
        for seg in 0..segs {
            let xb = &x[seg * 4..seg * 4 + 4];
            let base = seg * 256;
            // build incrementally: iterate fields to avoid 256*4 mults
            for b in 0..256usize {
                let v = ((b & 3) as f32 - 1.0) * xb[0]
                    + (((b >> 2) & 3) as f32 - 1.0) * xb[1]
                    + (((b >> 4) & 3) as f32 - 1.0) * xb[2]
                    + (((b >> 6) & 3) as f32 - 1.0) * xb[3];
                lut[base + b] = v;
            }
        }
        let bpr = segs;
        for row in 0..self.n {
            let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            let chunks = bytes.len() / 2;
            for c in 0..chunks {
                let i = c * 2;
                s0 += lut[i * 256 + bytes[i] as usize];
                s1 += lut[(i + 1) * 256 + bytes[i + 1] as usize];
            }
            if bytes.len() % 2 == 1 {
                let i = bytes.len() - 1;
                s0 += lut[i * 256 + bytes[i] as usize];
            }
            y[row] = (s0 + s1) * self.alphas[row];
        }
    }

    /// Half-byte LUT GEMV — the decode-path kernel. Per 4-weight segment,
    /// precompute the 16 possible contributions of each code *pair* (low
    /// and high half-byte separately): 32 floats per segment instead of
    /// `gemv_lut`'s 256, so the tables stay cache-resident at serving
    /// widths and the build cost is negligible. The row loop is then one
    /// byte load + two L1 table loads + two adds per 4 weights.
    pub fn gemv_fast(&self, x: &[f32], y: &mut [f32], lut: &mut Vec<f32>) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let segs = self.k / 4;
        lut.clear();
        lut.resize(segs * 32, 0.0);
        for seg in 0..segs {
            let xb = &x[seg * 4..seg * 4 + 4];
            let t = &mut lut[seg * 32..seg * 32 + 32];
            for c in 0..16usize {
                let w0 = (c & 3) as f32 - 1.0;
                let w1 = ((c >> 2) & 3) as f32 - 1.0;
                t[c] = w0 * xb[0] + w1 * xb[1];
                t[16 + c] = w0 * xb[2] + w1 * xb[3];
            }
        }
        for row in 0..self.n {
            let bytes = &self.bytes[row * segs..(row + 1) * segs];
            // four accumulator chains: a single s += chain is fadd-latency
            // bound (~4 cycles/byte), which would lose to the f32 path
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut pairs = bytes.chunks_exact(2);
            let mut i = 0;
            for pair in &mut pairs {
                let (b0, b1) = (pair[0], pair[1]);
                let base0 = i * 32;
                let base1 = base0 + 32;
                s0 += lut[base0 + (b0 & 15) as usize];
                s1 += lut[base0 + 16 + (b0 >> 4) as usize];
                s2 += lut[base1 + (b1 & 15) as usize];
                s3 += lut[base1 + 16 + (b1 >> 4) as usize];
                i += 2;
            }
            for &b in pairs.remainder() {
                let base = i * 32;
                s0 += lut[base + (b & 15) as usize];
                s1 += lut[base + 16 + (b >> 4) as usize];
                i += 1;
            }
            y[row] = ((s0 + s1) + (s2 + s3)) * self.alphas[row];
        }
    }
}

/// Ternary matrix packed base-3, 3 codes per 5 bits (1.67-bit strategy).
#[derive(Clone, Debug)]
pub struct PackedTernary167 {
    pub n: usize,
    pub k: usize,
    pub bytes: Vec<u8>,
    pub alphas: Vec<f32>,
}

impl PackedTernary167 {
    pub fn from_codes(codes: &[u8], alphas: &[f32], n: usize, k: usize) -> Self {
        assert_eq!(codes.len(), n * k);
        assert_eq!(alphas.len(), n, "one alpha per output row");
        // any k is fine (rows are padded to a multiple of 3 below), but the
        // base-3 packer silently aliases out-of-range digits — reject them
        assert!(
            codes.iter().all(|&c| c <= 2),
            "ternary codes must be 0..=2 (got a value > 2)"
        );
        // pad each row to a multiple of 3 with deadzone codes
        let k_pad = k.div_ceil(3) * 3;
        let mut padded = Vec::with_capacity(n * k_pad);
        for row in 0..n {
            padded.extend_from_slice(&codes[row * k..(row + 1) * k]);
            padded.extend(std::iter::repeat(1u8).take(k_pad - k));
        }
        PackedTernary167 {
            n,
            k,
            bytes: pack_ternary_1_67(&padded),
            alphas: alphas.to_vec(),
        }
    }

    /// y = W x — decodes the irregular 3-way base-3 groups inline. The
    /// div/mod decode is the "SIMD-unfriendly" cost the paper calls out.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let k_pad = self.k.div_ceil(3) * 3;
        let groups_per_row = k_pad / 3;
        let mut r = BitReader::new(&self.bytes);
        for row in 0..self.n {
            let mut acc = 0.0f32;
            for g in 0..groups_per_row {
                let v = r.read(5);
                let base = g * 3;
                let c0 = (v % 3) as f32 - 1.0;
                let c1 = ((v / 3) % 3) as f32 - 1.0;
                let c2 = ((v / 9) % 3) as f32 - 1.0;
                if base < self.k {
                    acc += c0 * x[base];
                }
                if base + 1 < self.k {
                    acc += c1 * x[base + 1];
                }
                if base + 2 < self.k {
                    acc += c2 * x[base + 2];
                }
            }
            y[row] = acc * self.alphas[row];
        }
    }

    /// Dequantize one row — bit-identical to
    /// `TernaryQuantizer::dequantize_codes` on the same codes. Rows are
    /// 5-bit-field streams (`k_pad/3` groups each), so the reader seeks to
    /// the row's bit offset rather than a byte boundary.
    pub fn dequant_row(&self, row: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        let k_pad = self.k.div_ceil(3) * 3;
        let groups_per_row = k_pad / 3;
        let mut r = BitReader::at_bit(&self.bytes, row * groups_per_row * 5);
        let a = self.alphas[row];
        for g in 0..groups_per_row {
            let v = r.read(5);
            let base = g * 3;
            let digits = [v % 3, (v / 3) % 3, (v / 9) % 3];
            for (t, &d) in digits.iter().enumerate() {
                if base + t < self.k {
                    out[base + t] = (d as f32 - 1.0) * a;
                }
            }
        }
    }
}

/// Sherry matrix: 5-bit block codes, 4 weights per code (1.25-bit).
#[derive(Clone, Debug)]
pub struct PackedSherry {
    pub n: usize,
    pub k: usize,
    pub bytes: Vec<u8>,
    pub alphas: Vec<f32>,
}

impl PackedSherry {
    pub fn from_codes(block_codes: &[u8], alphas: &[f32], n: usize, k: usize) -> Self {
        assert!(k % 4 == 0, "sherry packs 4-weight blocks: k={k} not divisible by 4");
        assert_eq!(block_codes.len(), n * k / 4);
        assert_eq!(alphas.len(), n, "one alpha per output row");
        PackedSherry { n, k, bytes: pack_sherry(block_codes), alphas: alphas.to_vec() }
    }

    /// Dequantize one row — bit-identical to `Sherry::dequantize_codes`
    /// on the same block codes (bit-offset seek: rows are 5-bit streams).
    pub fn dequant_row(&self, row: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        let lut = sherry_lut();
        let blocks_per_row = self.k / 4;
        let mut r = BitReader::at_bit(&self.bytes, row * blocks_per_row * 5);
        let a = self.alphas[row];
        for b in 0..blocks_per_row {
            let vals = &lut[r.read(5) as usize];
            let o = &mut out[b * 4..b * 4 + 4];
            for lane in 0..4 {
                o[lane] = vals[lane] * a;
            }
        }
    }

    /// y = W x — one 5-bit read expands to an aligned 4-lane group via a
    /// 32-entry LUT (the SIMD-friendly 4-way pattern of Fig. 4 right).
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        // 32-entry expansion LUT, built once
        let lut = sherry_lut();
        let blocks_per_row = self.k / 4;
        let mut r = BitReader::new(&self.bytes);
        for row in 0..self.n {
            let mut acc = 0.0f32;
            for b in 0..blocks_per_row {
                let code = r.read(5) as usize;
                let vals = &lut[code];
                let xb = &x[b * 4..b * 4 + 4];
                acc += vals[0] * xb[0] + vals[1] * xb[1] + vals[2] * xb[2] + vals[3] * xb[3];
            }
            y[row] = acc * self.alphas[row];
        }
    }
}

fn sherry_lut() -> [[f32; 4]; 32] {
    let mut lut = [[0.0f32; 4]; 32];
    for code in 0..32u8 {
        lut[code as usize] = SherryBlock::from_code(code).expand();
    }
    lut
}

/// Dense f32 GEMV baseline (the BF16 row of Table 3; compute is f32).
pub fn gemv_f32(w: &[f32], n: usize, k: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.len(), n * k);
    for row in 0..n {
        y[row] = crate::tensor::ops::dot(&w[row * k..(row + 1) * k], x);
    }
}

/// int4 group-wise packed GEMV (2 codes per byte) — the Q4_K_M analogue
/// for the Figure 2 edge comparison.
#[derive(Clone, Debug)]
pub struct PackedInt4 {
    pub n: usize,
    pub k: usize,
    pub group: usize,
    pub bytes: Vec<u8>,
    pub scales: Vec<f32>,
}

impl PackedInt4 {
    pub fn from_codes(codes: &[u8], scales: &[f32], n: usize, k: usize, group: usize) -> Self {
        assert_eq!(codes.len(), n * k);
        assert!(group > 0 && group % 2 == 0, "int4 group {group} must be even and non-zero");
        assert!(k % group == 0, "k={k} not divisible by group {group}");
        assert_eq!(scales.len(), n * (k / group), "one scale per group");
        PackedInt4 { n, k, group, bytes: pack_nibbles(codes), scales: scales.to_vec() }
    }

    /// Dequantize one row into `out` — bit-identical to
    /// `AffineQuantizer::dequantize_codes` on the same codes/scales.
    pub fn dequant_row(&self, row: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        let bpr = self.k / 2;
        let groups_per_row = self.k / self.group;
        let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
        for (bi, &b) in bytes.iter().enumerate() {
            let j = bi * 2;
            // group % 2 == 0, so both nibbles of a byte share one scale
            let s = self.scales[row * groups_per_row + j / self.group];
            out[j] = ((b & 0xF) as f32 - 8.0) * s;
            out[j + 1] = ((b >> 4) as f32 - 8.0) * s;
        }
    }

    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let bpr = self.k / 2;
        let groups_per_row = self.k / self.group;
        for row in 0..self.n {
            let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
            let mut acc = 0.0f32;
            for g in 0..groups_per_row {
                let s = self.scales[row * groups_per_row + g];
                let mut gacc = 0.0f32;
                let byte_lo = g * self.group / 2;
                let byte_hi = byte_lo + self.group / 2;
                for (bi, &b) in bytes[byte_lo..byte_hi].iter().enumerate() {
                    let xi = g * self.group + bi * 2;
                    gacc += ((b & 0xF) as f32 - 8.0) * x[xi];
                    gacc += ((b >> 4) as f32 - 8.0) * x[xi + 1];
                }
                acc += gacc * s;
            }
            y[row] = acc;
        }
    }

    /// T-MAC-style LUT GEMV for int4 (2 codes per byte, 256-entry table
    /// per byte position, group scales applied on group subtotals). See
    /// Packed2Bit::gemv_lut and EXPERIMENTS.md §Perf.
    pub fn gemv_lut(&self, x: &[f32], y: &mut [f32], lut: &mut Vec<f32>) {
        assert_eq!(x.len(), self.k);
        let segs = self.k / 2;
        lut.clear();
        lut.resize(segs * 256, 0.0);
        for seg in 0..segs {
            let x0 = x[seg * 2];
            let x1 = x[seg * 2 + 1];
            let base = seg * 256;
            for b in 0..256usize {
                lut[base + b] =
                    ((b & 0xF) as f32 - 8.0) * x0 + ((b >> 4) as f32 - 8.0) * x1;
            }
        }
        let bpr = segs;
        let groups_per_row = self.k / self.group;
        let bytes_per_group = self.group / 2;
        for row in 0..self.n {
            let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
            let mut acc = 0.0f32;
            for g in 0..groups_per_row {
                let s = self.scales[row * groups_per_row + g];
                let mut gacc = 0.0f32;
                let lo = g * bytes_per_group;
                for bi in 0..bytes_per_group {
                    gacc += lut[(lo + bi) * 256 + bytes[lo + bi] as usize];
                }
                acc += gacc * s;
            }
            y[row] = acc;
        }
    }

    /// Half-byte LUT GEMV — the decode-path kernel (see
    /// `Packed2Bit::gemv_fast`). Per byte position, two 16-entry tables
    /// hold `(code - 8) * x` for the even and odd nibble; the row loop is
    /// one byte load + two table loads + two adds per 2 weights, with
    /// group scales applied on group subtotals.
    pub fn gemv_fast(&self, x: &[f32], y: &mut [f32], lut: &mut Vec<f32>) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.n);
        let bpr = self.k / 2;
        lut.clear();
        lut.resize(bpr * 32, 0.0);
        for pos in 0..bpr {
            let (x0, x1) = (x[pos * 2], x[pos * 2 + 1]);
            let t = &mut lut[pos * 32..pos * 32 + 32];
            for c in 0..16usize {
                let w = c as f32 - 8.0;
                t[c] = w * x0;
                t[16 + c] = w * x1;
            }
        }
        let groups_per_row = self.k / self.group;
        let bytes_per_group = self.group / 2;
        for row in 0..self.n {
            let bytes = &self.bytes[row * bpr..(row + 1) * bpr];
            let mut acc = 0.0f32;
            for g in 0..groups_per_row {
                let s = self.scales[row * groups_per_row + g];
                let lo = g * bytes_per_group;
                // four accumulator chains per group (see Packed2Bit): a
                // single += chain would be fadd-latency bound
                let (mut g0, mut g1, mut g2, mut g3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                let chunk = &bytes[lo..lo + bytes_per_group];
                let mut pairs = chunk.chunks_exact(2);
                let mut bi = lo;
                for pair in &mut pairs {
                    let (b0, b1) = (pair[0], pair[1]);
                    let base0 = bi * 32;
                    let base1 = base0 + 32;
                    g0 += lut[base0 + (b0 & 15) as usize];
                    g1 += lut[base0 + 16 + (b0 >> 4) as usize];
                    g2 += lut[base1 + (b1 & 15) as usize];
                    g3 += lut[base1 + 16 + (b1 >> 4) as usize];
                    bi += 2;
                }
                for &b in pairs.remainder() {
                    let base = bi * 32;
                    g0 += lut[base + (b & 15) as usize];
                    g1 += lut[base + 16 + (b >> 4) as usize];
                    bi += 1;
                }
                acc += ((g0 + g1) + (g2 + g3)) * s;
            }
            y[row] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{seq2::Seq2Quantizer, ternary::TernaryQuantizer, Sherry};
    use crate::util::{testing, Rng};

    #[test]
    fn pack2_roundtrip() {
        testing::check(8, |rng| {
            let codes: Vec<u8> = (0..64).map(|_| rng.below(4) as u8).collect();
            assert_eq!(unpack_2bit(&pack_2bit(&codes)), codes);
        });
    }

    #[test]
    fn nibble_roundtrip() {
        testing::check(8, |rng| {
            let codes: Vec<u8> = (0..64).map(|_| rng.below(16) as u8).collect();
            assert_eq!(unpack_nibbles(&pack_nibbles(&codes)), codes);
        });
    }

    #[test]
    fn ternary167_roundtrip() {
        testing::check(8, |rng| {
            let codes: Vec<u8> = (0..96).map(|_| rng.below(3) as u8).collect();
            let packed = pack_ternary_1_67(&codes);
            assert_eq!(unpack_ternary_1_67(&packed, 96), codes);
            // 96 codes -> 32 groups * 5 bits = 160 bits = 20 bytes
            assert_eq!(packed.len(), 20);
        });
    }

    #[test]
    fn sherry_pack_roundtrip() {
        testing::check(8, |rng| {
            let codes: Vec<u8> = (0..40).map(|_| rng.below(32) as u8).collect();
            let packed = pack_sherry(&codes);
            assert_eq!(unpack_sherry(&packed, 40), codes);
            assert_eq!(packed.len(), 25); // 40 * 5 bits = 200 bits
        });
    }

    #[test]
    fn format_sizes_ordered() {
        let sizes: Vec<usize> = [
            PackFormat::F16,
            PackFormat::Int4,
            PackFormat::TwoBit,
            PackFormat::Ternary167,
            PackFormat::Sherry125,
        ]
        .iter()
        .map(|f| f.matrix_bytes(1024, 1024))
        .collect();
        for w in sizes.windows(2) {
            assert!(w[1] < w[0], "{sizes:?}");
        }
    }

    #[test]
    fn gemv_2bit_matches_dense() {
        testing::check(6, |rng| {
            let (n, k) = (16, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let deq = TernaryQuantizer::dequantize_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut dense = vec![0.0; n];
            gemv_f32(&deq, n, k, &x, &mut dense);
            let packed = Packed2Bit::from_codes(&codes, &alphas, n, k);
            let mut y = vec![0.0; n];
            packed.gemv(&x, &mut y);
            testing::assert_allclose(&y, &dense, 1e-4, 1e-4);
        });
    }

    #[test]
    fn gemv_2bit_lut_matches_baseline() {
        testing::check(6, |rng| {
            let (n, k) = (16, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let packed = Packed2Bit::from_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut base = vec![0.0; n];
            packed.gemv(&x, &mut base);
            let mut lut_buf = Vec::new();
            let mut fast = vec![0.0; n];
            packed.gemv_lut(&x, &mut fast, &mut lut_buf);
            testing::assert_allclose(&fast, &base, 1e-4, 1e-4);
        });
    }

    #[test]
    fn gemv_ternary167_matches_dense() {
        testing::check(6, |rng| {
            let (n, k) = (8, 48);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let deq = TernaryQuantizer::dequantize_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut dense = vec![0.0; n];
            gemv_f32(&deq, n, k, &x, &mut dense);
            let packed = PackedTernary167::from_codes(&codes, &alphas, n, k);
            let mut y = vec![0.0; n];
            packed.gemv(&x, &mut y);
            testing::assert_allclose(&y, &dense, 1e-4, 1e-4);
        });
    }

    #[test]
    fn gemv_sherry_matches_dense_dequant() {
        testing::check(6, |rng| {
            let (n, k) = (8, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = Sherry::quantize_codes(&w, n, k);
            let deq = Sherry::dequantize_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut dense = vec![0.0; n];
            gemv_f32(&deq, n, k, &x, &mut dense);
            let packed = PackedSherry::from_codes(&codes, &alphas, n, k);
            let mut y = vec![0.0; n];
            packed.gemv(&x, &mut y);
            testing::assert_allclose(&y, &dense, 1e-4, 1e-4);
        });
    }

    #[test]
    fn gemv_int4_matches_dense_dequant() {
        testing::check(6, |rng| {
            let (n, k, g) = (8, 64, 32);
            let w = rng.normal_vec(n * k, 1.0);
            let q = crate::quant::AffineQuantizer::int4_group32();
            let (codes, scales) = q.quantize_codes(&w, n, k);
            let deq = q.dequantize_codes(&codes, &scales, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut dense = vec![0.0; n];
            gemv_f32(&deq, n, k, &x, &mut dense);
            let packed = PackedInt4::from_codes(&codes, &scales, n, k, g);
            let mut y = vec![0.0; n];
            packed.gemv(&x, &mut y);
            testing::assert_allclose(&y, &dense, 1e-3, 1e-3);
        });
    }

    #[test]
    fn gemv_int4_lut_matches_baseline() {
        testing::check(6, |rng| {
            let (n, k, g) = (8, 64, 32);
            let w = rng.normal_vec(n * k, 1.0);
            let q = crate::quant::AffineQuantizer::int4_group32();
            let (codes, scales) = q.quantize_codes(&w, n, k);
            let packed = PackedInt4::from_codes(&codes, &scales, n, k, g);
            let x = rng.normal_vec(k, 1.0);
            let mut base = vec![0.0; n];
            packed.gemv(&x, &mut base);
            let mut lut = Vec::new();
            let mut fast = vec![0.0; n];
            packed.gemv_lut(&x, &mut fast, &mut lut);
            testing::assert_allclose(&fast, &base, 1e-4, 1e-4);
        });
    }

    #[test]
    fn seq2_codes_pack_2bit() {
        // SEQ codes are 0..=3 so the 2-bit codec stores them losslessly
        let mut rng = Rng::new(0);
        let w = rng.normal_vec(4 * 32, 1.0);
        let (codes, _) = Seq2Quantizer::new(32).quantize_codes(&w, 4, 32);
        assert_eq!(unpack_2bit(&pack_2bit(&codes)), codes);
    }

    #[test]
    fn int4_matrix_bytes_counts_group_scales() {
        // 8x64 int4: 8*64/2 = 256 payload bytes + 8 rows * 2 groups * 4B
        assert_eq!(PackFormat::Int4.matrix_bytes(8, 64), 256 + 8 * 2 * 4);
        // the old flat per-row accounting would have claimed 256 + 32
        assert!(PackFormat::Int4.matrix_bytes(8, 64) > 256 + 8 * 4);
    }

    #[test]
    fn ternary167_handles_k_not_divisible_by_3() {
        // regression: the constructor used to carry a tautological guard
        // instead of exercising the row-padding path
        testing::check(6, |rng| {
            let (n, k) = (8, 10); // k % 3 == 1 -> rows pad to 12 codes
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let deq = TernaryQuantizer::dequantize_codes(&codes, &alphas, n, k);
            let packed = PackedTernary167::from_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut dense = vec![0.0; n];
            gemv_f32(&deq, n, k, &x, &mut dense);
            let mut y = vec![0.0; n];
            packed.gemv(&x, &mut y);
            testing::assert_allclose(&y, &dense, 1e-4, 1e-4);
        });
    }

    #[test]
    #[should_panic(expected = "ternary codes must be 0..=2")]
    fn ternary167_rejects_out_of_range_codes() {
        PackedTernary167::from_codes(&[0, 1, 3], &[1.0], 1, 3);
    }

    #[test]
    #[should_panic(expected = "not divisible by 4")]
    fn packed_2bit_rejects_unaligned_k() {
        Packed2Bit::from_codes(&[1u8; 2 * 6], &[1.0; 2], 2, 6);
    }

    #[test]
    #[should_panic(expected = "not divisible by 4")]
    fn packed_sherry_rejects_unaligned_k() {
        PackedSherry::from_codes(&[0u8; 3], &[1.0; 2], 2, 6);
    }

    #[test]
    #[should_panic(expected = "not divisible by group")]
    fn packed_int4_rejects_unaligned_group() {
        PackedInt4::from_codes(&[8u8; 2 * 48], &[1.0; 2], 2, 48, 32);
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn packed_int4_rejects_odd_group() {
        PackedInt4::from_codes(&[8u8; 2 * 9], &[1.0; 6], 2, 9, 3);
    }

    #[test]
    #[should_panic(expected = "assertion")]
    fn packed_int4_gemv_rejects_short_y() {
        let q = crate::quant::AffineQuantizer::int4_group32();
        let mut rng = Rng::new(3);
        let w = rng.normal_vec(4 * 32, 1.0);
        let (codes, scales) = q.quantize_codes(&w, 4, 32);
        let packed = PackedInt4::from_codes(&codes, &scales, 4, 32, 32);
        let x = vec![0.0; 32];
        let mut y = vec![0.0; 3]; // one row short
        packed.gemv(&x, &mut y);
    }

    #[test]
    fn dequant_rows_match_quantizer_dequant_bitwise() {
        // the row providers behind the fused prefill kernel must agree
        // *bitwise* with each quantizer's dequantize_codes — this is the
        // packed-serving == dequantized-f32-serving correctness anchor
        testing::check(4, |rng| {
            let (n, k) = (6, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let mut out = vec![0.0f32; k];

            let (tc, ta) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let tdeq = TernaryQuantizer::dequantize_codes(&tc, &ta, n, k);
            let p2 = Packed2Bit::from_codes(&tc, &ta, n, k);
            let p167 = PackedTernary167::from_codes(&tc, &ta, n, k);
            for row in 0..n {
                p2.dequant_row(row, &mut out);
                assert_eq!(out, tdeq[row * k..(row + 1) * k], "2bit row {row}");
                p167.dequant_row(row, &mut out);
                assert_eq!(out, tdeq[row * k..(row + 1) * k], "ternary167 row {row}");
            }

            let q = crate::quant::AffineQuantizer::int4_group32();
            let (ic, is) = q.quantize_codes(&w, n, k);
            let ideq = q.dequantize_codes(&ic, &is, n, k);
            let p4 = PackedInt4::from_codes(&ic, &is, n, k, 32);
            for row in 0..n {
                p4.dequant_row(row, &mut out);
                assert_eq!(out, ideq[row * k..(row + 1) * k], "int4 row {row}");
            }

            let (sc, sa) = Sherry::quantize_codes(&w, n, k);
            let sdeq = Sherry::dequantize_codes(&sc, &sa, n, k);
            let ps = PackedSherry::from_codes(&sc, &sa, n, k);
            for row in 0..n {
                ps.dequant_row(row, &mut out);
                assert_eq!(out, sdeq[row * k..(row + 1) * k], "sherry row {row}");
            }
        });
    }

    #[test]
    fn ternary167_dequant_row_seeks_unaligned_rows() {
        // k=10 -> 4 groups * 5 bits = 20 bits per row: every other row
        // starts mid-byte, exercising the bit-offset reader seek
        let mut rng = Rng::new(11);
        let (n, k) = (5, 10);
        let w = rng.normal_vec(n * k, 1.0);
        let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
        let deq = TernaryQuantizer::dequantize_codes(&codes, &alphas, n, k);
        let packed = PackedTernary167::from_codes(&codes, &alphas, n, k);
        let mut out = vec![0.0f32; k];
        for row in 0..n {
            packed.dequant_row(row, &mut out);
            assert_eq!(out, deq[row * k..(row + 1) * k], "row {row}");
        }
    }

    #[test]
    fn gemv_fast_2bit_matches_baseline() {
        testing::check(6, |rng| {
            let (n, k) = (16, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = TernaryQuantizer::default().quantize_codes(&w, n, k);
            let packed = Packed2Bit::from_codes(&codes, &alphas, n, k);
            let x = rng.normal_vec(k, 1.0);
            let mut base = vec![0.0; n];
            packed.gemv(&x, &mut base);
            let mut lut = Vec::new();
            let mut fast = vec![0.0; n];
            packed.gemv_fast(&x, &mut fast, &mut lut);
            testing::assert_allclose(&fast, &base, 1e-4, 1e-4);
        });
    }

    #[test]
    fn gemv_fast_int4_matches_baseline() {
        testing::check(6, |rng| {
            let (n, k, g) = (8, 64, 32);
            let w = rng.normal_vec(n * k, 1.0);
            let q = crate::quant::AffineQuantizer::int4_group32();
            let (codes, scales) = q.quantize_codes(&w, n, k);
            let packed = PackedInt4::from_codes(&codes, &scales, n, k, g);
            let x = rng.normal_vec(k, 1.0);
            let mut base = vec![0.0; n];
            packed.gemv(&x, &mut base);
            let mut lut = Vec::new();
            let mut fast = vec![0.0; n];
            packed.gemv_fast(&x, &mut fast, &mut lut);
            testing::assert_allclose(&fast, &base, 1e-4, 1e-4);
        });
    }
}
