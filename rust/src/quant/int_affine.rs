//! k-bit integer affine quantization with per-tensor / per-channel /
//! group-wise granularity — the INT8/INT4 backbone of the PTQ framework
//! (§2.3.1). Symmetric around the mid-code, matching the python-side
//! reference (kernels/ref.py quantize_int4) for the group-wise 4-bit case.

use super::WeightQuantizer;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    PerChannel,
    /// group size along the reduction (in) axis
    Group(usize),
}

#[derive(Clone, Debug)]
pub struct AffineQuantizer {
    pub bits: u32,
    pub granularity: Granularity,
}

impl AffineQuantizer {
    pub fn new(bits: u32, granularity: Granularity) -> Self {
        assert!((2..=8).contains(&bits), "bits {bits} out of range");
        AffineQuantizer { bits, granularity }
    }

    pub fn int4_group32() -> Self {
        AffineQuantizer::new(4, Granularity::Group(32))
    }

    pub fn int8_per_channel() -> Self {
        AffineQuantizer::new(8, Granularity::PerChannel)
    }

    fn qmax(&self) -> f32 {
        ((1u32 << (self.bits - 1)) - 1) as f32
    }

    /// QDQ one contiguous group with an absmax scale; returns the scale.
    pub fn qdq_group(&self, xs: &mut [f32]) -> f32 {
        let absmax = xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let scale = if absmax == 0.0 { 1.0 } else { absmax / self.qmax() };
        let qmax = self.qmax();
        for x in xs.iter_mut() {
            let code = (*x / scale).round().clamp(-qmax, qmax);
            *x = code * scale;
        }
        scale
    }

    /// Quantize to codes (offset so codes are unsigned) — used by packers.
    /// Returns (codes, scales) with one scale per group.
    pub fn quantize_codes(&self, w: &[f32], n: usize, k: usize) -> (Vec<u8>, Vec<f32>) {
        assert_eq!(w.len(), n * k);
        let g = self.group_len(k);
        let qmax = self.qmax();
        let zero = (1u32 << (self.bits - 1)) as f32; // e.g. 8 for int4
        let mut codes = vec![0u8; n * k];
        let mut scales = Vec::with_capacity(n * k / g);
        for row in 0..n {
            for gs in (0..k).step_by(g) {
                let sl = &w[row * k + gs..row * k + gs + g];
                let absmax = sl.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let scale = if absmax == 0.0 { 1.0 } else { absmax / qmax };
                scales.push(scale);
                for (i, &x) in sl.iter().enumerate() {
                    let c = (x / scale).round().clamp(-qmax, qmax) + zero;
                    codes[row * k + gs + i] = c as u8;
                }
            }
        }
        (codes, scales)
    }

    pub fn dequantize_codes(
        &self,
        codes: &[u8],
        scales: &[f32],
        n: usize,
        k: usize,
    ) -> Vec<f32> {
        let g = self.group_len(k);
        let zero = (1u32 << (self.bits - 1)) as f32;
        let mut w = vec![0.0f32; n * k];
        for row in 0..n {
            for gs in (0..k).step_by(g) {
                let scale = scales[(row * k + gs) / g];
                for i in 0..g {
                    w[row * k + gs + i] =
                        (codes[row * k + gs + i] as f32 - zero) * scale;
                }
            }
        }
        w
    }

    fn group_len(&self, k: usize) -> usize {
        match self.granularity {
            Granularity::PerTensor => k, // handled row-wise below
            Granularity::PerChannel => k,
            Granularity::Group(g) => {
                assert!(k % g == 0, "k={k} not divisible by group {g}");
                g
            }
        }
    }
}

impl WeightQuantizer for AffineQuantizer {
    fn name(&self) -> &'static str {
        match (self.bits, self.granularity) {
            (4, _) => "int4",
            (8, _) => "int8",
            _ => "int-affine",
        }
    }

    fn bits(&self) -> f64 {
        // scale overhead: one f32 (32 bits) per group
        let overhead = match self.granularity {
            Granularity::PerTensor => 0.0,
            Granularity::PerChannel => 0.0, // amortized over k, negligible
            Granularity::Group(g) => 32.0 / g as f64,
        };
        self.bits as f64 + overhead
    }

    fn qdq(&self, w: &mut [f32], n: usize, k: usize) {
        assert_eq!(w.len(), n * k);
        match self.granularity {
            Granularity::PerTensor => {
                self.qdq_group(w);
            }
            Granularity::PerChannel => {
                for row in 0..n {
                    self.qdq_group(&mut w[row * k..(row + 1) * k]);
                }
            }
            Granularity::Group(g) => {
                assert!(k % g == 0);
                for row in 0..n {
                    for gs in (0..k).step_by(g) {
                        self.qdq_group(&mut w[row * k + gs..row * k + gs + g]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testing, Rng};

    #[test]
    fn int8_near_lossless() {
        let mut rng = Rng::new(0);
        let mut w = rng.normal_vec(64 * 64, 0.1);
        let orig = w.clone();
        AffineQuantizer::int8_per_channel().qdq(&mut w, 64, 64);
        let mse = crate::util::stats::mse(&w, &orig);
        assert!(mse < 1e-6, "int8 mse {mse}");
    }

    #[test]
    fn int4_coarser_than_int8() {
        let mut rng = Rng::new(1);
        let orig = rng.normal_vec(32 * 64, 1.0);
        let mut w8 = orig.clone();
        let mut w4 = orig.clone();
        AffineQuantizer::int8_per_channel().qdq(&mut w8, 32, 64);
        AffineQuantizer::int4_group32().qdq(&mut w4, 32, 64);
        assert!(
            crate::util::stats::mse(&w4, &orig) > crate::util::stats::mse(&w8, &orig)
        );
    }

    #[test]
    fn codes_roundtrip_equals_qdq() {
        testing::check(8, |rng| {
            let (n, k) = (16, 64);
            let w = rng.normal_vec(n * k, 0.5);
            let q = AffineQuantizer::int4_group32();
            let (codes, scales) = q.quantize_codes(&w, n, k);
            let deq = q.dequantize_codes(&codes, &scales, n, k);
            let mut direct = w.clone();
            q.qdq(&mut direct, n, k);
            testing::assert_allclose(&deq, &direct, 1e-6, 1e-6);
            assert!(codes.iter().all(|&c| c <= 15));
        });
    }

    #[test]
    fn error_bounded_by_half_step() {
        testing::check(8, |rng| {
            let (n, k) = (8, 32);
            let orig = rng.normal_vec(n * k, 1.0);
            let mut w = orig.clone();
            let q = AffineQuantizer::new(4, Granularity::Group(32));
            q.qdq(&mut w, n, k);
            for row in 0..n {
                let sl = &orig[row * k..(row + 1) * k];
                let absmax = sl.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let step = absmax / 7.0;
                for i in 0..k {
                    assert!(
                        (w[row * k + i] - sl[i]).abs() <= 0.5 * step + 1e-6,
                        "row {row} i {i}"
                    );
                }
            }
        });
    }

    #[test]
    fn effective_bits_include_scale_overhead() {
        let q = AffineQuantizer::int4_group32();
        assert!((q.bits() - 5.0).abs() < 1e-9); // 4 + 32/32
    }

    #[test]
    fn zero_weights_stay_zero() {
        let mut w = vec![0.0f32; 64];
        AffineQuantizer::int4_group32().qdq(&mut w, 2, 32);
        assert!(w.iter().all(|&x| x == 0.0));
    }
}
