//! TWN-style ternary quantization — the baseline for Tequila/Sherry (§2.2).
//!
//! codes {0,1,2} -> {-1,0,+1} * alpha with per-out-channel threshold
//! Delta = 0.75 * mean|w| and alpha = mean|w| over the kept set; mirrors
//! kernels/ref.py quantize_ternary.

use super::WeightQuantizer;

#[derive(Clone, Debug)]
pub struct TernaryQuantizer {
    /// threshold multiplier on mean |w| (TWN uses 0.75)
    pub delta_mult: f32,
}

impl Default for TernaryQuantizer {
    fn default() -> Self {
        TernaryQuantizer { delta_mult: 0.75 }
    }
}

impl TernaryQuantizer {
    /// Quantize one row; returns (codes, alpha).
    pub fn quantize_row(&self, row: &[f32]) -> (Vec<u8>, f32) {
        let mean_abs = row.iter().map(|x| x.abs()).sum::<f32>() / row.len() as f32;
        let delta = self.delta_mult * mean_abs;
        let mut kept_sum = 0.0f32;
        let mut kept_n = 0usize;
        let codes: Vec<u8> = row
            .iter()
            .map(|&x| {
                if x.abs() >= delta && delta > 0.0 {
                    kept_sum += x.abs();
                    kept_n += 1;
                    if x > 0.0 {
                        2
                    } else {
                        0
                    }
                } else {
                    1
                }
            })
            .collect();
        let alpha = if kept_n == 0 { 1.0 } else { kept_sum / kept_n as f32 };
        (codes, alpha)
    }

    pub fn quantize_codes(&self, w: &[f32], n: usize, k: usize) -> (Vec<u8>, Vec<f32>) {
        assert_eq!(w.len(), n * k);
        let mut codes = vec![0u8; n * k];
        let mut alphas = Vec::with_capacity(n);
        for row in 0..n {
            let (c, a) = self.quantize_row(&w[row * k..(row + 1) * k]);
            codes[row * k..(row + 1) * k].copy_from_slice(&c);
            alphas.push(a);
        }
        (codes, alphas)
    }

    pub fn dequantize_codes(codes: &[u8], alphas: &[f32], n: usize, k: usize) -> Vec<f32> {
        let mut w = vec![0.0f32; n * k];
        for row in 0..n {
            let a = alphas[row];
            for i in 0..k {
                w[row * k + i] = (codes[row * k + i] as f32 - 1.0) * a;
            }
        }
        w
    }

    /// Fraction of weights in the deadzone (code == 1) — the population
    /// Tequila reactivates.
    pub fn deadzone_fraction(codes: &[u8]) -> f32 {
        codes.iter().filter(|&&c| c == 1).count() as f32 / codes.len().max(1) as f32
    }
}

impl WeightQuantizer for TernaryQuantizer {
    fn name(&self) -> &'static str {
        "ternary"
    }

    fn bits(&self) -> f64 {
        // log2(3) entropy; stored as 1.67 or 1.25-bit via packing.rs codecs
        1.58
    }

    fn qdq(&self, w: &mut [f32], n: usize, k: usize) {
        let (codes, alphas) = self.quantize_codes(w, n, k);
        let deq = Self::dequantize_codes(&codes, &alphas, n, k);
        w.copy_from_slice(&deq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testing, Rng};

    #[test]
    fn signs_preserved_outside_deadzone() {
        let q = TernaryQuantizer::default();
        let row = [2.0f32, -2.0, 0.01, -0.01];
        let (codes, alpha) = q.quantize_row(&row);
        assert_eq!(codes[0], 2);
        assert_eq!(codes[1], 0);
        assert_eq!(codes[2], 1);
        assert_eq!(codes[3], 1);
        assert!((alpha - 2.0).abs() < 1e-6);
    }

    #[test]
    fn deadzone_fraction_reasonable_for_gaussian() {
        let mut rng = Rng::new(0);
        let w = rng.normal_vec(4096, 1.0);
        let q = TernaryQuantizer::default();
        let (codes, _) = q.quantize_codes(&w, 1, 4096);
        let f = TernaryQuantizer::deadzone_fraction(&codes);
        // P(|x| < 0.75 * E|x|) for a gaussian ~ 0.45
        assert!((0.3..0.6).contains(&f), "deadzone {f}");
    }

    #[test]
    fn qdq_idempotent() {
        testing::check(8, |rng| {
            let (n, k) = (8, 64);
            let mut w = rng.normal_vec(n * k, 1.0);
            let q = TernaryQuantizer::default();
            q.qdq(&mut w, n, k);
            let once = w.clone();
            q.qdq(&mut w, n, k);
            // quantizing a ternary tensor again is near-stable (alpha is a
            // fixed point of the mean over kept weights)
            testing::assert_allclose(&w, &once, 1e-4, 1e-5);
        });
    }

    #[test]
    fn all_zero_row_safe() {
        let q = TernaryQuantizer::default();
        let (codes, alpha) = q.quantize_row(&[0.0; 16]);
        assert!(codes.iter().all(|&c| c == 1));
        assert_eq!(alpha, 1.0);
    }
}
