//! Calibration statistics + low-memory calibration accounting (§2.3.1).
//!
//! `CalibStats` accumulates per-channel activation statistics (absmax,
//! mean |x|, reservoir sample for quantiles) across calibration batches —
//! the inputs AWQ / SmoothQuant / LeptoQuant consume.
//!
//! `LowMemoryLedger` models the paper's Low-Memory FP8 Calibration mode:
//! layers are streamed GPU<->CPU so peak resident bytes stay under a
//! budget; the ledger tracks residency, swaps, and peak usage so the
//! coordinator can report the same "single-GPU calibration" metric the
//! paper claims for DeepSeek-R1.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct CalibStats {
    pub channels: usize,
    pub absmax: Vec<f32>,
    pub mean_abs: Vec<f32>,
    pub count: usize,
    /// reservoir of |x| samples for quantile queries
    reservoir: Vec<f32>,
    reservoir_cap: usize,
    seen: usize,
    rng: Rng,
}

impl CalibStats {
    pub fn new(channels: usize) -> Self {
        CalibStats {
            channels,
            absmax: vec![0.0; channels],
            mean_abs: vec![0.0; channels],
            count: 0,
            reservoir: Vec::new(),
            reservoir_cap: 8192,
            seen: 0,
            rng: Rng::new(0xCA11B),
        }
    }

    /// Feed a batch of activations, row-major [rows, channels].
    pub fn update(&mut self, x: &[f32], rows: usize) {
        assert_eq!(x.len(), rows * self.channels);
        for r in 0..rows {
            for c in 0..self.channels {
                let a = x[r * self.channels + c].abs();
                self.absmax[c] = self.absmax[c].max(a);
                // running mean
                let n = (self.count * rows + r + 1) as f32;
                self.mean_abs[c] += (a - self.mean_abs[c]) / n.max(1.0);
                // reservoir sampling
                self.seen += 1;
                if self.reservoir.len() < self.reservoir_cap {
                    self.reservoir.push(a);
                } else if self.rng.below(self.seen) < self.reservoir_cap {
                    let slot = self.rng.below(self.reservoir_cap);
                    self.reservoir[slot] = a;
                }
            }
        }
        self.count += 1;
    }

    pub fn tensor_absmax(&self) -> f32 {
        self.absmax.iter().fold(0.0f32, |m, &x| m.max(x))
    }

    /// |x| value at the given upper quantile (e.g. 0.001 -> 99.9th pct) —
    /// the `Outlier(W, alpha)` operator of LeptoQuant (eq. 5).
    pub fn outlier(&self, alpha: f64) -> f32 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        if alpha <= 0.0 {
            return self.tensor_absmax();
        }
        let mut s = self.reservoir.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let idx = ((1.0 - alpha) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

/// Events emitted by the low-memory layer streamer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapEvent {
    LoadToDevice(usize),
    OffloadToHost(usize),
}

/// Residency ledger for low-memory calibration.
#[derive(Clone, Debug)]
pub struct LowMemoryLedger {
    /// bytes of each layer
    pub layer_bytes: Vec<usize>,
    /// maximum simultaneously-resident layers (0 = unlimited)
    pub budget_layers: usize,
    resident: Vec<usize>, // LRU queue of layer ids
    pub peak_bytes: usize,
    pub swaps: usize,
    pub log: Vec<SwapEvent>,
}

impl LowMemoryLedger {
    pub fn new(layer_bytes: Vec<usize>, budget_layers: usize) -> Self {
        LowMemoryLedger {
            layer_bytes,
            budget_layers,
            resident: Vec::new(),
            peak_bytes: 0,
            swaps: 0,
            log: Vec::new(),
        }
    }

    /// Touch a layer for computation; evicts LRU layers past the budget.
    pub fn touch(&mut self, layer: usize) {
        if let Some(pos) = self.resident.iter().position(|&l| l == layer) {
            self.resident.remove(pos);
            self.resident.push(layer);
        } else {
            self.log.push(SwapEvent::LoadToDevice(layer));
            self.swaps += 1;
            self.resident.push(layer);
            if self.budget_layers > 0 {
                while self.resident.len() > self.budget_layers {
                    let evicted = self.resident.remove(0);
                    self.log.push(SwapEvent::OffloadToHost(evicted));
                    self.swaps += 1;
                }
            }
        }
        let cur: usize = self.resident.iter().map(|&l| self.layer_bytes[l]).sum();
        self.peak_bytes = self.peak_bytes.max(cur);
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident.iter().map(|&l| self.layer_bytes[l]).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.layer_bytes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn stats_track_absmax() {
        let mut s = CalibStats::new(4);
        s.update(&[1.0, -2.0, 0.5, 0.1, 0.2, 3.0, -0.5, 0.0], 2);
        assert_eq!(s.absmax, vec![1.0, 3.0, 0.5, 0.1]);
        assert_eq!(s.tensor_absmax(), 3.0);
    }

    #[test]
    fn outlier_quantile_below_absmax() {
        let mut s = CalibStats::new(1);
        let mut rng = Rng::new(0);
        let xs: Vec<f32> = (0..4000).map(|_| rng.normal()).collect();
        s.update(&xs, 4000);
        let q = s.outlier(0.01);
        assert!(q < s.tensor_absmax());
        assert!(q > 1.0, "99th pct of |N(0,1)| ≈ 2.57, got {q}");
        assert_eq!(s.outlier(0.0), s.tensor_absmax());
    }

    #[test]
    fn ledger_respects_budget() {
        let mut led = LowMemoryLedger::new(vec![100; 8], 2);
        for l in 0..8 {
            led.touch(l);
        }
        assert!(led.peak_bytes <= 200);
        assert!(led.swaps >= 8);
        // total model never resident at once
        assert!(led.total_bytes() == 800);
        assert!(led.resident_bytes() <= 200);
    }

    #[test]
    fn ledger_unlimited_keeps_all() {
        let mut led = LowMemoryLedger::new(vec![10; 4], 0);
        for l in 0..4 {
            led.touch(l);
        }
        assert_eq!(led.peak_bytes, 40);
        assert_eq!(led.swaps, 4); // only loads, no evictions
    }

    #[test]
    fn ledger_lru_order() {
        let mut led = LowMemoryLedger::new(vec![1; 3], 2);
        led.touch(0);
        led.touch(1);
        led.touch(0); // refresh 0
        led.touch(2); // should evict 1, not 0
        assert!(led.log.contains(&SwapEvent::OffloadToHost(1)));
        assert!(!led.log.contains(&SwapEvent::OffloadToHost(0)));
    }
}
