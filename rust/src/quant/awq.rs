//! AWQ — activation-aware weight quantization (Lin et al., 2024), the
//! second INT4 scheme of the paper's PTQ framework (§2.3.1).
//!
//! Important channels (by activation magnitude) get their numerical range
//! amplified before quantization: W' = W * s, X' = X / s with
//! s_c = mean|X_c|^alpha, alpha grid-searched against layer output MSE.

use crate::tensor::{ops::matmul_transb, Tensor};

use super::{AffineQuantizer, Granularity, WeightQuantizer};

#[derive(Clone, Debug)]
pub struct Awq {
    pub bits: u32,
    pub group: usize,
    /// alpha grid for the per-channel scale exponent
    pub alpha_grid: Vec<f32>,
}

impl Default for Awq {
    fn default() -> Self {
        Awq {
            bits: 4,
            group: 32,
            alpha_grid: vec![0.0, 0.25, 0.5, 0.75, 1.0],
        }
    }
}

#[derive(Clone, Debug)]
pub struct AwqResult {
    /// QDQ weights *in the original (unscaled) space* — ready to substitute
    pub weights: Tensor,
    pub best_alpha: f32,
    pub output_mse: f32,
}

impl Awq {
    /// Quantize w [n, k] with calibration activations x [m, k].
    pub fn quantize(&self, w: &Tensor, x: &Tensor) -> AwqResult {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(x.cols(), k);
        let y_ref = matmul_transb(x, w);

        // per-channel activation magnitude
        let mut act_mag = vec![0.0f32; k];
        for r in 0..x.rows() {
            for c in 0..k {
                act_mag[c] += x.row(r)[c].abs();
            }
        }
        for a in act_mag.iter_mut() {
            *a = (*a / x.rows() as f32).max(1e-6);
        }

        let q = AffineQuantizer::new(self.bits, Granularity::Group(self.group));
        let mut best: Option<AwqResult> = None;
        for &alpha in &self.alpha_grid {
            // s_c = mag^alpha, normalized to geometric mean 1 for stability
            let mut s: Vec<f32> = act_mag.iter().map(|m| m.powf(alpha)).collect();
            let log_mean: f32 =
                s.iter().map(|v| v.ln()).sum::<f32>() / k as f32;
            let norm = log_mean.exp();
            s.iter_mut().for_each(|v| *v /= norm);

            // scale, quantize, unscale
            let mut ws = w.clone();
            for r in 0..n {
                let row = ws.row_mut(r);
                for c in 0..k {
                    row[c] *= s[c];
                }
            }
            q.qdq(&mut ws.data, n, k);
            for r in 0..n {
                let row = ws.row_mut(r);
                for c in 0..k {
                    row[c] /= s[c];
                }
            }
            let y = matmul_transb(x, &ws);
            let mse = crate::util::stats::mse(&y.data, &y_ref.data);
            if best.as_ref().map(|b| mse < b.output_mse).unwrap_or(true) {
                best = Some(AwqResult { weights: ws, best_alpha: alpha, output_mse: mse });
            }
        }
        best.unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Activations with a few dominant channels — AWQ's motivating setting.
    fn outlier_acts(m: usize, k: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::randn(&[m, k], 1.0, &mut rng);
        for r in 0..m {
            for c in (0..k).step_by(16) {
                x.row_mut(r)[c] *= 12.0; // outlier channels
            }
        }
        x
    }

    #[test]
    fn awq_no_worse_than_rtn() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(&[16, 64], 0.5, &mut rng);
        let x = outlier_acts(48, 64, 1);
        let y_ref = matmul_transb(&x, &w);

        let res = Awq::default().quantize(&w, &x);

        let mut rtn = w.clone();
        use crate::quant::WeightQuantizer;
        AffineQuantizer::int4_group32().qdq(&mut rtn.data, 16, 64);
        let y_rtn = matmul_transb(&x, &rtn);
        let e_rtn = crate::util::stats::mse(&y_rtn.data, &y_ref.data);

        // alpha=0 in the grid *is* RTN, so AWQ can never be worse
        assert!(res.output_mse <= e_rtn + 1e-9, "{} vs {e_rtn}", res.output_mse);
    }

    #[test]
    fn awq_prefers_nonzero_alpha_with_outliers() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[24, 64], 0.5, &mut rng);
        let x = outlier_acts(64, 64, 3);
        let res = Awq::default().quantize(&w, &x);
        assert!(res.best_alpha > 0.0, "expected activation-aware scaling to win");
    }

    #[test]
    fn result_shape_and_finite() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[8, 32], 0.5, &mut rng);
        let x = Tensor::randn(&[16, 32], 1.0, &mut rng);
        let res = Awq::default().quantize(&w, &x);
        assert_eq!(res.weights.dims(), &[8, 32]);
        assert!(res.weights.data.iter().all(|v| v.is_finite()));
    }
}
