//! Software FP8 codecs (E4M3FN and E5M2) with round-to-nearest-even.
//!
//! E4M3FN (the deployment format in the paper's PTQ suite): 1 sign, 4
//! exponent (bias 7), 3 mantissa; no infinities; max finite = 448;
//! subnormal step 2^-9. E5M2: bias 15, 2 mantissa, max finite 57344.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Format {
    E4M3,
    E5M2,
}

impl Fp8Format {
    pub fn max(&self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    pub fn qdq(&self, x: f32) -> f32 {
        match self {
            Fp8Format::E4M3 => fp8_e4m3_qdq(x),
            Fp8Format::E5M2 => fp8_e5m2_qdq(x),
        }
    }
}

fn qdq_generic(x: f32, mant_bits: i32, min_exp: i32, max_val: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    let sign = if x.is_sign_negative() { -1.0 } else { 1.0 };
    let a = x.abs();
    if a == 0.0 {
        return 0.0;
    }
    if a >= max_val {
        return sign * max_val;
    }
    // exponent of the value (floor(log2 a)), clamped to the subnormal floor
    let e = (a.log2().floor() as i32).max(min_exp);
    let step = (e - mant_bits) as f32;
    let step = step.exp2();
    let q = (a / step).round_ties_even() * step;
    // rounding up may have pushed us past max
    sign * q.min(max_val)
}

/// Round-trip a value through FP8-E4M3FN.
pub fn fp8_e4m3_qdq(x: f32) -> f32 {
    qdq_generic(x, 3, -6, 448.0)
}

/// Round-trip a value through FP8-E5M2.
pub fn fp8_e5m2_qdq(x: f32) -> f32 {
    qdq_generic(x, 2, -14, 57344.0)
}

/// QDQ a slice with a per-tensor scale mapping absmax -> fmt.max().
/// Returns the scale used.
pub fn qdq_slice_scaled(xs: &mut [f32], fmt: Fp8Format) -> f32 {
    let absmax = xs.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1e-12);
    let scale = absmax / fmt.max();
    for x in xs.iter_mut() {
        *x = fmt.qdq(*x / scale) * scale;
    }
    scale
}

/// QDQ with an explicit scale (the LeptoQuant search path).
pub fn qdq_slice_with_scale(xs: &mut [f32], fmt: Fp8Format, scale: f32) {
    for x in xs.iter_mut() {
        *x = fmt.qdq(*x / scale) * scale;
    }
}

/// Per-tensor absmax-scaled FP8-E4M3 weight QDQ as a [`WeightQuantizer`] —
/// the weight-side transform of the `fp8_dynamic` deployment mode
/// (activation QDQ is a runtime concern handled by LeptoQuant's scales).
///
/// [`WeightQuantizer`]: crate::quant::WeightQuantizer
#[derive(Clone, Copy, Debug, Default)]
pub struct Fp8WeightQuantizer;

impl crate::quant::WeightQuantizer for Fp8WeightQuantizer {
    fn name(&self) -> &'static str {
        "fp8"
    }

    fn bits(&self) -> f64 {
        8.0
    }

    fn qdq(&self, w: &mut [f32], _n: usize, _k: usize) {
        qdq_slice_scaled(w, Fp8Format::E4M3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        // representable e4m3 values must be fixed points
        for v in [0.0f32, 1.0, -1.0, 0.5, 1.75, 448.0, -448.0, 0.015625] {
            assert_eq!(fp8_e4m3_qdq(v), v, "{v}");
        }
    }

    #[test]
    fn saturates_at_max() {
        assert_eq!(fp8_e4m3_qdq(1e6), 448.0);
        assert_eq!(fp8_e4m3_qdq(-1e6), -448.0);
        assert_eq!(fp8_e5m2_qdq(1e9), 57344.0);
    }

    #[test]
    fn relative_error_bounded() {
        // e4m3 normals: relative error <= 2^-4 (half ulp of 3-bit mantissa)
        let mut x = 0.02f32;
        while x < 400.0 {
            let q = fp8_e4m3_qdq(x);
            let rel = (q - x).abs() / x;
            assert!(rel <= 1.0 / 16.0 + 1e-6, "x={x} q={q} rel={rel}");
            x *= 1.173;
        }
    }

    #[test]
    fn subnormals_snap_to_grid() {
        // subnormal step is 2^-9 = 0.001953125
        let step = 2f32.powi(-9);
        let q = fp8_e4m3_qdq(step * 2.4);
        assert_eq!(q, step * 2.0);
        let q2 = fp8_e4m3_qdq(step * 2.6);
        assert_eq!(q2, step * 3.0);
        // below half a step rounds to zero
        assert_eq!(fp8_e4m3_qdq(step * 0.4), 0.0);
    }

    #[test]
    fn round_ties_even() {
        // between 16 and 18 (step 2 at that exponent), 17 ties to 16 (even)
        let q = fp8_e4m3_qdq(17.0);
        assert_eq!(q, 16.0);
        let q = fp8_e4m3_qdq(19.0);
        assert_eq!(q, 20.0);
    }

    #[test]
    fn e5m2_coarser_than_e4m3_midrange() {
        let x = 3.3f32;
        let e4 = (fp8_e4m3_qdq(x) - x).abs();
        let e5 = (fp8_e5m2_qdq(x) - x).abs();
        assert!(e5 >= e4);
    }

    #[test]
    fn scaled_qdq_uses_full_range() {
        let mut xs = vec![0.001f32, -0.002, 0.0005, 0.002];
        let scale = qdq_slice_scaled(&mut xs, Fp8Format::E4M3);
        assert!((scale - 0.002 / 448.0).abs() < 1e-9);
        // absmax element must be exactly representable after scaling
        assert!((xs[3] - 0.002).abs() < 1e-9);
    }

    #[test]
    fn nan_propagates() {
        assert!(fp8_e4m3_qdq(f32::NAN).is_nan());
    }
}
