//! Quantization framework — pillar 1 of the paper (§2).
//!
//! PTQ: fp8 (E4M3/E5M2) QDQ, k-bit affine (per-tensor / per-channel /
//! group-wise), GPTQ layer-wise reconstruction, AWQ activation-aware
//! scaling, SmoothQuant-style migration, and **LeptoQuant** outlier-
//! isolation scale search (§2.3.2). QAT-side quantizers: SEQ 2-bit
//! (§2.1.2), ternary TWN, **Tequila** deadzone-bias (§2.2.1) and **Sherry**
//! 3:4 structured sparsity with the Arenas annealing schedule (§2.2.2).
//! `packing` holds the bit-exact storage codecs (2-bit, 1.67-bit 3-in-5,
//! Sherry's 1.25-bit 4-in-5) plus packed GEMV kernels for the edge
//! efficiency benches (Fig. 2, Table 3).

pub mod awq;
pub mod calib;
pub mod fp8;
pub mod gptq;
pub mod int_affine;
pub mod leptoquant;
pub mod packing;
pub mod seq2;
pub mod sherry;
pub mod smooth;
pub mod tequila;
pub mod ternary;

pub use calib::CalibStats;
pub use fp8::{fp8_e4m3_qdq, fp8_e5m2_qdq, Fp8Format, Fp8WeightQuantizer};
pub use int_affine::{AffineQuantizer, Granularity};
pub use leptoquant::LeptoQuant;
pub use seq2::Seq2Quantizer;
pub use sherry::{ArenasSchedule, Sherry};
pub use tequila::Tequila;
pub use ternary::TernaryQuantizer;

/// Common interface: quantize-dequantize a weight matrix `[out, in]`
/// in place, returning bookkeeping info as a human-readable tag.
pub trait WeightQuantizer {
    fn name(&self) -> &'static str;
    /// effective bits per weight (for size accounting)
    fn bits(&self) -> f64;
    /// QDQ: replace w by its quantized image. `w` is row-major [n, k].
    fn qdq(&self, w: &mut [f32], n: usize, k: usize);
}
