//! GPTQ — layer-wise reconstruction quantization (Frantar et al., 2022),
//! one of the two INT4 schemes in the paper's PTQ framework (§2.3.1).
//!
//! Quantizes weight columns in order while redistributing the rounding
//! error over the not-yet-quantized columns using the inverse Hessian
//! H = 2 XᵀX + λI of the layer's calibration activations — minimizing the
//! layer *output* error rather than the weight error.

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Gptq {
    pub bits: u32,
    pub group: usize,
    /// Hessian damping fraction of mean diagonal (GPTQ uses 1%)
    pub damp: f32,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { bits: 4, group: 32, damp: 0.01 }
    }
}

impl Gptq {
    fn qmax(&self) -> f32 {
        ((1u32 << (self.bits - 1)) - 1) as f32
    }

    /// Quantize w [n, k] given calibration activations x [m, k].
    /// Returns the QDQ weight matrix.
    pub fn quantize(&self, w: &Tensor, x: &Tensor) -> Tensor {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(x.cols(), k);
        let hinv = self.hessian_inverse(x);

        // per (row, group) scales from the *original* weights
        let qmax = self.qmax();
        let groups = k / self.group;
        let mut scales = vec![0.0f32; n * groups];
        for r in 0..n {
            for g in 0..groups {
                let sl = &w.row(r)[g * self.group..(g + 1) * self.group];
                let absmax = sl.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                scales[r * groups + g] = if absmax == 0.0 { 1.0 } else { absmax / qmax };
            }
        }

        // working copy; columns quantized in order with error feedback
        let mut wk = w.clone();
        let mut out = Tensor::zeros(&[n, k]);
        for j in 0..k {
            let d = hinv[j * k + j].max(1e-8);
            let g = j / self.group;
            for r in 0..n {
                let s = scales[r * groups + g];
                let v = wk.row(r)[j];
                let q = (v / s).round().clamp(-qmax, qmax) * s;
                out.row_mut(r)[j] = q;
                let err = (v - q) / d;
                // propagate to remaining columns of this row
                let row = wk.row_mut(r);
                for jj in (j + 1)..k {
                    row[jj] -= err * hinv[j * k + jj];
                }
            }
        }
        out
    }

    /// H^{-1} with damping, via Gauss-Jordan (k is at most a few hundred
    /// for the tiny models in this repo).
    fn hessian_inverse(&self, x: &Tensor) -> Vec<f32> {
        let (m, k) = (x.rows(), x.cols());
        // H = 2/m * X^T X
        let mut h = vec![0.0f32; k * k];
        for r in 0..m {
            let row = x.row(r);
            for i in 0..k {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in 0..k {
                    h[i * k + j] += 2.0 * xi * row[j] / m as f32;
                }
            }
        }
        let mean_diag: f32 = (0..k).map(|i| h[i * k + i]).sum::<f32>() / k as f32;
        let damp = self.damp * mean_diag.max(1e-8);
        for i in 0..k {
            h[i * k + i] += damp;
        }
        invert(&mut h, k)
    }
}

/// Gauss-Jordan inverse of a k x k matrix (destroys the input).
fn invert(a: &mut [f32], k: usize) -> Vec<f32> {
    let mut inv = vec![0.0f32; k * k];
    for i in 0..k {
        inv[i * k + i] = 1.0;
    }
    for col in 0..k {
        // partial pivot
        let mut piv = col;
        for r in (col + 1)..k {
            if a[r * k + col].abs() > a[piv * k + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..k {
                a.swap(col * k + j, piv * k + j);
                inv.swap(col * k + j, piv * k + j);
            }
        }
        let d = a[col * k + col];
        let d = if d.abs() < 1e-12 { 1e-12 } else { d };
        let dinv = 1.0 / d;
        for j in 0..k {
            a[col * k + j] *= dinv;
            inv[col * k + j] *= dinv;
        }
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = a[r * k + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..k {
                a[r * k + j] -= f * a[col * k + j];
                inv[r * k + j] -= f * inv[col * k + j];
            }
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{AffineQuantizer, WeightQuantizer};
    use crate::tensor::ops::matmul_transb;
    use crate::util::Rng;

    fn setup(seed: u64, n: usize, k: usize, m: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[n, k], 0.5, &mut rng);
        // correlated activations (what makes GPTQ matter)
        let base = Tensor::randn(&[m, k / 4], 1.0, &mut rng);
        let mix = Tensor::randn(&[k, k / 4], 0.5, &mut rng);
        let mut x = Tensor::zeros(&[m, k]);
        for r in 0..m {
            for c in 0..k {
                x.row_mut(r)[c] =
                    crate::tensor::ops::dot(base.row(r), mix.row(c)) + rng.normal() * 0.1;
            }
        }
        (w, x)
    }

    #[test]
    fn invert_identity() {
        let mut a = vec![2.0, 0.0, 0.0, 4.0];
        let inv = invert(&mut a, 2);
        assert!((inv[0] - 0.5).abs() < 1e-6);
        assert!((inv[3] - 0.25).abs() < 1e-6);
        assert!(inv[1].abs() < 1e-6);
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let (w, x) = setup(0, 24, 64, 96);
        let y_ref = matmul_transb(&x, &w);

        let gptq_w = Gptq::default().quantize(&w, &x);
        let y_gptq = matmul_transb(&x, &gptq_w);

        let mut rtn_w = w.clone();
        AffineQuantizer::int4_group32().qdq(&mut rtn_w.data, 24, 64);
        let y_rtn = matmul_transb(&x, &rtn_w);

        let e_gptq = crate::util::stats::mse(&y_gptq.data, &y_ref.data);
        let e_rtn = crate::util::stats::mse(&y_rtn.data, &y_ref.data);
        assert!(
            e_gptq < e_rtn,
            "gptq {e_gptq} should beat round-to-nearest {e_rtn}"
        );
    }

    #[test]
    fn gptq_output_on_quant_grid() {
        let (w, x) = setup(1, 8, 32, 40);
        let q = Gptq::default();
        let wq = q.quantize(&w, &x);
        // every output weight is a multiple of its group scale
        let qmax = 7.0f32;
        for r in 0..8 {
            let sl = w.row(r);
            let absmax = sl.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let s = absmax / qmax;
            for j in 0..32 {
                let code = wq.row(r)[j] / s;
                assert!((code - code.round()).abs() < 1e-3, "not on grid");
                assert!(code.round().abs() <= qmax + 0.5);
            }
        }
    }

    #[test]
    fn gptq_respects_shapes() {
        let (w, x) = setup(2, 4, 32, 16);
        let wq = Gptq::default().quantize(&w, &x);
        assert_eq!(wq.dims(), w.dims());
        assert!(wq.data.iter().all(|v| v.is_finite()));
    }
}
