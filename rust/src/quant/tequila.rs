//! Tequila — trapping-free ternary quantization (paper §2.2.1).
//!
//! Standard ternary QAT leaves deadzone weights (|w| < Δ) with
//! uninformative STE gradients ("deadzone trapping"). Tequila repurposes
//! them as an adaptive dynamic bias during training:
//!
//! ```text
//! Y = X·Q(W) + C(W),   C(W) = Σ_{i∈D} λ·w_i            (eq. 2)
//! ```
//!
//! which gives every dead weight a direct gradient path (eq. 3). After
//! training the bias is *merged into static parameters* — zero inference
//! overhead. This module provides the quantize-with-bias transform and the
//! offline merge; the training loop lives in qat/trainer.rs.

use super::ternary::TernaryQuantizer;

#[derive(Clone, Debug)]
pub struct Tequila {
    pub base: TernaryQuantizer,
    /// λ — the dead-weight bias coupling (paper's residual coefficient)
    pub lambda: f32,
}

impl Default for Tequila {
    fn default() -> Self {
        Tequila { base: TernaryQuantizer::default(), lambda: 0.05 }
    }
}

/// Result of quantizing one weight matrix with Tequila.
#[derive(Clone, Debug)]
pub struct TequilaQuant {
    pub codes: Vec<u8>,
    pub alphas: Vec<f32>,
    /// per-output-row dynamic bias C(W) = λ Σ_{i∈D} w_i
    pub bias: Vec<f32>,
    pub n: usize,
    pub k: usize,
}

impl Tequila {
    /// Quantize and extract the deadzone bias per output row.
    pub fn quantize(&self, w: &[f32], n: usize, k: usize) -> TequilaQuant {
        let (codes, alphas) = self.base.quantize_codes(w, n, k);
        let mut bias = vec![0.0f32; n];
        for row in 0..n {
            let mut c = 0.0;
            for i in 0..k {
                if codes[row * k + i] == 1 {
                    c += w[row * k + i];
                }
            }
            bias[row] = self.lambda * c;
        }
        TequilaQuant { codes, alphas, bias, n, k }
    }

    /// Training-time forward: y = x @ Wq.T + C(W) (bias broadcast per row).
    pub fn forward(&self, q: &TequilaQuant, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), q.k);
        let mut y = vec![0.0f32; q.n];
        for row in 0..q.n {
            let a = q.alphas[row];
            let mut acc = 0.0f32;
            for i in 0..q.k {
                let wv = (q.codes[row * q.k + i] as f32 - 1.0) * a;
                acc += x[i] * wv;
            }
            y[row] = acc + q.bias[row];
        }
        y
    }

    /// Per-weight STE gradient multiplier: dead weights receive the extra
    /// λ·dL/dY path (paper eq. 3); live weights get the plain STE path.
    pub fn grad_scale(&self, code: u8) -> f32 {
        if code == 1 {
            1.0 + self.lambda
        } else {
            1.0
        }
    }

    /// Offline merge: fold C(W) into a static bias vector (inference sees a
    /// plain ternary layer + bias — "nearly zero inference overhead").
    pub fn merge_bias(q: &TequilaQuant) -> Vec<f32> {
        q.bias.clone()
    }
}

/// Deploy-side QDQ so Tequila-trained checkpoints slot into the generic
/// pass pipeline: the weight image is the ternary reconstruction ONLY.
/// The deadzone bias C(W) is **dropped**, not merged — the Transformer
/// has no bias slots, so [`Tequila::merge_bias`] can only be applied by a
/// deployment target that does (the pipeline's `tequila` stage records
/// this limitation in its report notes). Metrics from this QDQ therefore
/// measure the ternary image without the bias recovery.
impl super::WeightQuantizer for Tequila {
    fn name(&self) -> &'static str {
        "tequila"
    }

    fn bits(&self) -> f64 {
        2.0
    }

    fn qdq(&self, w: &mut [f32], n: usize, k: usize) {
        let q = self.quantize(w, n, k);
        for row in 0..n {
            let a = q.alphas[row];
            for i in 0..k {
                w[row * k + i] = (q.codes[row * k + i] as f32 - 1.0) * a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing;

    #[test]
    fn bias_collects_deadzone_mass() {
        let t = Tequila { lambda: 0.1, ..Default::default() };
        // row: two big weights, two dead weights summing to 0.03
        let w = [2.0f32, -2.0, 0.02, 0.01];
        let q = t.quantize(&w, 1, 4);
        assert!((q.bias[0] - 0.1 * 0.03).abs() < 1e-7);
    }

    #[test]
    fn forward_adds_bias() {
        let t = Tequila { lambda: 1.0, ..Default::default() };
        let w = [2.0f32, -2.0, 0.02, 0.01];
        let q = t.quantize(&w, 1, 4);
        let x = [0.0f32; 4]; // zero input isolates the bias
        let y = t.forward(&q, &x);
        assert!((y[0] - q.bias[0]).abs() < 1e-7);
    }

    #[test]
    fn grad_scale_boosts_dead_weights() {
        let t = Tequila { lambda: 0.05, ..Default::default() };
        assert!(t.grad_scale(1) > t.grad_scale(0));
        assert_eq!(t.grad_scale(2), 1.0);
    }

    #[test]
    fn dead_fraction_drives_bias_magnitude() {
        testing::check(8, |rng| {
            let (n, k) = (4, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let t = Tequila::default();
            let q = t.quantize(&w, n, k);
            assert_eq!(q.bias.len(), n);
            // bias is bounded by λ * Σ|dead| <= λ * k * Δ-ish
            for &b in &q.bias {
                assert!(b.abs() < t.lambda * k as f32);
            }
        });
    }
}
